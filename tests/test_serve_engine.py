"""Bucketed continuous-batching engine tests: bucket selection, padded-prefill
state splicing vs the unpadded batch-1 reference, slot eviction/refill, EOS,
and the no-recompile-after-warmup guarantee (one compile per bucket)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import build_model
from repro.serve.engine import (EngineStats, Request, ServeEngine, bucket_for,
                                prefill_buckets)


def _tiny_model(arch="qwen3-0.6b", layers=2):
    cfg = reduced_config(arch)
    cfg = cfg.replace(num_layers=max(layers, len(cfg.block_pattern)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ------------------------------------------------------------------- buckets
def test_prefill_buckets_powers_of_two():
    assert prefill_buckets(64) == (16, 32, 64)
    # non-power-of-two max_len gets a final gap-covering bucket
    assert prefill_buckets(100) == (16, 32, 64, 100)
    assert prefill_buckets(16) == (16,)
    assert prefill_buckets(64, min_bucket=8) == (8, 16, 32, 64)
    with pytest.raises(ValueError):
        prefill_buckets(8, min_bucket=16)


def test_bucket_for_selects_smallest_fitting():
    buckets = (16, 32, 64)
    assert bucket_for(1, buckets) == 16
    assert bucket_for(16, buckets) == 16
    assert bucket_for(17, buckets) == 32
    assert bucket_for(64, buckets) == 64
    with pytest.raises(ValueError):
        bucket_for(65, buckets)


def test_submit_rejects_oversized_prompt():
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=32)
    with pytest.raises(ValueError):
        engine.submit(Request(rid=0, prompt=list(range(40))))
    # a max_len prompt fills the cache with no room to decode one token
    with pytest.raises(ValueError):
        engine.submit(Request(rid=1, prompt=list(range(32))))
    with pytest.raises(ValueError):
        engine.submit(Request(rid=3, prompt=[]))
    with pytest.raises(ValueError):
        engine.submit(Request(rid=4, prompt=[1, 2], max_new_tokens=0))
    with pytest.raises(NotImplementedError):
        ServeEngine(model, params, slots=1, max_len=32, greedy=False)
    # max_len - 1 is the longest admissible prompt
    engine.submit(Request(rid=2, prompt=list(range(31))))


def test_non_power_of_two_max_len_accepts_prompts_near_cache_size():
    """Regression: max_len=48 must not silently reject a 40-token prompt
    (the bucket list gains a final 48-wide bucket)."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=1, max_len=48)
    assert engine.buckets == (16, 32, 48)
    (req,) = engine.run([Request(rid=0, prompt=list(range(1, 41)),
                                 max_new_tokens=3)])
    assert req.done and len(req.generated) == 3


def test_gap_bucket_not_divisible_by_scan_chunk_on_recurrent_arch():
    """Regression: a 100-wide gap bucket is not a multiple of the reduced
    configs' scan_chunk=16 — the chunked linear scan must identity-pad the
    tail instead of crashing, and stay exact vs the unpadded reference."""
    _, model, params = _tiny_model("recurrentgemma-2b")
    engine = ServeEngine(model, params, slots=1, max_len=100)
    assert engine.buckets[-1] == 100
    prompt = list(range(1, 71))                   # selects the 100 bucket
    (req,) = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=3)])
    assert req.done and len(req.generated) == 3

    states = model.init_states(1, 100)
    logits, states, _ = model.prefill(
        params, jnp.asarray([prompt], jnp.int32), states)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(2):
        logits, states = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), states,
            jnp.asarray([pos], jnp.int32), None)
        toks.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    assert req.generated == toks


# ----------------------------------------------- splice vs batch-1 reference
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "recurrentgemma-2b",
                                  "falcon-mamba-7b"])
def test_bucketed_prefill_matches_unpadded_reference(arch):
    """Engine output (padded/bucketed prefill spliced into the pool) must
    reproduce the manual unpadded batch-1 prefill + decode token-for-token —
    covers the KV, RG-LRU, and SSM state families."""
    _, model, params = _tiny_model(arch)
    prompt = [5, 9, 2, 7, 11]
    n_new = 4
    engine = ServeEngine(model, params, slots=2, max_len=64)
    (req,) = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=n_new)])

    states = model.init_states(1, 64)
    logits, states, memory = model.prefill(
        params, jnp.asarray([prompt], jnp.int32), states)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, states = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), states,
            jnp.asarray([pos], jnp.int32), memory)
        toks.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    assert req.generated == toks


def test_padded_prefill_logits_and_states_exact():
    """Length-masked padded prefill is numerically identical to the unpadded
    one — logits at length-1 and the post-prefill decode logits match."""
    _, model, params = _tiny_model("recurrentgemma-2b")
    prompt = [5, 9, 2, 7, 11]
    L = len(prompt)
    s_ref = model.init_states(1, 64)
    lg_ref, s_ref, _ = model.prefill(
        params, jnp.asarray([prompt], jnp.int32), s_ref)
    toks = np.zeros((1, 16), np.int32)
    toks[0, :L] = prompt
    s_pad = model.init_states(1, 64)
    lg_pad, s_pad, _ = model.prefill(params, jnp.asarray(toks), s_pad,
                                     length=jnp.asarray([L], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_pad),
                               atol=1e-6, rtol=1e-6)
    lg1, _ = model.decode_step(params, jnp.asarray([[3]], jnp.int32), s_ref,
                               jnp.asarray([L], jnp.int32), None)
    lg2, _ = model.decode_step(params, jnp.asarray([[3]], jnp.int32), s_pad,
                               jnp.asarray([L], jnp.int32), None)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               atol=1e-6, rtol=1e-6)


# ------------------------------------------------------- eviction and refill
def test_slot_eviction_on_max_tokens_and_refill_order():
    """More requests than slots: every request completes with exactly its
    max_new_tokens, and slots are refilled in submission order."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=3 + i % 2)
            for i in range(5)]
    done = engine.run(reqs)
    assert all(r.done for r in done)
    for r in done:
        assert len(r.generated) == r.max_new_tokens
    # admission (first-token) order == submission order
    first_times = [r.t_first_token for r in done]
    assert first_times == sorted(first_times)
    assert engine.stats.requests_completed == 5


def test_slot_eviction_on_eos():
    """When the model emits eos_id the slot is evicted immediately."""
    _, model, params = _tiny_model()
    # learn what the (untrained) model generates first for this prompt
    probe = ServeEngine(model, params, slots=1, max_len=64)
    (r0,) = probe.run([Request(rid=0, prompt=[5, 6, 7], max_new_tokens=2)])
    eos = r0.generated[0]
    engine = ServeEngine(model, params, slots=1, max_len=64)
    (r1,) = engine.run([Request(rid=1, prompt=[5, 6, 7], max_new_tokens=8,
                                eos_id=eos)])
    assert r1.done
    assert r1.generated[0] == eos and len(r1.generated) == 1


def test_interleaved_admission_budget():
    """With max_prefill_per_step=1, a 4-request burst into 4 slots admits one
    request per tick — decode work proceeds between admissions."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=4, max_len=64,
                         max_prefill_per_step=1)
    reqs = [Request(rid=i, prompt=[1 + i, 2], max_new_tokens=6)
            for i in range(4)]
    done = engine.run(reqs)
    assert all(r.done for r in done)
    # each of the 4 prefills happened on a distinct tick
    assert engine.stats.prefills == 4
    assert engine.stats.ticks >= 4
    # later arrivals decoded fewer steps before earlier ones finished, but
    # everyone still produced exactly max_new_tokens
    assert all(len(r.generated) == 6 for r in done)


# ------------------------------------------------------------ compile counts
def test_no_recompiles_after_warmup():
    """A mixed-length trace spanning 3 buckets compiles each bucket once;
    repeating the trace (same buckets, different lengths/slots) adds zero
    compile-cache entries."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=64)

    def trace(seed):
        rng = np.random.RandomState(seed)
        lens = [3, 20, 40, 9, 27, 55]           # buckets 16, 32, 64
        return [Request(rid=i, prompt=rng.randint(1, 500, n).tolist(),
                        max_new_tokens=3)
                for i, n in enumerate(lens)]

    engine.run(trace(0))
    warm_prefill = engine.stats.prefill_compiles
    warm_decode = engine.stats.decode_compiles
    assert warm_prefill == 3                     # one program per bucket
    assert warm_decode == 1                      # one decode program
    assert engine.stats.bucket_counts == {16: 2, 32: 2, 64: 2}

    engine.reset_stats()
    engine.run(trace(1))
    assert engine.stats.prefill_compiles == warm_prefill
    assert engine.stats.decode_compiles == warm_decode


# -------------------------------------------------------------------- stats
def test_engine_stats_summary():
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=4)
            for i in range(3)]
    engine.run(reqs)
    s = engine.stats.summary()
    assert s["requests_completed"] == 3
    assert s["tokens_generated"] == 12
    assert s["tokens_per_s"] > 0
    assert len(engine.stats.ttft_s) == 3
    assert s["ttft_ms"]["mean"] > 0
    assert s["decode_step_ms"] > 0
    assert 0 < s["slot_occupancy"] <= 1
    assert s["prefills"] == 3
    # prompts of 3 tokens pad to the 16-bucket
    assert s["prefill_padding_overhead"] == pytest.approx(16 / 3 - 1)
    # ttft measured per request from submit to first token
    for r in reqs:
        assert r.t_first_token >= r.t_submit
        assert r.t_done >= r.t_first_token


def test_stats_meaningful_when_driven_via_step_api():
    """Callers embedding the engine in their own event loop (submit + step,
    never run) still get nonzero wall time and tokens_per_s."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=1, max_len=32)
    engine.submit(Request(rid=0, prompt=[4, 5, 6], max_new_tokens=3))
    for _ in range(10):
        engine.step()
    s = engine.stats.summary()
    assert s["requests_completed"] == 1
    assert s["wall_time_s"] > 0
    assert s["tokens_per_s"] > 0


def test_stats_reset_keeps_compile_counts():
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=1, max_len=32)
    engine.run([Request(rid=0, prompt=[4, 5], max_new_tokens=2)])
    n = engine.stats.prefill_compiles
    engine.reset_stats()
    assert engine.stats.prefill_compiles == n
    assert engine.stats.prefills == 0 and engine.stats.ticks == 0
