"""Bucketed continuous-batching engine tests: bucket selection, padded-prefill
state splicing vs the unpadded batch-1 reference, batched same-bucket
admission vs sequential batch-1, chunked prefill vs the unchunked reference,
slot eviction/refill, EOS, dead-slot isolation, queue/stats hygiene, and the
no-recompile-after-warmup guarantee (bounded compiled-program inventory)."""
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import build_model
from repro.serve.engine import (EngineStats, Request, ServeEngine,
                                bucket_for, prefill_buckets)


def _tiny_model(arch="qwen3-0.6b", layers=2):
    cfg = reduced_config(arch)
    cfg = cfg.replace(num_layers=max(layers, len(cfg.block_pattern)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ------------------------------------------------------------------- buckets
def test_prefill_buckets_powers_of_two():
    assert prefill_buckets(64) == (16, 32, 64)
    # non-power-of-two max_len gets a final gap-covering bucket
    assert prefill_buckets(100) == (16, 32, 64, 100)
    assert prefill_buckets(16) == (16,)
    assert prefill_buckets(64, min_bucket=8) == (8, 16, 32, 64)
    with pytest.raises(ValueError):
        prefill_buckets(8, min_bucket=16)


def test_bucket_for_selects_smallest_fitting():
    buckets = (16, 32, 64)
    assert bucket_for(1, buckets) == 16
    assert bucket_for(16, buckets) == 16
    assert bucket_for(17, buckets) == 32
    assert bucket_for(64, buckets) == 64
    with pytest.raises(ValueError):
        bucket_for(65, buckets)


def test_submit_rejects_oversized_prompt():
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=32)
    with pytest.raises(ValueError):
        engine.submit(Request(rid=0, prompt=list(range(40))))
    # a max_len prompt fills the cache with no room to decode one token
    with pytest.raises(ValueError):
        engine.submit(Request(rid=1, prompt=list(range(32))))
    with pytest.raises(ValueError):
        engine.submit(Request(rid=3, prompt=[]))
    with pytest.raises(ValueError):
        engine.submit(Request(rid=4, prompt=[1, 2], max_new_tokens=0))
    # invalid sampling knobs are rejected at submit
    with pytest.raises(ValueError):
        engine.submit(Request(rid=5, prompt=[1, 2], temperature=-0.5))
    with pytest.raises(ValueError):
        engine.submit(Request(rid=6, prompt=[1, 2], top_p=0.0))
    with pytest.raises(ValueError):
        engine.submit(Request(rid=7, prompt=[1, 2], top_k=-1))
    # greedy=False no longer raises: sampling is per-request now
    ServeEngine(model, params, slots=1, max_len=32, greedy=False)
    # max_len - 1 is the longest admissible prompt
    engine.submit(Request(rid=2, prompt=list(range(31))))


def test_non_power_of_two_max_len_accepts_prompts_near_cache_size():
    """Regression: max_len=48 must not silently reject a 40-token prompt
    (the bucket list gains a final 48-wide bucket)."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=1, max_len=48)
    assert engine.buckets == (16, 32, 48)
    (req,) = engine.run([Request(rid=0, prompt=list(range(1, 41)),
                                 max_new_tokens=3)])
    assert req.done and len(req.generated) == 3


def test_gap_bucket_not_divisible_by_scan_chunk_on_recurrent_arch():
    """Regression: a 100-wide gap bucket is not a multiple of the reduced
    configs' scan_chunk=16 — the chunked linear scan must identity-pad the
    tail instead of crashing, and stay exact vs the unpadded reference."""
    _, model, params = _tiny_model("recurrentgemma-2b")
    engine = ServeEngine(model, params, slots=1, max_len=100)
    assert engine.buckets[-1] == 100
    prompt = list(range(1, 71))                   # selects the 100 bucket
    (req,) = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=3)])
    assert req.done and len(req.generated) == 3

    states = model.init_states(1, 100)
    logits, states, _ = model.prefill(
        params, jnp.asarray([prompt], jnp.int32), states)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(2):
        logits, states = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), states,
            jnp.asarray([pos], jnp.int32), None)
        toks.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    assert req.generated == toks


# ----------------------------------------------- splice vs batch-1 reference
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "recurrentgemma-2b",
                                  "falcon-mamba-7b"])
def test_bucketed_prefill_matches_unpadded_reference(arch):
    """Engine output (padded/bucketed prefill spliced into the pool) must
    reproduce the manual unpadded batch-1 prefill + decode token-for-token —
    covers the KV, RG-LRU, and SSM state families."""
    _, model, params = _tiny_model(arch)
    prompt = [5, 9, 2, 7, 11]
    n_new = 4
    engine = ServeEngine(model, params, slots=2, max_len=64)
    (req,) = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=n_new)])

    states = model.init_states(1, 64)
    logits, states, memory = model.prefill(
        params, jnp.asarray([prompt], jnp.int32), states)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, states = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), states,
            jnp.asarray([pos], jnp.int32), memory)
        toks.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    assert req.generated == toks


def test_padded_prefill_logits_and_states_exact():
    """Length-masked padded prefill is numerically identical to the unpadded
    one — logits at length-1 and the post-prefill decode logits match."""
    _, model, params = _tiny_model("recurrentgemma-2b")
    prompt = [5, 9, 2, 7, 11]
    L = len(prompt)
    s_ref = model.init_states(1, 64)
    lg_ref, s_ref, _ = model.prefill(
        params, jnp.asarray([prompt], jnp.int32), s_ref)
    toks = np.zeros((1, 16), np.int32)
    toks[0, :L] = prompt
    s_pad = model.init_states(1, 64)
    lg_pad, s_pad, _ = model.prefill(params, jnp.asarray(toks), s_pad,
                                     length=jnp.asarray([L], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_pad),
                               atol=1e-6, rtol=1e-6)
    lg1, _ = model.decode_step(params, jnp.asarray([[3]], jnp.int32), s_ref,
                               jnp.asarray([L], jnp.int32), None)
    lg2, _ = model.decode_step(params, jnp.asarray([[3]], jnp.int32), s_pad,
                               jnp.asarray([L], jnp.int32), None)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------- batched prefill
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "recurrentgemma-2b",
                                  "falcon-mamba-7b"])
def test_batched_prefill_matches_sequential(arch):
    """Same-bucket admissions stacked into one (N, bucket) prefill call must
    generate exactly what N sequential batch-1 prefills generate, with fewer
    compiled calls than requests — covers all three state families."""
    _, model, params = _tiny_model(arch)
    rng = np.random.RandomState(3)
    prompts = [rng.randint(1, 400, 4 + i).tolist() for i in range(4)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]

    batched = ServeEngine(model, params, slots=4, max_len=64,
                          max_prefill_per_step=4, max_prefill_batch=4)
    sequential = ServeEngine(model, params, slots=4, max_len=64,
                             max_prefill_per_step=1, max_prefill_batch=1)
    rb = batched.run(reqs())
    rs = sequential.run(reqs())
    assert [r.generated for r in rb] == [r.generated for r in rs]
    # all 4 prompts fit the 16-bucket: one compiled call admitted them all
    assert batched.stats.prefill_calls == 1
    assert batched.stats.prefills == 4
    assert sequential.stats.prefill_calls == 4


def test_batched_admission_splits_by_bucket_and_cap():
    """Mixed buckets admitted in one tick become one call per bucket group;
    a group larger than max_prefill_batch splits."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=6, max_len=64,
                         max_prefill_per_step=6, max_prefill_batch=2)
    lens = [3, 5, 20, 25, 7, 9]                 # buckets 16,16,32,32,16,16
    reqs = [Request(rid=i, prompt=list(range(1, n + 1)), max_new_tokens=2)
            for i, n in enumerate(lens)]
    engine.run(reqs)
    assert all(r.done for r in reqs)
    assert engine.stats.prefills == 6
    # bucket16 group of 4 splits into 2 calls of 2; bucket32 group is 1 call
    assert engine.stats.prefill_calls == 3
    assert engine.stats.batch_counts == {2: 3}


def test_batch_bucket_padding_rows_are_inert():
    """A group of 3 into batch buckets (1,2,4) pads to 4 — the padding row
    targets a real slot but is spliced first and overwritten, so outputs
    match the sequential reference exactly."""
    _, model, params = _tiny_model()
    prompts = [[7, 8, 9], [4, 5], [11, 3, 2, 6]]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]

    batched = ServeEngine(model, params, slots=4, max_len=64,
                          max_prefill_per_step=4, max_prefill_batch=4)
    rb = batched.run(reqs())
    assert batched.stats.prefill_calls == 1     # one padded (4,16) call
    ref = ServeEngine(model, params, slots=4, max_len=64,
                      max_prefill_per_step=1, max_prefill_batch=1)
    rs = ref.run(reqs())
    assert [r.generated for r in rb] == [r.generated for r in rs]


# ---------------------------------------------------------- chunked prefill
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "recurrentgemma-2b",
                                  "falcon-mamba-7b"])
def test_chunked_prefill_matches_unchunked(arch):
    """A prompt longer than the largest bucket prefills in chunk-continuation
    calls (here 16+16+13) and must generate token-for-token what a one-shot
    unchunked engine generates — KV, ring-buffer sliding-window KV, RG-LRU,
    and SSM state families all resume correctly."""
    _, model, params = _tiny_model(arch)
    prompt = np.random.RandomState(5).randint(1, 400, 45).tolist()

    chunked = ServeEngine(model, params, slots=2, max_len=128,
                          buckets=(16,), prefill_chunk=16)
    (rc,) = chunked.run([Request(rid=0, prompt=prompt, max_new_tokens=5)])
    assert chunked.stats.prefill_chunks == 3
    assert chunked.stats.prefills == 1

    unchunked = ServeEngine(model, params, slots=2, max_len=128)
    (ru,) = unchunked.run([Request(rid=0, prompt=prompt, max_new_tokens=5)])
    assert rc.done and ru.done
    assert rc.generated == ru.generated


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "recurrentgemma-2b",
                                  "falcon-mamba-7b"])
def test_prefill_offset_continuation_matches_full(arch):
    """Model-level: prefill resumed via ``offset`` (ragged final chunk,
    right-padded) reproduces the one-shot prefill — last-position logits and
    the decode continuation match to float tolerance."""
    _, model, params = _tiny_model(arch)
    prompt = np.random.RandomState(11).randint(1, 400, 40).tolist()
    L = len(prompt)

    s_ref = model.init_states(1, 64)
    lg_ref, s_ref, _ = model.prefill(
        params, jnp.asarray([prompt], jnp.int32), s_ref)

    s = model.init_states(1, 64)
    off = 0
    for piece in (prompt[0:16], prompt[16:32], prompt[32:40]):
        n = len(piece)
        toks = np.zeros((1, 16), np.int32)
        toks[0, :n] = piece
        lg, s, _ = model.prefill(params, jnp.asarray(toks), s,
                                 length=jnp.asarray([n], jnp.int32),
                                 offset=jnp.asarray([off], jnp.int32))
        off += n
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg),
                               atol=1e-6, rtol=1e-6)
    tok = int(jnp.argmax(lg_ref[0, -1]))
    lg1, _ = model.decode_step(params, jnp.asarray([[tok]], jnp.int32), s_ref,
                               jnp.asarray([L], jnp.int32), None)
    lg2, _ = model.decode_step(params, jnp.asarray([[tok]], jnp.int32), s,
                               jnp.asarray([L], jnp.int32), None)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               atol=1e-6, rtol=1e-6)


def test_chunked_prefill_interleaves_with_decode():
    """While a long prompt prefills chunk-by-chunk, an already-running short
    request keeps decoding: the chunks and decode steps share ticks, and the
    short request's output is unaffected by the concurrent chunking."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=128,
                         buckets=(16,), prefill_chunk=16,
                         max_prefill_per_step=1)
    short = Request(rid=0, prompt=[5, 6, 7], max_new_tokens=8)
    long_ = Request(rid=1,
                    prompt=np.random.RandomState(9).randint(
                        1, 400, 60).tolist(),
                    max_new_tokens=3)
    engine.run([short, long_])
    assert short.done and long_.done
    st = engine.stats
    assert st.prefill_chunks == 4               # ceil(60 / 16)
    # chunks ran on the same ticks as decode steps — a serializing engine
    # would need at least chunks + decode_steps ticks
    assert st.ticks < st.prefill_chunks + st.decode_steps
    # the short request decoded during the chunked prefill, unaffected by it
    solo = ServeEngine(model, params, slots=2, max_len=128,
                       buckets=(16,), prefill_chunk=16)
    (ref,) = solo.run([Request(rid=0, prompt=[5, 6, 7], max_new_tokens=8)])
    assert short.generated == ref.generated
    # and the long prompt's first token arrived after its chunks, not before
    assert long_.t_first_token > short.t_first_token


# ------------------------------------------------------- dead-slot isolation
def test_dead_slots_do_not_corrupt_state():
    """Regression for the dead-slot decode-write bug: while a slot sits empty
    (its neighbor still decoding), masked decode must leave it untouched so a
    request later admitted into it generates exactly what a fresh engine
    would.  Run A (long) + B (short) so B's slot is dead for several ticks,
    then admit C into the recycled slot."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=64)
    a = Request(rid=0, prompt=[3, 4, 5], max_new_tokens=9)
    b = Request(rid=1, prompt=[6, 7], max_new_tokens=2)
    engine.run([a, b])
    assert a.done and b.done
    c = Request(rid=2, prompt=[8, 9, 10], max_new_tokens=5)
    engine.run([c])

    fresh = ServeEngine(model, params, slots=2, max_len=64)
    (ref,) = fresh.run([Request(rid=2, prompt=[8, 9, 10], max_new_tokens=5)])
    assert c.generated == ref.generated


def test_decode_active_mask_freezes_state_bitwise():
    """Model-level: a decode step with active=False must leave every state
    leaf (KV contents + length, conv context, recurrent h) bit-for-bit
    unchanged, and active=True rows must match active=None bitwise."""
    for arch in ["qwen3-0.6b", "recurrentgemma-2b", "falcon-mamba-7b"]:
        _, model, params = _tiny_model(arch)
        states = model.init_states(2, 32)
        toks = jnp.asarray([[5, 9, 2], [7, 1, 4]], jnp.int32)
        _, states, _ = model.prefill(params, toks, states)
        pos = jnp.asarray([3, 3], jnp.int32)
        step = jnp.asarray([[8], [8]], jnp.int32)
        # both rows frozen: states unchanged
        _, frozen = model.decode_step(params, step, states, pos,
                                      active=jnp.asarray([False, False]))
        for a, b in zip(jax.tree.leaves(states), jax.tree.leaves(frozen)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # both rows active: bitwise identical to no mask at all
        lg_ref, s_ref = model.decode_step(params, step, states, pos)
        lg_act, s_act = model.decode_step(params, step, states, pos,
                                          active=jnp.asarray([True, True]))
        np.testing.assert_array_equal(np.asarray(lg_ref), np.asarray(lg_act))
        for a, b in zip(jax.tree.leaves(s_ref), jax.tree.leaves(s_act)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- eviction and refill
def test_slot_eviction_on_max_tokens_and_refill_order():
    """More requests than slots: every request completes with exactly its
    max_new_tokens, and slots are refilled in submission order."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=3 + i % 2)
            for i in range(5)]
    done = engine.run(reqs)
    assert all(r.done for r in done)
    for r in done:
        assert len(r.generated) == r.max_new_tokens
    # admission (first-token) order == submission order
    first_times = [r.t_first_token for r in done]
    assert first_times == sorted(first_times)
    assert engine.stats.requests_completed == 5


def test_slot_eviction_on_eos():
    """When the model emits eos_id the slot is evicted immediately."""
    _, model, params = _tiny_model()
    # learn what the (untrained) model generates first for this prompt
    probe = ServeEngine(model, params, slots=1, max_len=64)
    (r0,) = probe.run([Request(rid=0, prompt=[5, 6, 7], max_new_tokens=2)])
    eos = r0.generated[0]
    engine = ServeEngine(model, params, slots=1, max_len=64)
    (r1,) = engine.run([Request(rid=1, prompt=[5, 6, 7], max_new_tokens=8,
                                eos_id=eos)])
    assert r1.done
    assert r1.generated[0] == eos and len(r1.generated) == 1


def test_interleaved_admission_budget():
    """With max_prefill_per_step=1, a 4-request burst into 4 slots admits one
    request per tick — decode work proceeds between admissions."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=4, max_len=64,
                         max_prefill_per_step=1)
    reqs = [Request(rid=i, prompt=[1 + i, 2], max_new_tokens=6)
            for i in range(4)]
    done = engine.run(reqs)
    assert all(r.done for r in done)
    # each of the 4 prefills happened on a distinct tick
    assert engine.stats.prefills == 4
    assert engine.stats.ticks >= 4
    # later arrivals decoded fewer steps before earlier ones finished, but
    # everyone still produced exactly max_new_tokens
    assert all(len(r.generated) == 6 for r in done)


# ------------------------------------------------------------ compile counts
def test_no_recompiles_after_warmup():
    """A mixed-length trace spanning 3 buckets compiles each bucket once;
    repeating the trace (same buckets, different lengths/slots) adds zero
    compile-cache entries."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=64)

    def trace(seed):
        rng = np.random.RandomState(seed)
        lens = [3, 20, 40, 9, 27, 55]           # buckets 16, 32, 64
        return [Request(rid=i, prompt=rng.randint(1, 500, n).tolist(),
                        max_new_tokens=3)
                for i, n in enumerate(lens)]

    engine.run(trace(0))
    warm_prefill = engine.stats.prefill_compiles
    warm_decode = engine.stats.decode_compiles
    assert warm_prefill == 3                     # one program per bucket
    assert warm_decode == 1                      # one decode program
    assert engine.stats.bucket_counts == {16: 2, 32: 2, 64: 2}

    engine.reset_stats()
    engine.run(trace(1))
    assert engine.stats.prefill_compiles == warm_prefill
    assert engine.stats.decode_compiles == warm_decode


# -------------------------------------------------------------------- stats
def test_engine_stats_summary():
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=4)
            for i in range(3)]
    engine.run(reqs)
    s = engine.stats.summary()
    assert s["requests_completed"] == 3
    assert s["tokens_generated"] == 12
    assert s["tokens_per_s"] > 0
    assert engine.stats.ttft_count == 3
    assert s["ttft_ms"]["mean"] > 0
    assert s["obs"]["histograms"]["ttft_s"]["count"] == 3
    assert s["decode_step_ms"] > 0
    assert 0 < s["slot_occupancy"] <= 1
    assert s["prefills"] == 3
    # prompts of 3 tokens pad to the 16-bucket
    assert s["prefill_padding_overhead"] == pytest.approx(16 / 3 - 1)
    # ttft measured per request from submit to first token
    for r in reqs:
        assert r.t_first_token >= r.t_submit
        assert r.t_done >= r.t_first_token


def test_stats_meaningful_when_driven_via_step_api():
    """Callers embedding the engine in their own event loop (submit + step,
    never run) still get nonzero wall time and tokens_per_s."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=1, max_len=32)
    engine.submit(Request(rid=0, prompt=[4, 5, 6], max_new_tokens=3))
    for _ in range(10):
        engine.step()
    s = engine.stats.summary()
    assert s["requests_completed"] == 1
    assert s["wall_time_s"] > 0
    assert s["tokens_per_s"] > 0


def test_stats_reset_keeps_compile_counts():
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=1, max_len=32)
    engine.run([Request(rid=0, prompt=[4, 5], max_new_tokens=2)])
    n = engine.stats.prefill_compiles
    engine.reset_stats()
    assert engine.stats.prefill_compiles == n
    assert engine.stats.prefills == 0 and engine.stats.ticks == 0


def test_ttft_stats_exact_mean_max_and_bounded_memory():
    """The histogram-backed TTFT stats: mean and max stay exact no matter
    how many samples arrive (streaming aggregates next to the log2 buckets),
    memory stays fixed-size forever, and the p50 lands within one log2
    bucket of the true median."""
    st = EngineStats()
    st.record_ttft(1.0)
    st.record_ttft(3.0)
    p50 = st.summary()["ttft_ms"]["p50"]
    assert 1000.0 <= p50 <= 3000.0          # clamped to the exact envelope
    # stream a lot of samples: no growth, no aggregate drift
    st = EngineStats()
    n = 10_000
    vals = [float(i % 97) / 97.0 + (1000.0 if i == 3 else 0.0)
            for i in range(n)]
    for v in vals:
        st.record_ttft(v)
    assert st.ttft_count == n
    hist = st.metrics.histogram("ttft_s")
    assert len(hist.counts) == hist.nbuckets            # fixed-size forever
    s = st.summary()["ttft_ms"]
    assert s["mean"] == pytest.approx(1e3 * sum(vals) / n)      # exact
    assert s["max"] == pytest.approx(1e3 * max(vals))           # exact
    # p50 within one log2 bucket (factor of 2) of the true median
    true_p50 = 1e3 * float(np.median(vals))
    assert true_p50 / 2 <= s["p50"] <= true_p50 * 2


def test_queue_is_deque_and_deep_queue_admits_fifo():
    """Regression for the O(n) list.pop(0) admission queue: the queue is a
    deque, a deep backlog submits in O(1) each, and admission order is
    strictly FIFO."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=1, max_len=32)
    assert isinstance(engine._queue, deque)
    reqs = [Request(rid=i, prompt=[1 + i % 30, 2], max_new_tokens=1)
            for i in range(5000)]
    for r in reqs:
        engine.submit(r)
    assert len(engine._queue) == 5000
    # drain a few ticks: admissions come off the head in submission order
    for _ in range(3):
        engine.step()
    first_done = [r.rid for r in reqs if r.done]
    assert first_done == sorted(first_done)
    assert engine._queue[0].rid == 5000 - len(engine._queue)


def test_run_truncation_marks_aborted_and_warns_or_raises():
    """run() hitting max_steps must not silently hand back unfinished
    requests: survivors are marked, counted, and reported."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=1, max_len=64)
    reqs = [Request(rid=i, prompt=[2 + i, 3], max_new_tokens=30)
            for i in range(3)]
    with pytest.warns(RuntimeWarning, match="max_steps"):
        engine.run(reqs, max_steps=2)
    unfinished = [r for r in reqs if not r.done]
    assert unfinished and all(r.aborted for r in unfinished)
    assert engine.stats.requests_aborted == len(unfinished)
    assert engine.stats.summary()["requests_aborted"] == len(unfinished)
    # a second truncated run over the same survivors must not double-count
    with pytest.warns(RuntimeWarning, match="max_steps"):
        engine.run([], max_steps=1)
    assert engine.stats.requests_aborted == len(unfinished)
    # finishing them later clears the flag
    engine.run([], max_steps=10_000)
    assert all(r.done and not r.aborted for r in reqs)

    engine2 = ServeEngine(model, params, slots=1, max_len=64)
    with pytest.raises(RuntimeError, match="max_steps"):
        engine2.run([Request(rid=9, prompt=[5, 6], max_new_tokens=30)],
                    max_steps=1, on_truncate="raise")
    with pytest.raises(ValueError):
        engine2.run([], on_truncate="explode")


# ---------------------------------------------------------------- warmup
def test_warmup_precompiles_closed_program_inventory():
    """warmup() compiles every (batch-bucket, bucket) prefill shape plus the
    chunk and decode programs; any trace afterwards — batched admissions,
    long chunked prompts, refills — adds zero compile-cache entries."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=128,
                         buckets=(16, 32), prefill_chunk=32,
                         max_prefill_per_step=2, max_prefill_batch=2)
    engine.warmup()
    # 2 buckets x batch buckets (1, 2) + 1 chunk program
    assert engine.stats.prefill_compiles == 5
    assert engine.stats.decode_compiles == 1
    rng = np.random.RandomState(2)
    reqs = [Request(rid=i, prompt=rng.randint(1, 400, n).tolist(),
                    max_new_tokens=3)
            for i, n in enumerate([4, 9, 20, 30, 50, 100, 7, 25])]
    engine.run(reqs)
    assert all(r.done for r in reqs)
    assert engine.stats.prefill_compiles == 5    # zero recompiles
    assert engine.stats.decode_compiles == 1
    with pytest.raises(RuntimeError):            # mid-flight warmup refused
        engine.submit(Request(rid=99, prompt=[1, 2], max_new_tokens=1))
        engine.warmup()
