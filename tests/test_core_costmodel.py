"""Cost-model behaviour tests: rooflines, dataflow effects, paper §3.1 findings."""

from repro.core import (BASE_HB, EDGE_TPU, JACQUARD, PASCAL, PAVLOV, LayerKind,
                        LayerSpec, layer_cost, monolithic_cost)
from repro.edge import edge_zoo


def _lstm(hidden=2048, fin=512, T=200):
    return LayerSpec(name="l", kind=LayerKind.LSTM, in_features=fin,
                     hidden=hidden, seq_len=T)


def _conv(hw=56, cin=64, cout=64):
    return LayerSpec(name="c", kind=LayerKind.CONV2D, in_hw=hw, in_ch=cin,
                     out_ch=cout, kernel=3)


def test_lstm_baseline_is_memory_bound_and_underutilized():
    c = layer_cost(_lstm(), EDGE_TPU)
    assert c.mem_s > c.compute_s          # paper: LPDDR4 bandwidth-bound
    assert c.utilization < 0.015          # paper: <1% of peak for LSTMs


def test_lstm_base_hb_faster():
    base = layer_cost(_lstm(), EDGE_TPU)
    hb = layer_cost(_lstm(), BASE_HB)
    assert hb.latency_s < base.latency_s / 3  # 8x bandwidth helps a lot


def test_lstm_pavlov_beats_both():
    base = layer_cost(_lstm(), EDGE_TPU)
    hb = layer_cost(_lstm(), BASE_HB)
    pav = layer_cost(_lstm(), PAVLOV)
    assert pav.latency_s < hb.latency_s < base.latency_s
    # and with far less off-chip traffic for W_x (decoupled input MVMs)
    assert pav.prof.offchip_param_bytes < base.prof.offchip_param_bytes


def test_lstm_pavlov_energy_win():
    base = layer_cost(_lstm(), EDGE_TPU)
    pav = layer_cost(_lstm(), PAVLOV)
    assert pav.energy.total < base.energy.total / 3


def test_conv_compute_bound_on_baseline():
    c = layer_cost(_conv(), EDGE_TPU)
    assert c.compute_s >= c.mem_s
    assert c.utilization > 0.5            # paper: C1 layers ~82% util


def test_pascal_matches_baseline_throughput_on_conv_with_less_energy():
    base = layer_cost(_conv(), EDGE_TPU)
    pas = layer_cost(_conv(), PASCAL)
    assert pas.latency_s <= base.latency_s * 1.3
    assert pas.energy.total < base.energy.total


def test_late_conv_memory_relief_on_jacquard():
    late = LayerSpec(name="late", kind=LayerKind.CONV2D, in_hw=4, in_ch=320,
                     out_ch=480, kernel=3)
    base = layer_cost(late, EDGE_TPU)
    jac = layer_cost(late, JACQUARD)
    assert base.mem_s > base.compute_s    # C4: memory-bound on baseline
    assert jac.latency_s < base.latency_s


def test_fc_skinny_gemm_weight_streaming():
    fc = LayerSpec(name="f", kind=LayerKind.FC, in_features=1024,
                   out_features=1000)
    c = layer_cost(fc, EDGE_TPU)
    # weight-streaming mapping keeps eff_map high; the layer is DRAM-bound
    assert c.prof.eff_map > 0.5
    assert c.mem_s > c.compute_s


def test_baseline_average_utilization_matches_paper():
    """Paper: Edge TPU averages 27.3% utilization, 75.6% below peak."""
    utils = []
    for g in edge_zoo():
        sc = monolithic_cost(g, EDGE_TPU)
        utils.append(sc.throughput_flops / EDGE_TPU.peak_flops)
    avg = sum(utils) / len(utils)
    assert 0.15 <= avg <= 0.40


def test_latency_positive_and_finite():
    for g in edge_zoo():
        sc = monolithic_cost(g, EDGE_TPU)
        assert 0 < sc.latency_s < 60.0
        assert sc.energy.total > 0
