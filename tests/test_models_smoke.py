"""Per-architecture smoke tests: reduced config of the same family runs one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement), plus serving-path consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config
from repro.models import build_model

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, b=2, s=32):
    rng = np.random.RandomState(0)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.modality_tokens:
        batch["modality"] = jnp.asarray(
            rng.randn(b, cfg.modality_tokens, cfg.modality_dim), jnp.float32)
    if cfg.is_encdec:
        batch["src_embeds"] = jnp.asarray(rng.randn(b, s, cfg.d_model),
                                          jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = m.forward(params, batch["tokens"], batch.get("modality"),
                            batch.get("src_embeds"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return m.loss(p, batch)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Token-by-token decode logits must match teacher-forced forward logits."""
    cfg = reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    full_logits, _ = m.forward(params, batch["tokens"], batch.get("modality"),
                               batch.get("src_embeds"))
    if cfg.modality_tokens:
        pytest.skip("decode parity with modality prefix covered via prefill")

    states = m.init_states(b, max(2 * s, cfg.window or 0))
    prefix = s // 2
    logits_p, states, memory = m.prefill(
        params, batch["tokens"][:, :prefix], states, None,
        batch.get("src_embeds"))
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(full_logits[:, prefix - 1]),
        atol=5e-2, rtol=5e-2)
    # decode the rest one token at a time
    for t in range(prefix, s):
        tok = batch["tokens"][:, t:t + 1]
        pos = jnp.full((b,), t, jnp.int32)
        logits_d, states = m.decode_step(params, tok, states, pos, memory)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, t]),
            atol=5e-2, rtol=5e-2,
            err_msg=f"{arch}: decode@{t} != forward@{t}")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_structure(arch):
    """Full configs match the assignment table exactly."""
    cfg = get_config(arch)
    table = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
    }
    layers, d, h, kv, dff, vocab = table[arch]
    assert cfg.num_layers == layers
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == dff
    assert cfg.vocab_size == vocab


def test_moe_active_vs_total_params():
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert 38e9 < phi.param_count() < 46e9
    assert 5.5e9 < phi.param_count(active_only=True) < 8e9
    scout = get_config("llama4-scout-17b-a16e")
    assert 95e9 < scout.param_count() < 115e9
    assert 15e9 < scout.param_count(active_only=True) < 19e9


def test_sub_quadratic_flags():
    assert get_config("falcon-mamba-7b").sub_quadratic
    assert get_config("recurrentgemma-2b").sub_quadratic
    for a in ("qwen3-0.6b", "starcoder2-7b", "phi3.5-moe-42b-a6.6b",
              "seamless-m4t-medium"):
        assert not get_config(a).sub_quadratic
