"""Executor (plan -> execution profile), HLO parser, and shape-rule tests."""
import pytest

from repro.configs import SHAPES, applicable, get_config
from repro.core.executor import execution_profile, plan_for_cell
from repro.utils.hlo import parse_collectives

HLO_SAMPLE = """
ENTRY %main {
  %ag = bf16[2,4096,1024]{2,1,0} all-gather(bf16[2,256,1024] %x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={1}
  %ar = f32[2,32768,2560]{2,1,0} all-reduce(f32[2,32768,2560] %y), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = f32[16,64]{1,0} reduce-scatter(f32[256,64] %z), replica_groups={{0,1}}, dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8] %w), source_target_pairs={{0,1}}
  %dot = f32[8,8] dot(f32[8,8] %a, f32[8,8] %b)
}
"""


def test_hlo_parser_kinds_and_counts():
    st = parse_collectives(HLO_SAMPLE, default_group=256)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1}
    # all-gather: out 2*4096*1024*2 bytes * 15/16
    ag = 2 * 4096 * 1024 * 2 * 15 / 16
    assert st.wire_bytes["all-gather"] == pytest.approx(ag)
    # all-reduce group size from iota [16,16]: 2*bytes*15/16
    ar = 2 * (2 * 32768 * 2560 * 4) * 15 / 16
    assert st.wire_bytes["all-reduce"] == pytest.approx(ar)
    # reduce-scatter out bytes * (n-1), group=2
    rs = 16 * 64 * 4 * 1
    assert st.wire_bytes["reduce-scatter"] == pytest.approx(rs)
    assert st.wire_bytes["collective-permute"] == pytest.approx(8 * 8 * 2)
    assert st.total_wire_bytes > 0


def test_hlo_parser_ignores_non_collectives():
    st = parse_collectives("%d = f32[8,8] dot(f32[8,8] %a, f32[8,8] %b)")
    assert st.total_wire_bytes == 0


# ------------------------------------------------------------------- executor
def test_execution_profile_small_dense_is_dp():
    prof = execution_profile(get_config("smollm-135m"), SHAPES["train_4k"])
    assert prof.strategy == "dp"
    assert prof.cfg_overrides.get("remat") is False


def test_execution_profile_big_dense_is_tp():
    prof = execution_profile(get_config("starcoder2-7b"), SHAPES["train_4k"])
    assert prof.strategy == "tp"
    assert "remat" not in prof.cfg_overrides


def test_execution_profile_moe_uses_scatter_dispatch():
    prof = execution_profile(get_config("phi3.5-moe-42b-a6.6b"),
                             SHAPES["train_4k"])
    assert prof.strategy == "tp"
    assert prof.cfg_overrides.get("moe_impl") == "scatter"


def test_execution_profile_rglru_blockdiag():
    prof = execution_profile(get_config("recurrentgemma-2b"),
                             SHAPES["prefill_32k"])
    assert prof.cfg_overrides.get("rglru_gate_blocks") == 16
    cfg = prof.apply(get_config("recurrentgemma-2b"))
    assert cfg.rglru_gate_blocks == 16


def test_plan_for_cell_covers_all_cells():
    for arch in ("smollm-135m", "falcon-mamba-7b", "seamless-m4t-medium",
                 "llama4-scout-17b-a16e"):
        for shape in SHAPES.values():
            ok, _ = applicable(get_config(arch), shape)
            if not ok:
                continue
            p = plan_for_cell(get_config(arch), shape)
            assert p.blocks, (arch, shape.name)
            for b in p.blocks:
                assert b.strategy in b.candidates


# ----------------------------------------------------------------- shape rules
def test_long_500k_applicability_rules():
    assert applicable(get_config("falcon-mamba-7b"), SHAPES["long_500k"])[0]
    assert applicable(get_config("recurrentgemma-2b"), SHAPES["long_500k"])[0]
    for a in ("qwen3-0.6b", "starcoder2-7b", "smollm-135m", "qwen2-0.5b",
              "internvl2-2b", "phi3.5-moe-42b-a6.6b",
              "llama4-scout-17b-a16e", "seamless-m4t-medium"):
        ok, why = applicable(get_config(a), SHAPES["long_500k"])
        assert not ok and "full-attention" in why


def test_all_dryrun_artifacts_green():
    """Deliverable (e): every (arch x shape x mesh) cell is ok or an
    assignment-mandated skip."""
    import json
    from pathlib import Path
    d = Path(__file__).resolve().parent.parent / "results" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run artifacts not generated in this environment")
    recs = [json.loads(f.read_text()) for f in d.glob("*.json")]
    assert len(recs) == 80
    statuses = {r["status"] for r in recs}
    assert statuses <= {"ok", "skip"}
    assert sum(r["status"] == "skip" for r in recs) == 16
    # memory fits everywhere
    for r in recs:
        if r["status"] != "ok":
            continue
        m = r.get("memory", {})
        tot = m.get("argument_size_in_bytes", 0) + \
            m.get("peak_memory_in_bytes", 0)
        assert tot < 16 * 2**30, (r["arch"], r["shape"], r["mesh"], tot)


def test_vocab_padding_divisible():
    for a in ("internvl2-2b", "seamless-m4t-medium"):
        cfg = get_config(a)
        assert cfg.vocab_padded % 16 == 0
        assert cfg.vocab_padded >= cfg.vocab_size
