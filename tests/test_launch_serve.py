"""Serving launcher tests: the CLI flags must actually reach the engine
(regression for main() silently dropping engine knobs), and build_engine must
wire bucket caps / batching / chunking through to ServeEngine."""
import jax

from repro.configs import reduced_config
from repro.launch import serve as serve_mod
from repro.models import build_model


def test_build_engine_passes_knobs_through():
    cfg = reduced_config("qwen3-0.6b")
    cfg = cfg.replace(num_layers=len(cfg.block_pattern))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = serve_mod.build_engine(
        cfg, params, slots=3, max_len=128, max_bucket=32,
        max_prefill_per_step=2, max_prefill_batch=2, prefill_chunk=16)
    assert engine.buckets == (16, 32)           # capped below max_len
    assert engine.prefill_chunk == 16
    assert engine.max_prefill_per_step == 2
    assert engine.max_prefill_batch == 2
    assert engine.slots == 3 and engine.max_len == 128
    assert engine.kv is None                    # dense KV by default


def test_build_engine_passes_paged_kv_knobs_through():
    cfg = reduced_config("qwen3-0.6b")
    cfg = cfg.replace(num_layers=len(cfg.block_pattern))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = serve_mod.build_engine(
        cfg, params, slots=2, max_len=64, kv_block_size=16, kv_blocks=6,
        prefix_cache=False)
    assert engine.kv is not None
    assert engine.kv.block_size == 16
    assert engine.kv.pool.num_blocks == 6
    assert not engine.kv.prefix_enabled
    engine = serve_mod.build_engine(cfg, params, slots=2, max_len=64,
                                    kv_block_size=16)
    assert engine.kv.pool.num_blocks == 2 * 64 // 16   # dense equivalent
    assert engine.kv.prefix_enabled                    # pure-attention stack
    assert engine.mesh is None                         # unsharded by default


def test_build_engine_passes_mesh_through():
    from repro.launch.mesh import make_serve_mesh
    cfg = reduced_config("qwen3-0.6b")
    cfg = cfg.replace(num_layers=len(cfg.block_pattern))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_serve_mesh(1, 1)
    engine = serve_mod.build_engine(cfg, params, slots=2, max_len=64,
                                    kv_block_size=16, mesh=mesh)
    assert engine.mesh is mesh
    assert engine._state_shardings is not None
    # params actually landed on the mesh
    leaf = jax.tree.leaves(engine.params)[0]
    assert leaf.sharding.mesh.shape == mesh.shape


def test_mesh_from_args():
    args = serve_mod.parse_args([])
    assert serve_mod.mesh_from_args(args) is None      # --mesh off default
    args = serve_mod.parse_args(["--mesh", "1x1"])
    m = serve_mod.mesh_from_args(args)
    assert dict(m.shape) == {"data": 1, "model": 1}
    args = serve_mod.parse_args(["--dp", "1"])
    m = serve_mod.mesh_from_args(args)
    assert dict(m.shape) == {"data": 1, "model": 1}


def test_cli_flags_reach_engine(monkeypatch):
    """main() must forward every engine knob; the stub records what
    ServeEngine actually receives."""
    captured = {}

    class StubStats:
        def summary(self):
            return {}

    class StubEngine:
        def __init__(self, model, params, **kwargs):
            captured.update(kwargs)
            self.buckets = kwargs.get("buckets") or (16, 32)
            self.prefill_chunk = kwargs.get("prefill_chunk") or 32
            self.stats = StubStats()
            self.warmed = False

        def warmup(self):
            captured["warmed"] = True

        def run(self, reqs):
            captured["n_requests"] = len(reqs)
            captured["reqs"] = reqs
            return reqs

    monkeypatch.setattr(serve_mod, "ServeEngine", StubEngine)
    serve_mod.main(["--arch", "qwen3-0.6b", "--reduced", "--requests", "3",
                    "--slots", "2", "--max-len", "128", "--max-bucket", "32",
                    "--max-prefill-per-step", "3", "--max-prefill-batch", "2",
                    "--prefill-chunk", "16", "--long-prompts", "1",
                    "--kv-block-size", "16", "--kv-blocks", "12",
                    "--no-prefix-cache", "--temperature", "0.7",
                    "--top-k", "5", "--top-p", "0.9",
                    "--warmup"])
    assert captured["slots"] == 2
    assert captured["max_len"] == 128
    assert captured["buckets"] == (16, 32)
    assert captured["max_prefill_per_step"] == 3
    assert captured["max_prefill_batch"] == 2
    assert captured["prefill_chunk"] == 16
    assert captured["kv_block_size"] == 16
    assert captured["kv_blocks"] == 12
    assert captured["prefix_cache"] is False
    assert captured["mesh"] is None                    # --mesh off default
    assert captured["param_strategy"] == "tp"
    # default --policy auto: an oracle-resolved PlacementPlan reaches the
    # engine constructor
    assert captured["policy"] is not None
    assert captured["policy"].source == "auto"
    assert captured["warmed"] is True
    assert captured["n_requests"] == 4          # 3 short + 1 long
    # sampling knobs land on every submitted request
    assert all(r.temperature == 0.7 and r.top_k == 5 and r.top_p == 0.9
               for r in captured["reqs"])


def test_cli_policy_fixed_reaches_engine(monkeypatch):
    """--policy fixed must not resolve an oracle plan: the engine receives
    policy=None and materializes its own fixed_plan from constructor knobs."""
    captured = {}

    class StubStats:
        def summary(self):
            return {}

    class StubEngine:
        def __init__(self, model, params, **kwargs):
            captured.update(kwargs)
            self.buckets = kwargs.get("buckets") or (16, 32)
            self.prefill_chunk = 32
            self.stats = StubStats()

        def run(self, reqs):
            return reqs

    monkeypatch.setattr(serve_mod, "ServeEngine", StubEngine)
    serve_mod.main(["--arch", "qwen3-0.6b", "--reduced", "--requests", "2",
                    "--policy", "fixed"])
    assert captured["policy"] is None


def test_cli_policy_dump_smoke(capsys):
    """--policy-dump prints the resolved plan as JSON and exits before any
    engine (or model) is built."""
    import json
    serve_mod.main(["--arch", "recurrentgemma-2b", "--policy-dump",
                    "--max-len", "128", "--max-bucket", "32"])
    plan = json.loads(capsys.readouterr().out)
    assert plan["arch"] == "recurrentgemma-2b"
    assert plan["source"] == "auto"
    assert plan["policies"] and plan["buckets"] == [16, 32]
    assert set(plan["layer_kinds"]) == {"local", "rec"}
    assert {"prefill_chunk_s", "decode_step_s"} <= set(plan["predicted"])


def test_cli_defaults_parse():
    args = serve_mod.parse_args([])
    assert args.mesh == "off"                   # unsharded by default
    assert args.dp is None and args.mp is None
    assert args.param_strategy == "tp"
    assert args.max_prefill_per_step == 1
    assert args.max_prefill_batch == 4
    assert args.prefill_chunk is None
    assert args.max_bucket is None
    assert args.kv_block_size is None           # dense KV by default
    assert args.kv_blocks is None
    assert args.prefix_cache is True
    assert args.temperature == 0.0              # greedy by default
    assert args.top_k == 0
    assert args.top_p == 1.0
    assert args.policy == "auto"                # oracle placement by default
    assert args.policy_dump is False
    assert args.trace == ""                     # tracing on, buffer unsaved
    assert args.profile_dir == ""
    assert args.metrics_json == ""


def test_cli_observability_flags(monkeypatch, tmp_path):
    """--trace / --metrics-json / --profile-dir / --param-strategy auto all
    reach their targets: save_trace is called with the path, the metrics
    JSON lands on disk with the stats summary, the profiler context receives
    the directory, and the auto weight layout is forwarded to the engine."""
    import contextlib
    import json

    captured = {}

    class StubTracer:
        def __len__(self):
            return 7

        dropped = 0

    class StubStats:
        def summary(self):
            return {"requests_completed": 2,
                    "obs": {"version": 1, "counters": {}, "histograms": {}}}

    class StubEngine:
        def __init__(self, model, params, **kwargs):
            captured.update(kwargs)
            self.buckets = kwargs.get("buckets") or (16, 32)
            self.prefill_chunk = 32
            self.stats = StubStats()
            self.tracer = StubTracer()

        def run(self, reqs):
            return reqs

        def save_trace(self, path):
            captured["trace_path"] = path

    @contextlib.contextmanager
    def stub_profile(profile_dir):
        captured["profile_dir"] = profile_dir
        yield

    monkeypatch.setattr(serve_mod, "ServeEngine", StubEngine)
    monkeypatch.setattr(serve_mod, "profile_trace", stub_profile)
    metrics = tmp_path / "metrics.json"
    serve_mod.main(["--arch", "qwen3-0.6b", "--reduced", "--requests", "2",
                    "--trace", str(tmp_path / "t.json"),
                    "--metrics-json", str(metrics),
                    "--profile-dir", str(tmp_path / "prof"),
                    "--param-strategy", "auto"])
    assert captured["trace_path"] == str(tmp_path / "t.json")
    assert captured["profile_dir"] == str(tmp_path / "prof")
    assert captured["param_strategy"] == "auto"
    payload = json.loads(metrics.read_text())
    assert payload["requests_completed"] == 2
    assert payload["obs"]["version"] == 1
