"""Two-phase scheduler tests (paper §4.2) + property tests on its invariants."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (CLUSTER_TO_ACCELERATOR, JACQUARD, MENSA_ACCELERATORS,
                        PASCAL, PAVLOV, LayerKind, LayerSpec, MensaScheduler,
                        ModelGraph, characterize_model, rule_cluster,
                        schedule_cost)
from repro.edge import edge_zoo


def test_phase1_follows_cluster_map():
    g = edge_zoo()[0]
    sched = MensaScheduler()
    p1, clusters = sched.phase1(g)
    for acc, cl in zip(p1, clusters):
        assert acc.name == CLUSTER_TO_ACCELERATOR[cl].name


def test_lstm_layers_go_to_pavlov():
    g = [m for m in edge_zoo() if m.family == "lstm"][0]
    s = MensaScheduler().schedule(g)
    for spec, acc in zip(g.layers, s.mapping):
        if spec.kind is LayerKind.LSTM:
            assert acc.name == PAVLOV.name


def test_conv_heavy_layers_go_to_pascal():
    g = [m for m in edge_zoo() if m.family == "cnn"][0]
    s = MensaScheduler().schedule(g)
    pascal_flops = sum(spec.flops for spec, a in zip(g.layers, s.mapping)
                       if a.name == PASCAL.name)
    assert pascal_flops > 0.5 * g.total_flops


def test_phase2_never_worsens_total_cost():
    """Phase 2 only remaps when its local EDP heuristic improves; verify the
    global schedule cost does not regress on any zoo model."""
    sched = MensaScheduler()
    for g in edge_zoo():
        p1, _ = sched.phase1(g)
        p2, _ = sched.phase2(g, p1)
        c1 = schedule_cost(g, p1, MENSA_ACCELERATORS)
        c2 = schedule_cost(g, p2, MENSA_ACCELERATORS)
        edp1 = c1.latency_s * c1.energy.total
        edp2 = c2.latency_s * c2.energy.total
        assert edp2 <= edp1 * 1.05, f"{g.name}: phase2 regressed EDP"


def test_phase2_reduces_transfers():
    sched = MensaScheduler()
    for g in edge_zoo():
        p1, _ = sched.phase1(g)
        p2, _ = sched.phase2(g, p1)
        x1 = schedule_cost(g, p1, MENSA_ACCELERATORS).transfer_bytes
        x2 = schedule_cost(g, p2, MENSA_ACCELERATORS).transfer_bytes
        assert x2 <= x1


def test_cost_policy_schedules_every_layer():
    sched = MensaScheduler(policy="cost")
    for g in edge_zoo()[:4]:
        s = sched.schedule(g)
        assert len(s.mapping) == len(g.layers)
        assert all(a in MENSA_ACCELERATORS for a in s.mapping)


# ------------------------------------------------------------------ property
@st.composite
def random_chain(draw):
    n = draw(st.integers(min_value=2, max_value=12))
    layers = []
    for i in range(n):
        kind = draw(st.sampled_from([LayerKind.CONV2D, LayerKind.PWCONV2D,
                                     LayerKind.DWCONV2D, LayerKind.FC,
                                     LayerKind.LSTM]))
        if kind in (LayerKind.CONV2D, LayerKind.PWCONV2D, LayerKind.DWCONV2D):
            hw = draw(st.sampled_from([7, 14, 28, 56]))
            cin = draw(st.sampled_from([16, 64, 256]))
            cout = draw(st.sampled_from([16, 64, 256]))
            layers.append(LayerSpec(name=f"l{i}", kind=kind, in_hw=hw,
                                    in_ch=cin, out_ch=cout, kernel=3))
        elif kind is LayerKind.FC:
            layers.append(LayerSpec(name=f"l{i}", kind=kind,
                                    in_features=draw(st.sampled_from([256, 2048])),
                                    out_features=draw(st.sampled_from([256, 4096]))))
        else:
            layers.append(LayerSpec(name=f"l{i}", kind=kind,
                                    in_features=draw(st.sampled_from([128, 1024])),
                                    hidden=draw(st.sampled_from([128, 1024])),
                                    seq_len=draw(st.sampled_from([10, 100]))))
    return ModelGraph("rand", "cnn", layers)


@given(random_chain())
@settings(max_examples=40, deadline=None)
def test_scheduler_total_and_valid_on_random_graphs(graph):
    """Property: every layer gets exactly one accelerator from the system;
    schedule cost is finite and positive; clusters are in range."""
    sched = MensaScheduler()
    s = sched.schedule(graph)
    assert len(s.mapping) == len(graph.layers)
    assert all(a in MENSA_ACCELERATORS for a in s.mapping)
    assert all(1 <= c <= 5 for c in s.clusters)
    cost = sched.evaluate(graph)
    assert cost.latency_s > 0 and cost.energy.total > 0
    assert cost.latency_s < 1e4


@given(random_chain())
@settings(max_examples=20, deadline=None)
def test_mensa_never_catastrophically_worse_than_best_single(graph):
    """Property: the greedy two-phase schedule is never catastrophically worse
    (>4x EDP) than the best single Mensa accelerator running the whole graph.
    (The paper's algorithm is locally greedy — phase 1 ignores transfers and
    phase 2 only remaps pairwise — so small constant-factor regressions on
    adversarial graphs are possible by design.)"""
    sched = MensaScheduler(policy="cost")
    het = sched.evaluate(graph)
    best = min(
        (schedule_cost(graph, [a] * len(graph.layers), MENSA_ACCELERATORS)
         for a in MENSA_ACCELERATORS),
        key=lambda c: c.latency_s * c.energy.total)
    assert het.latency_s * het.energy.total <= 4.0 * best.latency_s * best.energy.total
