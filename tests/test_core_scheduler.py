"""Two-phase scheduler tests (paper §4.2) + randomized-graph invariant tests.

The randomized sweeps were originally hypothesis property tests; they now run
as seeded ``pytest.mark.parametrize`` cases so the suite collects and runs
offline with stdlib + jax only (see tests/conftest.py)."""
import random

import pytest

from repro.core import (CLUSTER_TO_ACCELERATOR, MENSA_ACCELERATORS,
                        PASCAL, PAVLOV, LayerKind, LayerSpec, MensaScheduler,
                        ModelGraph, schedule_cost)
from repro.edge import edge_zoo


def test_phase1_follows_cluster_map():
    g = edge_zoo()[0]
    sched = MensaScheduler()
    p1, clusters = sched.phase1(g)
    for acc, cl in zip(p1, clusters):
        assert acc.name == CLUSTER_TO_ACCELERATOR[cl].name


def test_lstm_layers_go_to_pavlov():
    g = [m for m in edge_zoo() if m.family == "lstm"][0]
    s = MensaScheduler().schedule(g)
    for spec, acc in zip(g.layers, s.mapping):
        if spec.kind is LayerKind.LSTM:
            assert acc.name == PAVLOV.name


def test_conv_heavy_layers_go_to_pascal():
    g = [m for m in edge_zoo() if m.family == "cnn"][0]
    s = MensaScheduler().schedule(g)
    pascal_flops = sum(spec.flops for spec, a in zip(g.layers, s.mapping)
                       if a.name == PASCAL.name)
    assert pascal_flops > 0.5 * g.total_flops


def test_phase2_never_worsens_total_cost():
    """Phase 2 only remaps when its local EDP heuristic improves; verify the
    global schedule cost does not regress on any zoo model."""
    sched = MensaScheduler()
    for g in edge_zoo():
        p1, _ = sched.phase1(g)
        p2, _ = sched.phase2(g, p1)
        c1 = schedule_cost(g, p1, MENSA_ACCELERATORS)
        c2 = schedule_cost(g, p2, MENSA_ACCELERATORS)
        edp1 = c1.latency_s * c1.energy.total
        edp2 = c2.latency_s * c2.energy.total
        assert edp2 <= edp1 * 1.05, f"{g.name}: phase2 regressed EDP"


def test_phase2_reduces_transfers():
    sched = MensaScheduler()
    for g in edge_zoo():
        p1, _ = sched.phase1(g)
        p2, _ = sched.phase2(g, p1)
        x1 = schedule_cost(g, p1, MENSA_ACCELERATORS).transfer_bytes
        x2 = schedule_cost(g, p2, MENSA_ACCELERATORS).transfer_bytes
        assert x2 <= x1


def test_phase2_diamond_aggregates_all_in_edges():
    """Regression: a diamond DAG (A -> B, A -> C, B -> D, C -> D) must decide
    D's placement from *both* in-edges at once.  The old per-edge greedy loop
    could flip D twice (once per edge), pricing each move as if the other
    in-edge were free."""
    conv = dict(kind=LayerKind.CONV2D, in_hw=28, in_ch=64, out_ch=64, kernel=3)
    lstm = dict(kind=LayerKind.LSTM, in_features=512, hidden=512, seq_len=50)
    g = ModelGraph("diamond", "rcnn", [
        LayerSpec(name="A", **conv),
        LayerSpec(name="B", **lstm),
        LayerSpec(name="C", **conv),
        LayerSpec(name="D", **lstm),
    ], edges=[(0, 1), (0, 2), (1, 3), (2, 3)])
    g.validate()
    sched = MensaScheduler()
    p1, _ = sched.phase1(g)
    p2, moved = sched.phase2(g, p1)
    assert len(p2) == 4 and all(a in MENSA_ACCELERATORS for a in p2)
    # the remap must never worsen the global schedule EDP
    c1 = schedule_cost(g, p1, MENSA_ACCELERATORS)
    c2 = schedule_cost(g, p2, MENSA_ACCELERATORS)
    assert c2.latency_s * c2.energy.total \
        <= c1.latency_s * c1.energy.total * 1.05


def test_phase2_considers_every_candidate_once_per_node():
    """With many predecessors on one accelerator and one on another, the
    decision for the join node must price transfers over ALL in-edges for
    each candidate — build a case where moving to the majority accelerator
    wins and check phase 2 lands there deterministically."""
    lstm = dict(kind=LayerKind.LSTM, in_features=256, hidden=256, seq_len=20)
    # three LSTM preds (Pavlov) feeding a small FC join
    g = ModelGraph("join", "transducer", [
        LayerSpec(name="p0", **lstm),
        LayerSpec(name="p1", **lstm),
        LayerSpec(name="p2", **lstm),
        LayerSpec(name="join", kind=LayerKind.FC, in_features=256,
                  out_features=256),
    ], edges=[(0, 3), (1, 3), (2, 3)])
    g.validate()
    sched = MensaScheduler()
    p1, _ = sched.phase1(g)
    p2, _ = sched.phase2(g, p1)
    # running phase 2 twice is a fixed point (the old per-edge loop could
    # keep flipping the join node between accelerators)
    p3, moved_again = sched.phase2(g, p2)
    assert [a.name for a in p3] == [a.name for a in p2]
    assert moved_again == 0


def test_cost_policy_schedules_every_layer():
    sched = MensaScheduler(policy="cost")
    for g in edge_zoo()[:4]:
        s = sched.schedule(g)
        assert len(s.mapping) == len(g.layers)
        assert all(a in MENSA_ACCELERATORS for a in s.mapping)


# --------------------------------------------------------- randomized graphs
def random_chain(seed: int) -> ModelGraph:
    rng = random.Random(seed)
    n = rng.randint(2, 12)
    layers = []
    for i in range(n):
        kind = rng.choice([LayerKind.CONV2D, LayerKind.PWCONV2D,
                           LayerKind.DWCONV2D, LayerKind.FC, LayerKind.LSTM])
        if kind in (LayerKind.CONV2D, LayerKind.PWCONV2D, LayerKind.DWCONV2D):
            layers.append(LayerSpec(name=f"l{i}", kind=kind,
                                    in_hw=rng.choice([7, 14, 28, 56]),
                                    in_ch=rng.choice([16, 64, 256]),
                                    out_ch=rng.choice([16, 64, 256]),
                                    kernel=3))
        elif kind is LayerKind.FC:
            layers.append(LayerSpec(name=f"l{i}", kind=kind,
                                    in_features=rng.choice([256, 2048]),
                                    out_features=rng.choice([256, 4096])))
        else:
            layers.append(LayerSpec(name=f"l{i}", kind=kind,
                                    in_features=rng.choice([128, 1024]),
                                    hidden=rng.choice([128, 1024]),
                                    seq_len=rng.choice([10, 100])))
    return ModelGraph("rand", "cnn", layers)


@pytest.mark.parametrize("seed", range(40))
def test_scheduler_total_and_valid_on_random_graphs(seed):
    """Every layer gets exactly one accelerator from the system; schedule
    cost is finite and positive; clusters are in range."""
    graph = random_chain(seed)
    sched = MensaScheduler()
    s = sched.schedule(graph)
    assert len(s.mapping) == len(graph.layers)
    assert all(a in MENSA_ACCELERATORS for a in s.mapping)
    assert all(1 <= c <= 5 for c in s.clusters)
    cost = sched.evaluate(graph)
    assert cost.latency_s > 0 and cost.energy.total > 0
    assert cost.latency_s < 1e4


@pytest.mark.parametrize("seed", range(100, 120))
def test_mensa_never_catastrophically_worse_than_best_single(seed):
    """The greedy two-phase schedule is never catastrophically worse (>4x
    EDP) than the best single Mensa accelerator running the whole graph.
    (The paper's algorithm is locally greedy — phase 1 ignores transfers and
    phase 2 only remaps per join node — so small constant-factor regressions
    on adversarial graphs are possible by design.)"""
    graph = random_chain(seed)
    sched = MensaScheduler(policy="cost")
    het = sched.evaluate(graph)
    best = min(
        (schedule_cost(graph, [a] * len(graph.layers), MENSA_ACCELERATORS)
         for a in MENSA_ACCELERATORS),
        key=lambda c: c.latency_s * c.energy.total)
    assert het.latency_s * het.energy.total \
        <= 4.0 * best.latency_s * best.energy.total
