"""Observability-layer tests: tracer ring/export semantics, histogram math,
Timed sync discipline, drift arithmetic, and the engine's trace schema —
valid Chrome trace-event JSON with per-track monotonic timestamps, nested
request spans, stable request ids across the lifecycle, and deterministic
event sequences under a fixed seed, across all three state families."""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import build_model
from repro.obs import (LEDGER_SCHEMA_VERSION, OBS_SCHEMA_VERSION,
                       PROGRAMS_SCHEMA_VERSION, Counter, Gauge, Histogram,
                       MetricsRegistry, ProgramRegistry, Timed, Tracer,
                       append_record, read_ledger, trend_check)
from repro.obs import ledger as ledger_mod
from repro.obs.drift import (PHASES, drift_report, geomean, plan_predictions,
                             residual_factor)
from repro.serve.engine import Request, ServeEngine

ARCHS = ("qwen3-0.6b", "recurrentgemma-2b", "falcon-mamba-7b")


def _tiny_model(arch="qwen3-0.6b", layers=2):
    cfg = reduced_config(arch)
    cfg = cfg.replace(num_layers=max(layers, len(cfg.block_pattern)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _trace(cfg, n=4, max_new=3, seed=5):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, 3 + 5 * i).tolist(),
                    max_new_tokens=max_new)
            for i in range(n)]


# ------------------------------------------------------------------- tracer
def test_tracer_ring_overflow_drops_oldest():
    tr = Tracer(capacity=4)
    for i in range(7):
        tr.instant(f"e{i}", 0, float(i))
    assert len(tr) == 4
    assert tr.dropped == 3
    names = [e[1] for e in tr.events()]
    assert names == ["e3", "e4", "e5", "e6"]     # oldest three fell out
    doc = tr.to_chrome()
    assert doc["otherData"]["dropped_events"] == 3
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_disabled_emits_nothing():
    tr = Tracer(enabled=False)
    tr.span("s", 0, 0.0, 1.0)
    tr.counter("c", 0.0, (("a", 1),))
    assert len(tr) == 0
    doc = tr.to_chrome()
    # still a valid (empty) document: process metadata only
    assert all(e["ph"] == "M" for e in doc["traceEvents"])


def test_tracer_chrome_export_shape():
    tr = Tracer()
    tr.set_track(1, "slot 0")
    t0 = tr.now()
    tr.begin("req 7", 1, t0, (("rid", 7),))
    tr.span("prefill", 1, t0 + 0.001, t0 + 0.002, (("rid", 7),))
    tr.counter("queue_depth", t0 + 0.001, (("queued", 3),))
    tr.end("req 7", 1, t0 + 0.003, (("rid", 7), ("tokens", 4)))
    doc = json.loads(tr.dumps(other_data={"extra": 1}))
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["extra"] == 1
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["name"] for m in meta} == {
        "process_name", "thread_name", "thread_sort_index"}
    x = next(e for e in evs if e["ph"] == "X")
    assert x["dur"] == pytest.approx(1000.0)     # 1 ms in microseconds
    assert x["args"]["rid"] == 7
    inst = [e for e in evs if e["ph"] == "i"]
    assert all(e["s"] == "t" for e in inst) or not inst
    c = next(e for e in evs if e["ph"] == "C")
    assert c["args"] == {"queued": 3}
    # B/E pair well ordered
    b = next(e for e in evs if e["ph"] == "B")
    e_ = next(e for e in evs if e["ph"] == "E")
    assert b["ts"] <= e_["ts"] and e_["args"]["tokens"] == 4


def test_tracer_export_sorted_even_with_late_spans():
    """X spans are emitted at t1 but stamped at t0 — export must re-sort so
    every track reads monotonically."""
    tr = Tracer()
    tr.instant("late", 0, 10.0)
    tr.span("early", 0, 1.0, 2.0)      # emitted after, starts before
    ts = [e[3] for e in tr.events()]
    assert ts == sorted(ts)


# ------------------------------------------------------------------ metrics
def test_histogram_bucket_edges_and_quantiles():
    h = Histogram("lat", base=1.0, nbuckets=8, unit="s")
    # bucket 0: below base; bucket i: [2**(i-1), 2**i)
    assert h.bucket_of(0.5) == 0
    assert h.bucket_of(1.0) == 1
    assert h.bucket_of(1.99) == 1
    assert h.bucket_of(2.0) == 2
    assert h.bucket_of(2.0 ** 30) == 7          # clamped to last bucket
    for v in (1.0, 1.5, 3.0, 3.5):
        h.record(v)
    assert h.count == 4
    assert h.mean == pytest.approx(9.0 / 4)
    assert h.min == 1.0 and h.max == 3.5
    # quantiles clamp to the exact envelope
    assert h.quantile(0.0) == 1.0
    assert h.quantile(1.0) == 3.5
    assert 1.0 <= h.quantile(0.5) <= 3.5
    d = h.to_dict()
    assert d["count"] == 4 and d["buckets"] == {"1": 2, "2": 2}
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        Histogram("bad", base=0.0)


def test_registry_get_or_create_and_versioned_dict():
    reg = MetricsRegistry()
    reg.counter("waste", unit="tokens").inc(3)
    reg.counter("waste").inc(2)
    reg.histogram("ttft_s").record(0.5)
    assert reg.counter("waste").value == 5
    d = reg.to_dict()
    assert d["version"] == OBS_SCHEMA_VERSION
    assert d["counters"]["waste"] == {"unit": "tokens", "value": 5}
    assert d["histograms"]["ttft_s"]["count"] == 1


def test_counter_basics():
    c = Counter("n", unit="x")
    c.inc()
    c.inc(4)
    assert c.to_dict() == {"unit": "x", "value": 5}


# ------------------------------------------------------------------- timing
def _double(v):
    return v * 2


def _incr(v):
    return v + 1


_jit_double = jax.jit(_double)
_jit_incr = jax.jit(_incr)


def test_timed_syncs_device_work_before_stamping():
    x = _jit_double(np.arange(8.0))
    with Timed("section") as tm:
        out = tm.sync(_jit_incr(x))
    assert tm.synced
    assert tm.dur >= 0.0 and tm.t1 >= tm.t0
    np.testing.assert_array_equal(np.asarray(out), np.arange(8.0) * 2 + 1)


def test_timed_unsynced_section_is_visible():
    with Timed("section") as tm:
        pass
    assert not tm.synced


# -------------------------------------------------------------------- drift
def test_drift_arithmetic():
    assert geomean([2.0, 8.0]) == pytest.approx(4.0)
    with pytest.raises(ValueError):
        geomean([])
    assert residual_factor(4.0, 4.0) == pytest.approx(1.0)
    assert residual_factor(8.0, 4.0) == pytest.approx(2.0)
    assert residual_factor(2.0, 4.0) == pytest.approx(2.0)   # symmetric
    rep = drift_report({"decode_step_s": 1e-3}, {"decode_step_s": 2e-3})
    ph = rep["phases"]["decode_step_s"]
    assert ph["ratio"] == pytest.approx(2.0)
    assert rep["max_residual_factor"] >= 1.0
    assert drift_report({}, {}) == {}


def test_engine_drift_section_uses_shared_arithmetic():
    from repro.launch.serve import build_engine
    cfg, _, params = _tiny_model()
    engine = build_engine(cfg, params, slots=2, max_len=64, max_bucket=32,
                          policy="auto")
    engine.run(_trace(cfg))
    p = engine.stats.summary()["placement"]
    drift = p["drift"]
    assert set(drift["phases"]) == set(PHASES)
    for ph, rec in drift["phases"].items():
        assert rec["predicted"] == plan_predictions(p)[ph]
        assert rec["residual_factor"] == pytest.approx(
            residual_factor(rec["ratio"], 1.0))


# ----------------------------------------------------------- engine schema
def _run_traced(arch, seed=5, enabled=True, max_new=3):
    cfg, model, params = _tiny_model(arch)
    tracer = Tracer(enabled=enabled)
    engine = ServeEngine(model, params, slots=2, max_len=64, buckets=(8, 16),
                         prefill_chunk=8, tracer=tracer)
    engine.run(_trace(cfg, seed=seed, max_new=max_new))
    return engine


def _by_track(events):
    tracks: dict = {}
    for e in events:
        if e["ph"] != "M":
            tracks.setdefault(e["tid"], []).append(e)
    return tracks


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_trace_schema(arch):
    engine = _run_traced(arch)
    doc = json.loads(engine.tracer.dumps())
    evs = doc["traceEvents"]

    # track metadata covers every tid in use
    named = {e["tid"] for e in evs if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert {e["tid"] for e in evs} <= named
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"requests", "slot 0", "slot 1", "engine"} <= names

    # per-track monotonic timestamps
    for tid, track in _by_track(evs).items():
        ts = [e["ts"] for e in track]
        assert ts == sorted(ts), f"track {tid} not monotonic"

    # request spans nest: balanced B/E per slot track, E follows its B
    for tid, track in _by_track(evs).items():
        depth = 0
        for e in track:
            if e["ph"] == "B":
                depth += 1
                assert depth == 1       # one request resident per slot
            elif e["ph"] == "E":
                depth -= 1
                assert depth >= 0
        assert depth == 0

    # stable rids: every request's submit instant, B span, and E span agree,
    # and every per-request event carries the rid
    rids = {e["args"]["rid"] for e in evs
            if e["ph"] == "i" and e["name"] == "submit"}
    assert rids == {0, 1, 2, 3}
    for rid in rids:
        b = [e for e in evs if e["ph"] == "B" and e["args"]["rid"] == rid]
        e_ = [e for e in evs if e["ph"] == "E" and e["args"]["rid"] == rid]
        assert len(b) == 1 and len(e_) == 1
        assert b[0]["name"] == e_[0]["name"] == f"req {rid}"
        assert b[0]["tid"] == e_[0]["tid"]       # resident on one slot track
        assert b[0]["ts"] <= e_[0]["ts"]

    # counters sampled every tick, with the engine's full series vocabulary
    counters = {e["name"]: e for e in evs if e["ph"] == "C"}
    assert {"queue_depth", "slots"} <= set(counters)
    assert set(counters["slots"]["args"]) == {"busy", "free"}

    # decode spans live on the engine track
    decode = [e for e in evs if e["ph"] == "X" and e["name"] == "decode"]
    assert decode and len({e["tid"] for e in decode}) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_trace_deterministic_under_seed(arch):
    """Same seed -> same event sequence (names, tracks, args) modulo
    timestamps and durations."""
    def shape(engine):
        # args keyed *_s are wall-clock durations — timing, not structure
        return [(ph, name, tid,
                 tuple((k, v) for k, v in args if not k.endswith("_s")))
                for ph, name, tid, ts, dur, args in engine.tracer.events()]
    a = shape(_run_traced(arch, seed=9))
    b = shape(_run_traced(arch, seed=9))
    assert a == b


def test_engine_trace_disabled_and_empty_paths():
    engine = _run_traced("qwen3-0.6b", enabled=False)
    assert len(engine.tracer) == 0
    doc = json.loads(engine.tracer.dumps())      # still valid JSON
    assert all(e["ph"] == "M" for e in doc["traceEvents"])
    # stats stay fully populated with the tracer off
    s = engine.stats.summary()
    assert s["requests_completed"] == 4
    assert s["obs"]["histograms"]["ttft_s"]["count"] == 4

    # engine with no work: empty but well-formed trace, zero-valued obs
    cfg, model, params = _tiny_model()
    idle = ServeEngine(model, params, slots=1, max_len=32)
    json.loads(idle.tracer.dumps())
    assert idle.stats.summary()["obs"]["version"] == OBS_SCHEMA_VERSION


def test_engine_trace_stall_and_save(tmp_path):
    """A pool-starved engine emits stall instants; save_trace round-trips
    through disk with the obs summary attached."""
    cfg, model, params = _tiny_model()
    # pool of 5 blocks: two 14-token prompts hold 2 blocks each, the first
    # boundary crossing takes the last free block for slot 0 and stalls
    # slot 1 (its neighbours' blocks are referenced, so nothing is evictable)
    # until the short request retires
    engine = ServeEngine(model, params, slots=2, max_len=40, buckets=(16,),
                         kv_block_size=8, kv_blocks=5)
    rng = np.random.RandomState(2)
    prompts = [rng.randint(1, cfg.vocab_size, 14).tolist() for _ in range(2)]
    engine.run([Request(rid=0, prompt=prompts[0], max_new_tokens=6),
                Request(rid=1, prompt=prompts[1], max_new_tokens=18)])
    assert engine.stats.summary()["kv"]["decode_stalls"] > 0
    stalls = [e for e in engine.tracer.events() if e[0] == "i"
              and e[1] == "stall"]
    assert stalls and all(dict(e[5])["rid"] == 1 for e in stalls)
    out = tmp_path / "trace.json"
    engine.save_trace(out)
    doc = json.loads(out.read_text())
    assert doc["otherData"]["obs"]["version"] == OBS_SCHEMA_VERSION
    assert any(e["ph"] == "i" and e["name"] == "stall"
               for e in doc["traceEvents"])


def test_engine_prefill_waste_counter():
    cfg, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=32, buckets=(16,))
    engine.run([Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2)])
    obs = engine.stats.summary()["obs"]
    # 3-token prompt padded to the 16 bucket: 13 wasted positions
    assert obs["counters"]["prefill_waste_tokens"]["value"] == 13
    assert obs["histograms"]["decode_tick_s"]["count"] == \
        engine.stats.decode_steps
    assert obs["histograms"]["tokens_per_tick"]["count"] == \
        engine.stats.decode_steps


# ------------------------------------------------------------------- gauges
def test_gauge_last_write_wins_and_registry_section():
    g = Gauge("pool", unit="bytes")
    g.set(100)
    g.set(42.5)
    assert g.to_dict() == {"unit": "bytes", "value": 42.5}
    reg = MetricsRegistry()
    reg.gauge("kv_pool_bytes", "bytes").set(4096)
    assert reg.gauge("kv_pool_bytes").value == 4096  # get-or-create
    d = reg.to_dict()
    assert d["version"] == OBS_SCHEMA_VERSION >= 2  # v2 added gauges
    assert d["gauges"]["kv_pool_bytes"] == {"unit": "bytes", "value": 4096.0}


# --------------------------------------------------------------- prometheus
def test_prometheus_exposition_counters_and_gauges():
    reg = MetricsRegistry()
    reg.counter("prefill_waste_tokens", "tokens").inc(13)
    reg.gauge("kv_pool_bytes", "bytes").set(6144)
    text = reg.to_prometheus()
    lines = text.splitlines()
    assert text.endswith("\n")
    assert "# TYPE repro_serve_prefill_waste_tokens_total counter" in lines
    assert "repro_serve_prefill_waste_tokens_total 13" in lines
    assert "# TYPE repro_serve_kv_pool_bytes gauge" in lines
    assert "repro_serve_kv_pool_bytes 6144" in lines
    # HELP lines carry the unit
    assert "# HELP repro_serve_kv_pool_bytes (bytes)" in lines


def test_prometheus_exposition_histogram_cumulative_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("tick", base=1.0, nbuckets=8, unit="s")
    for v in (0.5, 1.5, 1.7, 3.0):
        h.record(v)
    lines = reg.to_prometheus(prefix="x").splitlines()
    buckets = [ln for ln in lines if ln.startswith("x_tick_bucket")]
    # bucket 0 (le=1): 1 sample; bucket 1 (le=2): +2; bucket 2 (le=4): +1
    assert buckets == ['x_tick_bucket{le="1"} 1',
                       'x_tick_bucket{le="2"} 3',
                       'x_tick_bucket{le="4"} 4',
                       'x_tick_bucket{le="+Inf"} 4']
    assert "x_tick_sum 6.7" in lines
    assert "x_tick_count 4" in lines
    assert "# TYPE x_tick histogram" in lines


def test_prometheus_name_sanitization():
    reg = MetricsRegistry()
    reg.counter("kv.blocks-copied", "blocks").inc(1)
    text = reg.to_prometheus()
    assert "repro_serve_kv_blocks_copied_total 1" in text
    # empty prefix + leading digit gets a guard underscore
    reg2 = MetricsRegistry()
    reg2.gauge("2fast").set(1)
    assert "_2fast 1" in reg2.to_prometheus(prefix="")


# ----------------------------------------------------- memory normalization
def test_normalize_memory_analysis_shapes():
    from repro.utils.hlo import normalize_memory_analysis

    class Stats:                       # the CompiledMemoryStats shape
        temp_size_in_bytes = 100
        argument_size_in_bytes = 30
        output_size_in_bytes = 8
        generated_code_size_in_bytes = 7

    assert normalize_memory_analysis(None) == {}
    one = normalize_memory_analysis(Stats())
    assert one["temp_size_in_bytes"] == 100
    assert one["argument_size_in_bytes"] == 30
    # per-program lists sum; dict entries read the same keys; None entries
    # are skipped
    many = normalize_memory_analysis(
        [Stats(), {"temp_size_in_bytes": 11, "peak_memory_in_bytes": 5},
         None])
    assert many["temp_size_in_bytes"] == 111
    assert many["peak_memory_in_bytes"] == 5
    assert many["output_size_in_bytes"] == 8


# ----------------------------------------------------------- program registry
def jnp_ones(shape):
    return jax.numpy.ones(shape, jax.numpy.float32)


def _mm(a, b):
    return a @ b


def _sq_sum(a):
    return (a @ a.T).sum()


def test_program_registry_static_cost_and_observe():
    reg = ProgramRegistry()
    fn = jax.jit(_mm)
    args = (jnp_ones((8, 16)), jnp_ones((16, 4)))
    e = reg.register("matmul", fn, args, phase="prefill", program="_prefill")
    assert e.analyzed and e.flops > 0 and e.bytes_accessed > 0
    assert e.arithmetic_intensity == pytest.approx(
        e.flops / e.bytes_accessed)
    assert e.invocations == 0 and e.measured_s == 0.0
    reg.observe("matmul", 0.25)
    reg.observe("matmul", 0.25)
    s = reg.summary()
    assert s["version"] == PROGRAMS_SCHEMA_VERSION
    p = s["programs"]["matmul"]
    assert p["invocations"] == 2 and p["measured_s"] == pytest.approx(0.5)
    assert p["flops_per_s"] == pytest.approx(2 * e.flops / 0.5)
    assert p["utilization"] == pytest.approx(
        p["flops_per_s"] / s["chip"]["peak_flops"])
    assert p["bandwidth_utilization"] == pytest.approx(
        p["bytes_per_s"] / s["chip"]["hbm_bw"])
    # reset_observed zeroes the dynamic side, keeps the static cost
    reg.reset_observed()
    p2 = reg.summary()["programs"]["matmul"]
    assert p2["invocations"] == 0 and p2["measured_s"] == 0.0
    assert p2["flops"] == p["flops"] and p2["analyzed"]


def test_program_registry_memory_watermarks():
    reg = ProgramRegistry()
    fn = jax.jit(_sq_sum)
    args = (jnp_ones((16, 16)),)
    e = reg.register("m", fn, args, phase="decode", memory=True)
    assert e.memory, "memory=True should AOT-compile for memory_analysis"
    assert e.memory.get("argument_size_in_bytes", 0) > 0
    assert reg.temp_bytes_peak() == e.memory.get("temp_size_in_bytes", 0)
    assert reg.summary()["programs"]["m"]["memory"] == e.memory


def test_program_registry_never_raises_into_serving():
    reg = ProgramRegistry()
    e = reg.register("broken", object(), (), phase="decode")
    assert not e.analyzed and e.flops == 0.0
    # un-analyzed entries still accumulate observations (graceful path for
    # engines that never warmed up)
    reg.observe("never_registered", 0.1, phase="decode", program="_decode")
    s = reg.summary()
    assert s["programs"]["never_registered"]["invocations"] == 1
    assert not s["programs"]["never_registered"]["analyzed"]


def test_program_registry_cluster_rollup_attribution():
    from repro.core.accelerators import by_name
    plan = {"policies": [
        {"cluster": 2, "kinds": ["attention"], "accelerator": "pascal",
         "predicted_prefill_s": 0.03, "predicted_decode_s": 0.001},
        {"cluster": 3, "kinds": ["ffn"], "accelerator": "pavlov",
         "predicted_prefill_s": 0.01, "predicted_decode_s": 0.003},
    ]}
    reg = ProgramRegistry(plan_summary=plan)
    fn = jax.jit(_mm)
    reg.register("prefill[1x16]", fn, (jnp_ones((16, 32)), jnp_ones((32, 8))),
                 phase="prefill", program="_prefill")
    reg.register("decode", fn, (jnp_ones((4, 32)), jnp_ones((32, 8))),
                 phase="decode", program="_decode")
    reg.observe("prefill[1x16]", 0.08, phase="prefill")
    reg.observe("decode", 0.02, phase="decode")
    roll = reg.cluster_rollup()
    assert set(roll) == {"2", "3"}
    # predicted shares: prefill 3:1, decode 1:3 — measured time splits along
    # them and sums back to the phase totals
    assert roll["2"]["prefill"]["share"] == pytest.approx(0.75)
    assert roll["3"]["prefill"]["share"] == pytest.approx(0.25)
    assert roll["2"]["prefill"]["measured_s"] \
        + roll["3"]["prefill"]["measured_s"] == pytest.approx(0.08)
    assert roll["2"]["decode"]["share"] == pytest.approx(0.25)
    # ratio is measured/predicted per cluster; uniform within a phase by
    # construction (documented attribution limit)
    assert roll["2"]["prefill"]["ratio"] == pytest.approx(
        roll["3"]["prefill"]["ratio"])
    # utilization divides by the policy's own Mensa accelerator peak
    c2 = roll["2"]["prefill"]
    assert c2["utilization"] == pytest.approx(
        c2["flops_per_s"] / by_name("pascal").peak_flops)
    assert roll["2"]["accelerator"] == "pascal"
    # no plan -> no rollup -> no clusters key in the summary
    assert ProgramRegistry().cluster_rollup() == {}
    assert "clusters" not in ProgramRegistry().summary()


def test_engine_programs_cover_warmed_inventory_vs_jl006():
    """The acceptance cross-check: the cost observatory's coverage equals
    the static JL006 compile inventory — every ``self.X = jax.jit(...)`` in
    ``ServeEngine.__init__`` (the rule's definition of a program) appears as
    the ``program`` owner of at least one registered entry, with full static
    cost, and the runtime-expected name set matches exactly."""
    import ast
    import inspect

    from repro.analysis.rules.compile_inventory import _jit_value
    from repro.serve import engine as engine_mod

    tree = ast.parse(inspect.getsource(engine_mod))
    cls = next(n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)
               and n.name == "ServeEngine")
    init = next(n for n in cls.body if isinstance(n, ast.FunctionDef)
                and n.name == "__init__")
    jl006 = set()
    for stmt in ast.walk(init):
        if isinstance(stmt, ast.Assign) and _jit_value(stmt.value) \
                and isinstance(stmt.targets[0], ast.Attribute):
            jl006.add(stmt.targets[0].attr)
    assert jl006 == {"_prefill", "_chunk", "_copy", "_decode",
                     "_export", "_import"}

    cfg, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=64, buckets=(16,),
                         kv_block_size=8, program_memory=True)
    engine.warmup()
    progs = engine.stats.summary()["programs"]["programs"]
    expected = {f"prefill[{nb}x{b}]" for b in engine.buckets
                for nb in engine.batch_buckets}
    expected |= {"chunk", "copy", "decode"}   # paged + beyond-bucket prompts
    assert set(progs) == expected
    # the handoff pair is role-gated to None on an interleaved engine; every
    # other JL006 inventory entry owns at least one registered program
    assert {p["program"] for p in progs.values()} \
        == jl006 - {"_export", "_import"}
    for name, p in progs.items():
        assert p["analyzed"], name
        assert p["flops"] > 0 and p["bytes_accessed"] > 0, name
        assert p["memory"].get("argument_size_in_bytes", 0) > 0, name
    assert engine.stats.summary()["programs"].get("temp_bytes_peak", 0) > 0

    # a role-split pair warms (and registers) each side of the handoff,
    # completing 100% coverage of the JL006 inventory
    role_progs = {}
    for role in ("prefill", "decode"):
        e = ServeEngine(model, params, slots=2, max_len=64, buckets=(16,),
                        kv_block_size=8, program_memory=True, role=role)
        e.warmup()
        role_progs[role] = e.stats.summary()["programs"]["programs"]
    exp = role_progs["prefill"]["export"]
    imp = role_progs["decode"]["import"]
    assert exp["program"] == "_export" and exp["analyzed"]
    assert imp["program"] == "_import" and imp["analyzed"]
    assert exp["bytes_accessed"] > 0 and imp["bytes_accessed"] > 0
    covered = {p["program"] for ps in role_progs.values()
               for p in ps.values()} | {p["program"] for p in progs.values()}
    assert covered == jl006


def test_engine_memory_gauges_and_device_memory_track(tmp_path):
    cfg, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=32, buckets=(16,),
                         kv_block_size=8)
    engine.run([Request(rid=0, prompt=[1, 2, 3], max_new_tokens=3)])
    g = engine.stats.summary()["obs"]["gauges"]
    assert g["kv_pool_capacity_bytes"]["value"] > 0
    assert g["kv_pool_bytes_peak"]["value"] > 0
    assert g["kv_pool_bytes_peak"]["value"] \
        <= g["kv_pool_capacity_bytes"]["value"]
    # block-granular accounting: peak bytes = peak blocks x block bytes
    assert g["kv_pool_bytes_peak"]["value"] == \
        engine.stats.kv_blocks_peak * engine.kv.block_bytes
    out = tmp_path / "t.json"
    engine.save_trace(out)
    doc = json.loads(out.read_text())
    mem = [e for e in doc["traceEvents"]
           if e["ph"] == "C" and e["name"] == "device_memory_bytes"]
    assert mem, "no device_memory_bytes counter track in the trace"
    assert {"slot_state", "kv_pool"} <= set(mem[0]["args"])
    assert "programs" in doc["otherData"]


# -------------------------------------------------------------------- ledger
def _rec(tps, ttft, **kw):
    return ledger_mod.make_record(arch="qwen3-0.6b", tokens_per_s=tps,
                                  ttft_p50_ms=ttft, sha="abc123", **kw)


def test_ledger_append_read_roundtrip(tmp_path):
    p = tmp_path / "ledger.jsonl"
    assert read_ledger(p) == []            # missing file is an empty history
    r = _rec(1000.0, 20.0, prefix_hit_rate=0.5,
             program_utilization={"decode": 1e-5})
    assert r["version"] == LEDGER_SCHEMA_VERSION
    append_record(p, r)
    append_record(p, _rec(1100.0, 19.0))
    got = read_ledger(p)
    assert [x["tokens_per_s"] for x in got] == [1000.0, 1100.0]
    assert got[0]["program_utilization"] == {"decode": 1e-5}
    assert got[0]["git_sha"] == "abc123"
    p.write_text(p.read_text() + "{not json\n")
    with pytest.raises(ValueError, match="malformed ledger line"):
        read_ledger(p)


def test_ledger_trend_vacuous_then_binding(tmp_path):
    # fewer than MIN_HISTORY prior records: vacuously ok
    assert trend_check([]) == {"ok": True, "band": ledger_mod.DEFAULT_BAND,
                               "runs": 0, "checks": []}
    one = trend_check([_rec(1000, 20)])
    assert one["ok"] and all(c["median"] is None for c in one["checks"])
    # healthy history, healthy newcomer
    hist = [_rec(1000 + 10 * i, 20.0) for i in range(5)]
    ok = trend_check(hist + [_rec(1010, 21.0)])
    assert ok["ok"] and ok["runs"] == 6
    # the acceptance case: a synthetic regressed record fails the check
    bad_tps = trend_check(hist + [_rec(400, 20.0)])       # < half the median
    assert not bad_tps["ok"]
    failed = [c for c in bad_tps["checks"] if not c["ok"]]
    assert [c["metric"] for c in failed] == ["tokens_per_s"]
    assert failed[0]["bound"] == pytest.approx(0.5 * 1020)
    bad_ttft = trend_check(hist + [_rec(1010, 70.0)])     # latency tripled
    assert not bad_ttft["ok"]
    assert [c["metric"] for c in bad_ttft["checks"] if not c["ok"]] \
        == ["ttft_p50_ms"]
    # the window slides: 400-tps history long past stops dragging the median
    assert trend_check([_rec(400, 20)] * 3
                       + [_rec(1000, 20)] * ledger_mod.DEFAULT_WINDOW
                       + [_rec(950, 20)])["ok"]
    with pytest.raises(ValueError):
        trend_check(hist, band=0.0)


def test_ledger_cli_blocking_step(tmp_path, capsys):
    p = tmp_path / "ledger.jsonl"
    for r in [_rec(1000, 20), _rec(1010, 20), _rec(990, 21)]:
        append_record(p, r)
    assert ledger_mod.main([str(p)]) == 0
    # a near-zero band flags even ordinary run-to-run jitter
    assert ledger_mod.main([str(p), "--band", "0.001"]) == 1
    capsys.readouterr()
    append_record(p, _rec(100, 20))        # collapse: an order of magnitude
    assert ledger_mod.main([str(p)]) == 1
    out = capsys.readouterr().out
    assert '"ok": false' in out
