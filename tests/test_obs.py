"""Observability-layer tests: tracer ring/export semantics, histogram math,
Timed sync discipline, drift arithmetic, and the engine's trace schema —
valid Chrome trace-event JSON with per-track monotonic timestamps, nested
request spans, stable request ids across the lifecycle, and deterministic
event sequences under a fixed seed, across all three state families."""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import build_model
from repro.obs import (OBS_SCHEMA_VERSION, Counter, Histogram,
                       MetricsRegistry, Timed, Tracer)
from repro.obs.drift import (PHASES, drift_report, geomean, plan_predictions,
                             residual_factor)
from repro.serve.engine import Request, ServeEngine

ARCHS = ("qwen3-0.6b", "recurrentgemma-2b", "falcon-mamba-7b")


def _tiny_model(arch="qwen3-0.6b", layers=2):
    cfg = reduced_config(arch)
    cfg = cfg.replace(num_layers=max(layers, len(cfg.block_pattern)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _trace(cfg, n=4, max_new=3, seed=5):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, 3 + 5 * i).tolist(),
                    max_new_tokens=max_new)
            for i in range(n)]


# ------------------------------------------------------------------- tracer
def test_tracer_ring_overflow_drops_oldest():
    tr = Tracer(capacity=4)
    for i in range(7):
        tr.instant(f"e{i}", 0, float(i))
    assert len(tr) == 4
    assert tr.dropped == 3
    names = [e[1] for e in tr.events()]
    assert names == ["e3", "e4", "e5", "e6"]     # oldest three fell out
    doc = tr.to_chrome()
    assert doc["otherData"]["dropped_events"] == 3
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_disabled_emits_nothing():
    tr = Tracer(enabled=False)
    tr.span("s", 0, 0.0, 1.0)
    tr.counter("c", 0.0, (("a", 1),))
    assert len(tr) == 0
    doc = tr.to_chrome()
    # still a valid (empty) document: process metadata only
    assert all(e["ph"] == "M" for e in doc["traceEvents"])


def test_tracer_chrome_export_shape():
    tr = Tracer()
    tr.set_track(1, "slot 0")
    t0 = tr.now()
    tr.begin("req 7", 1, t0, (("rid", 7),))
    tr.span("prefill", 1, t0 + 0.001, t0 + 0.002, (("rid", 7),))
    tr.counter("queue_depth", t0 + 0.001, (("queued", 3),))
    tr.end("req 7", 1, t0 + 0.003, (("rid", 7), ("tokens", 4)))
    doc = json.loads(tr.dumps(other_data={"extra": 1}))
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["extra"] == 1
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["name"] for m in meta} == {
        "process_name", "thread_name", "thread_sort_index"}
    x = next(e for e in evs if e["ph"] == "X")
    assert x["dur"] == pytest.approx(1000.0)     # 1 ms in microseconds
    assert x["args"]["rid"] == 7
    inst = [e for e in evs if e["ph"] == "i"]
    assert all(e["s"] == "t" for e in inst) or not inst
    c = next(e for e in evs if e["ph"] == "C")
    assert c["args"] == {"queued": 3}
    # B/E pair well ordered
    b = next(e for e in evs if e["ph"] == "B")
    e_ = next(e for e in evs if e["ph"] == "E")
    assert b["ts"] <= e_["ts"] and e_["args"]["tokens"] == 4


def test_tracer_export_sorted_even_with_late_spans():
    """X spans are emitted at t1 but stamped at t0 — export must re-sort so
    every track reads monotonically."""
    tr = Tracer()
    tr.instant("late", 0, 10.0)
    tr.span("early", 0, 1.0, 2.0)      # emitted after, starts before
    ts = [e[3] for e in tr.events()]
    assert ts == sorted(ts)


# ------------------------------------------------------------------ metrics
def test_histogram_bucket_edges_and_quantiles():
    h = Histogram("lat", base=1.0, nbuckets=8, unit="s")
    # bucket 0: below base; bucket i: [2**(i-1), 2**i)
    assert h.bucket_of(0.5) == 0
    assert h.bucket_of(1.0) == 1
    assert h.bucket_of(1.99) == 1
    assert h.bucket_of(2.0) == 2
    assert h.bucket_of(2.0 ** 30) == 7          # clamped to last bucket
    for v in (1.0, 1.5, 3.0, 3.5):
        h.record(v)
    assert h.count == 4
    assert h.mean == pytest.approx(9.0 / 4)
    assert h.min == 1.0 and h.max == 3.5
    # quantiles clamp to the exact envelope
    assert h.quantile(0.0) == 1.0
    assert h.quantile(1.0) == 3.5
    assert 1.0 <= h.quantile(0.5) <= 3.5
    d = h.to_dict()
    assert d["count"] == 4 and d["buckets"] == {"1": 2, "2": 2}
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        Histogram("bad", base=0.0)


def test_registry_get_or_create_and_versioned_dict():
    reg = MetricsRegistry()
    reg.counter("waste", unit="tokens").inc(3)
    reg.counter("waste").inc(2)
    reg.histogram("ttft_s").record(0.5)
    assert reg.counter("waste").value == 5
    d = reg.to_dict()
    assert d["version"] == OBS_SCHEMA_VERSION
    assert d["counters"]["waste"] == {"unit": "tokens", "value": 5}
    assert d["histograms"]["ttft_s"]["count"] == 1


def test_counter_basics():
    c = Counter("n", unit="x")
    c.inc()
    c.inc(4)
    assert c.to_dict() == {"unit": "x", "value": 5}


# ------------------------------------------------------------------- timing
def _double(v):
    return v * 2


def _incr(v):
    return v + 1


_jit_double = jax.jit(_double)
_jit_incr = jax.jit(_incr)


def test_timed_syncs_device_work_before_stamping():
    x = _jit_double(np.arange(8.0))
    with Timed("section") as tm:
        out = tm.sync(_jit_incr(x))
    assert tm.synced
    assert tm.dur >= 0.0 and tm.t1 >= tm.t0
    np.testing.assert_array_equal(np.asarray(out), np.arange(8.0) * 2 + 1)


def test_timed_unsynced_section_is_visible():
    with Timed("section") as tm:
        pass
    assert not tm.synced


# -------------------------------------------------------------------- drift
def test_drift_arithmetic():
    assert geomean([2.0, 8.0]) == pytest.approx(4.0)
    with pytest.raises(ValueError):
        geomean([])
    assert residual_factor(4.0, 4.0) == pytest.approx(1.0)
    assert residual_factor(8.0, 4.0) == pytest.approx(2.0)
    assert residual_factor(2.0, 4.0) == pytest.approx(2.0)   # symmetric
    rep = drift_report({"decode_step_s": 1e-3}, {"decode_step_s": 2e-3})
    ph = rep["phases"]["decode_step_s"]
    assert ph["ratio"] == pytest.approx(2.0)
    assert rep["max_residual_factor"] >= 1.0
    assert drift_report({}, {}) == {}


def test_engine_drift_section_uses_shared_arithmetic():
    from repro.launch.serve import build_engine
    cfg, _, params = _tiny_model()
    engine = build_engine(cfg, params, slots=2, max_len=64, max_bucket=32,
                          policy="auto")
    engine.run(_trace(cfg))
    p = engine.stats.summary()["placement"]
    drift = p["drift"]
    assert set(drift["phases"]) == set(PHASES)
    for ph, rec in drift["phases"].items():
        assert rec["predicted"] == plan_predictions(p)[ph]
        assert rec["residual_factor"] == pytest.approx(
            residual_factor(rec["ratio"], 1.0))


# ----------------------------------------------------------- engine schema
def _run_traced(arch, seed=5, enabled=True, max_new=3):
    cfg, model, params = _tiny_model(arch)
    tracer = Tracer(enabled=enabled)
    engine = ServeEngine(model, params, slots=2, max_len=64, buckets=(8, 16),
                         prefill_chunk=8, tracer=tracer)
    engine.run(_trace(cfg, seed=seed, max_new=max_new))
    return engine


def _by_track(events):
    tracks: dict = {}
    for e in events:
        if e["ph"] != "M":
            tracks.setdefault(e["tid"], []).append(e)
    return tracks


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_trace_schema(arch):
    engine = _run_traced(arch)
    doc = json.loads(engine.tracer.dumps())
    evs = doc["traceEvents"]

    # track metadata covers every tid in use
    named = {e["tid"] for e in evs if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert {e["tid"] for e in evs} <= named
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"requests", "slot 0", "slot 1", "engine"} <= names

    # per-track monotonic timestamps
    for tid, track in _by_track(evs).items():
        ts = [e["ts"] for e in track]
        assert ts == sorted(ts), f"track {tid} not monotonic"

    # request spans nest: balanced B/E per slot track, E follows its B
    for tid, track in _by_track(evs).items():
        depth = 0
        for e in track:
            if e["ph"] == "B":
                depth += 1
                assert depth == 1       # one request resident per slot
            elif e["ph"] == "E":
                depth -= 1
                assert depth >= 0
        assert depth == 0

    # stable rids: every request's submit instant, B span, and E span agree,
    # and every per-request event carries the rid
    rids = {e["args"]["rid"] for e in evs
            if e["ph"] == "i" and e["name"] == "submit"}
    assert rids == {0, 1, 2, 3}
    for rid in rids:
        b = [e for e in evs if e["ph"] == "B" and e["args"]["rid"] == rid]
        e_ = [e for e in evs if e["ph"] == "E" and e["args"]["rid"] == rid]
        assert len(b) == 1 and len(e_) == 1
        assert b[0]["name"] == e_[0]["name"] == f"req {rid}"
        assert b[0]["tid"] == e_[0]["tid"]       # resident on one slot track
        assert b[0]["ts"] <= e_[0]["ts"]

    # counters sampled every tick, with the engine's full series vocabulary
    counters = {e["name"]: e for e in evs if e["ph"] == "C"}
    assert {"queue_depth", "slots"} <= set(counters)
    assert set(counters["slots"]["args"]) == {"busy", "free"}

    # decode spans live on the engine track
    decode = [e for e in evs if e["ph"] == "X" and e["name"] == "decode"]
    assert decode and len({e["tid"] for e in decode}) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_trace_deterministic_under_seed(arch):
    """Same seed -> same event sequence (names, tracks, args) modulo
    timestamps and durations."""
    def shape(engine):
        # args keyed *_s are wall-clock durations — timing, not structure
        return [(ph, name, tid,
                 tuple((k, v) for k, v in args if not k.endswith("_s")))
                for ph, name, tid, ts, dur, args in engine.tracer.events()]
    a = shape(_run_traced(arch, seed=9))
    b = shape(_run_traced(arch, seed=9))
    assert a == b


def test_engine_trace_disabled_and_empty_paths():
    engine = _run_traced("qwen3-0.6b", enabled=False)
    assert len(engine.tracer) == 0
    doc = json.loads(engine.tracer.dumps())      # still valid JSON
    assert all(e["ph"] == "M" for e in doc["traceEvents"])
    # stats stay fully populated with the tracer off
    s = engine.stats.summary()
    assert s["requests_completed"] == 4
    assert s["obs"]["histograms"]["ttft_s"]["count"] == 4

    # engine with no work: empty but well-formed trace, zero-valued obs
    cfg, model, params = _tiny_model()
    idle = ServeEngine(model, params, slots=1, max_len=32)
    json.loads(idle.tracer.dumps())
    assert idle.stats.summary()["obs"]["version"] == OBS_SCHEMA_VERSION


def test_engine_trace_stall_and_save(tmp_path):
    """A pool-starved engine emits stall instants; save_trace round-trips
    through disk with the obs summary attached."""
    cfg, model, params = _tiny_model()
    # pool of 5 blocks: two 14-token prompts hold 2 blocks each, the first
    # boundary crossing takes the last free block for slot 0 and stalls
    # slot 1 (its neighbours' blocks are referenced, so nothing is evictable)
    # until the short request retires
    engine = ServeEngine(model, params, slots=2, max_len=40, buckets=(16,),
                         kv_block_size=8, kv_blocks=5)
    rng = np.random.RandomState(2)
    prompts = [rng.randint(1, cfg.vocab_size, 14).tolist() for _ in range(2)]
    engine.run([Request(rid=0, prompt=prompts[0], max_new_tokens=6),
                Request(rid=1, prompt=prompts[1], max_new_tokens=18)])
    assert engine.stats.summary()["kv"]["decode_stalls"] > 0
    stalls = [e for e in engine.tracer.events() if e[0] == "i"
              and e[1] == "stall"]
    assert stalls and all(dict(e[5])["rid"] == 1 for e in stalls)
    out = tmp_path / "trace.json"
    engine.save_trace(out)
    doc = json.loads(out.read_text())
    assert doc["otherData"]["obs"]["version"] == OBS_SCHEMA_VERSION
    assert any(e["ph"] == "i" and e["name"] == "stall"
               for e in doc["traceEvents"])


def test_engine_prefill_waste_counter():
    cfg, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=32, buckets=(16,))
    engine.run([Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2)])
    obs = engine.stats.summary()["obs"]
    # 3-token prompt padded to the 16 bucket: 13 wasted positions
    assert obs["counters"]["prefill_waste_tokens"]["value"] == 13
    assert obs["histograms"]["decode_tick_s"]["count"] == \
        engine.stats.decode_steps
    assert obs["histograms"]["tokens_per_tick"]["count"] == \
        engine.stats.decode_steps
