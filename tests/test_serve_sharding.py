"""Unit tests for the multi-device serving pieces that don't need multiple
devices: serve_state_specs structure, mesh helpers / CLI parsing, and the
block pool's per-shard accounting (tests/test_distributed.py runs the real
sharded engines under 8 forced host devices)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import reduced_config
from repro.launch import shardings as sh
from repro.launch.mesh import make_serve_mesh, parse_mesh_arg
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvpool import KVBlockPool, PagedKVManager, RadixPrefixCache

ARCHS = ("qwen3-0.6b", "recurrentgemma-2b", "falcon-mamba-7b")


# ------------------------------------------------------------------ specs
@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("paged", [False, True])
def test_serve_state_specs_mirror_init_states(arch, paged):
    """One full-rank PartitionSpec per state leaf, for every family and for
    both dense and paged KV layouts."""
    cfg = reduced_config(arch)
    cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
    model = build_model(cfg)
    kw = {}
    if paged:
        if any(k != "attn" for k in
               tuple(model.pattern) + tuple(model.tail_kinds)):
            pytest.skip("paged KV covers full-attention layers")
        kw = dict(kv_block_size=16, kv_blocks=8)
    mesh = make_serve_mesh(1, 1)
    states = model.init_states(4, 64, **kw)
    specs = sh.serve_state_specs(model, mesh, 4, 64, **kw)
    is_p = lambda x: isinstance(x, P)
    state_leaves = jax.tree.leaves(states)
    spec_leaves = jax.tree.leaves(specs, is_leaf=is_p)
    assert len(state_leaves) == len(spec_leaves)
    # tree_map across both trees raises on any structural mismatch and lets
    # us pin specs to full rank (device_put requires len(spec) <= ndim; full
    # rank means every axis got an explicit decision)
    def check(leaf, spec):
        assert isinstance(spec, P), spec
        assert len(spec) == leaf.ndim, (leaf.shape, spec)
        return leaf
    jax.tree.map(check, states, specs, is_leaf=lambda x: is_p(x) or None)


def test_serve_state_specs_shard_fallbacks():
    """Axes that don't divide the mesh fall back to replicated instead of
    erroring: odd slot counts and odd pool sizes must still serve."""
    cfg = reduced_config("qwen3-0.6b")
    cfg = cfg.replace(num_layers=2)
    model = build_model(cfg)
    mesh = make_serve_mesh(1, 1)
    # slots=3 divides nd=1, so the batch axis keeps its data spec (qwen3's
    # two layers land in one scanned group: axis 0 is the stack, 1 the batch)
    specs = sh.serve_state_specs(model, mesh, 3, 64)
    kv = specs["groups"]["0"].kv
    assert kv.k[0] is None and kv.k[1] == ("data",)
    # a device_put through the specs round-trips the real states
    states = model.init_states(3, 64)
    placed = jax.device_put(states, sh.to_named(specs, mesh))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), states, placed)


# ------------------------------------------------------------------ mesh CLI
def test_make_serve_mesh_shapes_and_validation():
    m = make_serve_mesh(1, 1)
    assert dict(m.shape) == {"data": 1, "model": 1}
    assert make_serve_mesh().shape["model"] == 1       # defaults to pure dp
    with pytest.raises(RuntimeError):
        make_serve_mesh(64, 64)                        # more than we have
    with pytest.raises(ValueError):
        make_serve_mesh(1, 0)


def test_parse_mesh_arg():
    assert parse_mesh_arg("off") is None
    assert parse_mesh_arg("none") is None
    assert parse_mesh_arg("") is None
    m = parse_mesh_arg("1x1")
    assert dict(m.shape) == {"data": 1, "model": 1}
    assert parse_mesh_arg("auto") is not None
    with pytest.raises(ValueError):
        parse_mesh_arg("banana")


# ------------------------------------------------------------- pool shards
def test_pool_per_shard_accounting():
    tree = RadixPrefixCache(block_size=4)
    pool = KVBlockPool(12, 4, shards=4)                # 3 blocks per stripe
    got = [pool.alloc(tree) for _ in range(7)]
    assert pool.in_use == 7 == sum(pool.in_use_by_shard)
    assert pool.in_use_by_shard == [3, 3, 1, 0]        # contiguous stripes
    assert pool.peak_by_shard == [3, 3, 1, 0]
    assert sum(pool.peak_by_shard) == pool.peak_in_use == 7
    for b in got[2:]:
        pool.release(b, tree)
    assert pool.in_use == 2 == sum(pool.in_use_by_shard)
    # the peak snapshot is frozen at the high-water mark
    assert pool.peak_by_shard == [3, 3, 1, 0]
    b = pool.alloc(tree)                               # below peak: no change
    assert pool.shard_of(b) == b // 3
    assert sum(pool.peak_by_shard) == pool.peak_in_use == 7


def test_pool_shards_must_tile_blocks():
    with pytest.raises(ValueError):
        KVBlockPool(10, 4, shards=4)


def test_manager_shards_survive_clear_and_reset():
    mgr = PagedKVManager(slots=2, max_len=16, block_size=4, num_blocks=8,
                         shards=2)
    assert mgr.shards == 2
    plan = mgr.admit(0, list(range(6)))
    assert plan is not None
    assert sum(mgr.in_use_by_shard) == mgr.in_use == 2
    mgr.release(0)
    mgr.clear()
    assert mgr.shards == 2
    assert mgr.in_use_by_shard == [0, 0]
    mgr.reset_stats()
    assert mgr.peak_by_shard == [0, 0]


# ------------------------------------------------------------ 1-device mesh
def test_engine_on_one_device_mesh_matches_meshless():
    """The mesh path (sharded params/states, pinned out-shardings, gather
    spec) on a 1-device mesh is plumbing-only: tokens must match the
    meshless engine exactly — paged + prefix cache included."""
    cfg = reduced_config("qwen3-0.6b")
    cfg = cfg.replace(num_layers=2)
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    def trace():
        rng = np.random.RandomState(11)
        shared = rng.randint(1, cfg.vocab_size, 20).tolist()
        out = [Request(rid=i, prompt=shared + rng.randint(
                   1, cfg.vocab_size, 2 + i).tolist(), max_new_tokens=4)
               for i in range(3)]
        out.append(Request(rid=9, prompt=rng.randint(
            1, cfg.vocab_size, 7).tolist(), max_new_tokens=4))
        return out

    def build(mesh):
        return ServeEngine(build_model(cfg), params, slots=2, max_len=64,
                           buckets=(16, 32), kv_block_size=16, mesh=mesh)

    ref = build(None).run(trace())
    eng = build(make_serve_mesh(1, 1))
    assert eng.mesh is not None
    eng.warmup()
    w = eng.stats.summary()
    eng.reset_stats()
    done = eng.run(trace())
    s = eng.stats.summary()
    rec = (s["prefill_compiles"] - w["prefill_compiles"]) \
        + (s["decode_compiles"] - w["decode_compiles"])
    assert rec == 0, f"{rec} recompiles after warmup on the 1-device mesh"
    assert [r.generated for r in done] == [r.generated for r in ref]
    assert s["kv"]["prefix_hit_rate"] > 0


# --------------------------------------------------------- auto param specs
@pytest.mark.parametrize("dp,mp", [(1, 1), (1, 2)])
def test_param_specs_auto_follows_plan_sharding_axis(dp, mp):
    """The PR 7 leftover, closed: ``ExecutionPolicy.sharding_axis`` now
    drives the weight layout.  The oracle marks falcon-mamba's SSM cluster
    memory-centric (axis "data"), so ``param_specs(..., "auto")`` replicates
    the SSM family that the "tp" templates would slice over the model axis —
    while embeddings stay Jacquard vocab-sharded and, on a TP mesh, the
    engine still generates the exact tokens the "tp" layout does."""
    import jax.tree_util as jtu
    from jax.sharding import NamedSharding

    from repro.configs import get_config
    from repro.launch.serve import build_engine
    from repro.serve.placement import resolve_policy

    if int(np.prod([d for d in (dp, mp)])) > len(jax.devices()):
        pytest.skip(f"needs {dp * mp} devices")

    plan = resolve_policy(get_config("falcon-mamba-7b"), slots=4,
                          max_len=256, mesh_axes=("data", "model"))
    # the empirical anchor: the oracle really does rank the SSM cluster
    # memory-centric (data axis) where qwen3's attention ranks compute-
    # centric (model axis) — if the cost model changes its mind, this test
    # must be revisited along with the layout it pins
    ssm = next(p for p in plan.policies if "ssm" in p.kinds)
    assert ssm.sharding_axis == "data"
    qwen_plan = resolve_policy(get_config("qwen3-0.6b"), slots=4,
                               max_len=256, mesh_axes=("data", "model"))
    assert all(p.sharding_axis == "model" for p in qwen_plan.policies)

    cfg = reduced_config("falcon-mamba-7b")
    cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shapes = jax.eval_shape(lambda: params)
    tp = sh.param_specs(cfg, shapes, "tp")
    auto = sh.param_specs(cfg, shapes, "auto", plan=plan)

    is_p = lambda x: isinstance(x, P)
    changed = {
        jtu.keystr(path): (a, b)
        for (path, a), (_, b) in zip(
            jtu.tree_leaves_with_path(tp, is_leaf=is_p),
            jtu.tree_leaves_with_path(auto, is_leaf=is_p))
        if a != b}
    assert changed, "auto layout identical to tp — the plan had no effect"
    for key, (a, b) in changed.items():
        assert "ssm" in key, f"auto changed a non-SSM leaf: {key}"
        assert b == P(*((None,) * len(b))), (key, b)   # fully replicated
        assert "model" in a, (key, a)   # tp really sliced it
    # embeddings never replicate, whatever the plan says
    assert auto["embed"] == tp["embed"] == P("model", None)

    # qwen3 (every cluster model-axis): auto degrades to exactly tp
    qcfg = reduced_config("qwen3-0.6b").replace(num_layers=2)
    qshapes = jax.eval_shape(
        lambda: build_model(qcfg).init(jax.random.PRNGKey(0)))
    assert sh.param_specs(qcfg, qshapes, "auto", plan=qwen_plan) \
        == sh.param_specs(qcfg, qshapes, "tp")

    # auto without a plan is a usage error, not a silent tp fallback
    with pytest.raises(ValueError):
        sh.param_specs(cfg, shapes, "auto")

    # placement end to end: the auto layout serves the same tokens as tp
    mesh = make_serve_mesh(dp, mp)
    placed = jax.device_put(params, jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), auto, is_leaf=is_p))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, placed)

    def run(strategy):
        eng = build_engine(cfg, params, slots=2, max_len=64, max_bucket=32,
                           mesh=make_serve_mesh(dp, mp),
                           param_strategy=strategy,
                           plan_cfg=get_config("falcon-mamba-7b"))
        rng = np.random.RandomState(11)
        return [r.generated for r in eng.run(
            [Request(rid=i, prompt=rng.randint(1, cfg.vocab_size,
                                               4 + 6 * i).tolist(),
                     max_new_tokens=4) for i in range(3)])]

    assert run("auto") == run("tp")
