"""Tests for the jitlint static-analysis suite (src/repro/analysis).

Each seeded-violation file under tests/analysis_cases/ carries
``# expect[JLxxx]`` markers on the exact lines where findings must anchor;
its ``*_ok.py`` twin seeds the same violations behind pragmas and must lint
clean.  These tests are stdlib-only (no jax import) — the corpus is parsed,
never executed.
"""
import json
import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (
    LintConfig,
    get_rule,
    lint_paths,
    load_config,
)
from repro.analysis.config import AllowEntry
from repro.analysis.findings import Severity

REPO = Path(__file__).resolve().parent.parent
CASES = REPO / "tests" / "analysis_cases"

# config-literal and pallas-spec restrict themselves to src/* and *kernels/*
# respectively; widen them so they can see their corpus file.
CASE_OPTIONS = {
    "case_config_literal": {"config-literal": {"paths": ["*"]}},
    "case_pallas_spec": {"pallas-spec": {"paths": ["*"]}},
    "case_policy_knob": {"policy-owned-knob": {"paths": ["*"]}},
}

VIOLATION_CASES = [
    "case_recompile_hazard",
    "case_config_literal",
    "case_api_drift",
    "case_optional_dep",
    "case_pallas_spec",
    "case_compile_inventory",
    "case_policy_knob",
    "case_timing_discipline",
]

_MARKER_RE = re.compile(r"#\s*expect\[(JL\d{3})\]")


def _markers(path: Path) -> set:
    out = set()
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        for m in _MARKER_RE.finditer(line):
            out.add((lineno, m.group(1)))
    return out


def _lint_case(stem: str):
    path = CASES / f"{stem}.py"
    config = LintConfig(rule_options=dict(CASE_OPTIONS.get(
        stem.removesuffix("_ok"), {})))
    return path, lint_paths([path], root=REPO, config=config)


@pytest.mark.parametrize("stem", VIOLATION_CASES)
def test_rule_fires_exactly_where_expected(stem):
    path, result = _lint_case(stem)
    expected = _markers(path)
    assert expected, f"{path} has no expect[] markers"
    got = {(f.line, f.rule_id) for f in result.findings}
    assert got == expected, (
        f"{stem}: expected findings {sorted(expected)}, got {sorted(got)}\n"
        + "\n".join(f.render() for f in result.findings))


@pytest.mark.parametrize("stem", VIOLATION_CASES)
def test_pragma_twin_is_clean(stem):
    path, result = _lint_case(f"{stem}_ok")
    assert result.findings == [], (
        f"{stem}_ok must lint clean:\n"
        + "\n".join(f.render() for f in result.findings))
    assert result.suppressed > 0, (
        f"{stem}_ok seeds violations behind pragmas — suppressed count "
        f"should be positive, not {result.suppressed}")


def test_recompile_hazard_shape_branch_is_warning_only():
    _, result = _lint_case("case_recompile_hazard")
    warnings = [f for f in result.findings if f.severity is Severity.WARNING]
    assert warnings and all("shape" in f.message for f in warnings)
    errors = [f for f in result.findings if f.severity is Severity.ERROR]
    assert errors  # the .item()/int()/jit-in-loop seeds are hard errors


def test_repo_gate_is_clean():
    """The acceptance gate: jitlint over src+tests exits 0 with the
    committed config, and the only allowlisted finding is the documented
    shardings.py parameter-count threshold."""
    config = load_config(root=REPO)
    result = lint_paths(["src", "tests"], root=REPO, config=config)
    assert result.exit_code() == 0, "\n".join(
        f.render() for f in result.findings)
    assert [(f.rule_id, f.path) for f in result.allowed] == [
        ("JL002", "src/repro/launch/shardings.py")]
    assert result.files > 50  # the sweep actually traversed the repo


def test_engine_compile_inventory_is_clean():
    """serve/engine.py is the real target of JL006 — every jitted program
    must be warmed; this locks the invariant against regressions."""
    result = lint_paths([REPO / "src/repro/serve/engine.py"], root=REPO,
                        rules=[get_rule("JL006")])
    assert result.findings == [], "\n".join(
        f.render() for f in result.findings)


def test_engine_timing_discipline_is_clean():
    """serve/engine.py is the real target of JL008 — since the async-dispatch
    fix, every timed section routes through obs.Timed (which syncs before
    stamping) and the engine holds no direct `time.*` calls at all; this
    locks both against regressions."""
    engine = REPO / "src/repro/serve/engine.py"
    result = lint_paths([engine], root=REPO, rules=[get_rule("JL008")])
    assert result.findings == [], "\n".join(
        f.render() for f in result.findings)
    assert not re.search(r"\btime\.(time|perf_counter|monotonic)\s*\(",
                         engine.read_text()), \
        "engine must clock exclusively through tracer.now()/Timed"


def test_timing_discipline_severities():
    """Jit-reachable clock reads are hard errors; the unsynced-section
    heuristic warns (gates only --strict)."""
    _, result = _lint_case("case_timing_discipline")
    sev = {f.line: f.severity for f in result.findings}
    assert Severity.ERROR in sev.values()
    assert Severity.WARNING in sev.values()
    for f in result.findings:
        if f.severity is Severity.WARNING:
            assert "async dispatch" in f.message


def test_serve_layer_owns_no_knobs():
    """serve/ is the real target of JL007 — the engine must receive kernel
    variants / chunking only through the oracle's phase-profile overrides,
    never by reading the knobs itself (placement.py, the owner, is exempt
    via the rule's default allow_paths)."""
    result = lint_paths([REPO / "src/repro/serve"], root=REPO,
                        rules=[get_rule("JL007")])
    assert result.findings == [], "\n".join(
        f.render() for f in result.findings)
    assert result.files >= 3    # engine, kvpool, placement at minimum


def test_unknown_pragma_label_is_reported(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text("x = 1  # jitlint: ignore[JL999]\n")
    result = lint_paths([f], root=tmp_path)
    assert [(g.rule_id, g.line) for g in result.findings] == [("JL000", 1)]
    assert "JL999" in result.findings[0].message


def test_skip_file_pragma(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "# jitlint: skip-file\n"
        "def probe(compiled):\n"
        "    return compiled.cost_analysis()\n")
    result = lint_paths([f], root=tmp_path)
    assert result.findings == []
    assert result.files == 1


def test_syntax_error_is_a_finding_not_a_crash(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text("def broken(:\n")
    result = lint_paths([f], root=tmp_path)
    assert [g.rule_id for g in result.findings] == ["JL000"]
    assert "syntax error" in result.findings[0].message


def test_allowlist_absorbs_finding(tmp_path):
    (tmp_path / "tests").mkdir()            # JL004 only inspects tests/*
    f = tmp_path / "tests" / "test_opt.py"
    f.write_text("import hypothesis\n")
    config = LintConfig(allow=[AllowEntry(
        rule="JL004", path="tests/test_opt.py", reason="corpus fixture")])
    result = lint_paths([f], root=tmp_path, config=config)
    assert result.findings == []
    assert [g.rule_id for g in result.allowed] == ["JL004"]
    assert "corpus fixture" in result.allowed[0].allowed_by


def test_allow_entry_requires_reason(tmp_path):
    cfg = tmp_path / "jitlint.toml"
    cfg.write_text('[[allow]]\nrule = "JL002"\npath = "x.py"\n')
    with pytest.raises(ValueError, match="missing required key"):
        load_config(cfg)
    cfg.write_text(
        '[[allow]]\nrule = "JL002"\npath = "x.py"\nreason = "  "\n')
    with pytest.raises(ValueError, match="empty reason"):
        load_config(cfg)


def test_config_exclude(tmp_path):
    (tmp_path / "skipme").mkdir()
    f = tmp_path / "skipme" / "test_mod.py"
    f.write_text("import hypothesis\n")
    config = LintConfig(exclude=["skipme/*"])
    result = lint_paths([tmp_path], root=tmp_path, config=config)
    assert result.files == 0


def _run_cli(*argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.jitlint", *argv],
        capture_output=True, text=True, env=env, cwd=REPO)


def test_cli_exit_one_on_violations(tmp_path):
    # the optional-dep case needs no option overrides, so the CLI can
    # reproduce the finding end to end; an empty --config sidesteps the
    # repo jitlint.toml (which excludes the corpus from the real gate)
    empty_cfg = tmp_path / "jitlint.toml"
    empty_cfg.write_text("")
    json_out = tmp_path / "findings.json"
    proc = _run_cli(str(CASES / "case_optional_dep.py"),
                    "--root", str(REPO), "--config", str(empty_cfg),
                    "--json", str(json_out))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "JL004" in proc.stdout
    payload = json.loads(json_out.read_text())
    assert payload["version"] == 1
    assert payload["errors"] == 3
    assert {f["rule_id"] for f in payload["findings"]} == {"JL004"}


def test_cli_exit_zero_on_clean_file(tmp_path):
    empty_cfg = tmp_path / "jitlint.toml"
    empty_cfg.write_text("")
    proc = _run_cli(str(CASES / "case_optional_dep_ok.py"),
                    "--root", str(REPO), "--config", str(empty_cfg))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "1 file(s)" in proc.stdout  # it really linted the corpus file


def test_cli_list_rules():
    proc = _run_cli("--list-rules")
    assert proc.returncode == 0
    for rule_id in ("JL001", "JL002", "JL003", "JL004", "JL005", "JL006",
                    "JL007", "JL008"):
        assert rule_id in proc.stdout
