"""Shared pytest configuration.

Offline reproducibility note: the tier-1 command is
``PYTHONPATH=src python -m pytest -q`` and must collect and pass with
**stdlib + jax + numpy + pytest only**.  In particular ``hypothesis`` is an
optional dev dependency (see requirements-dev.txt): the randomized sweeps in
test_core_scheduler.py, test_kernels.py, and test_models_attention.py run as
seeded ``pytest.mark.parametrize`` cases, so nothing here may hard-import
hypothesis.  Keep new randomized tests seeded the same way (derive shapes
from ``random.Random(seed)``) so failures reproduce from the parametrize id
alone.
"""
import os
import sys
from pathlib import Path

# allow running `pytest` without PYTHONPATH=src already exported
_SRC = str(Path(__file__).resolve().parent.parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# keep CPU test runs deterministic and quiet
os.environ.setdefault("JAX_PLATFORMS", "cpu")
