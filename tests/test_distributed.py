"""Multi-device tests — run in a subprocess with 8 forced host devices so the
main pytest process keeps seeing exactly 1 device (assignment requirement)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(code: str) -> str:
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=500)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


def test_sharded_train_step_matches_single_device():
    """Same model+data on a (4,2) mesh == unsharded reference (loss equal)."""
    out = _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import reduced_config
        from repro.models import build_model
        from repro.train import optim
        from repro.train.trainer import make_train_step
        from repro.launch import shardings as sh

        cfg = reduced_config("qwen3-0.6b").replace(num_layers=2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = optim.adamw_init(params)
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32))),
                 "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)))}
        step = make_train_step(model)

        # reference on default device placement
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pspecs = sh.param_specs(cfg, params)
        with mesh:
            ps = jax.device_put(params, sh.to_named(pspecs, mesh))
            os_ = jax.device_put(opt, sh.to_named(
                optim.AdamWState(P(), pspecs, pspecs), mesh))
            bs = jax.device_put(batch, sh.to_named(
                {"tokens": P("data", None), "labels": P("data", None)}, mesh))
            p2, o2, m2 = jax.jit(step)(ps, os_, bs)
        print("LOSS", float(m1["loss"]), float(m2["loss"]))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
        # updated params agree
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
        mx = max(jax.tree.leaves(d))
        print("MAXDIFF", mx)
        assert mx < 5e-2
        print("OK")
    """))
    assert "OK" in out


def test_elastic_checkpoint_restore_on_different_mesh():
    """Save sharded on (4,2); restore on (2,4) — elastic scaling."""
    out = _run(textwrap.dedent("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import checkpoint as ckpt_lib

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": jnp.ones((16,), jnp.bfloat16)}
        d = tempfile.mkdtemp()
        m1 = jax.make_mesh((4, 2), ("data", "model"))
        t1 = jax.device_put(tree, {"w": NamedSharding(m1, P("data", "model")),
                                   "b": NamedSharding(m1, P("model"))})
        ckpt_lib.save(d, 1, t1)

        m2 = jax.make_mesh((2, 4), ("data", "model"))
        sh2 = {"w": NamedSharding(m2, P("model", "data")),
               "b": NamedSharding(m2, P("data"))}
        got = ckpt_lib.restore(d, 1, jax.eval_shape(lambda: tree), sh2)
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
        np.testing.assert_array_equal(np.asarray(got["b"], np.float32),
                                      np.asarray(tree["b"], np.float32))
        assert got["w"].sharding == sh2["w"]
        print("OK")
    """))
    assert "OK" in out


def test_compressed_gradient_allreduce():
    """int8 error-feedback psum: mean within quantization error of exact,
    error feedback captures the residual."""
    out = _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.train.grad import compressed_psum, init_error_state

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.RandomState(0)
        g_global = jnp.asarray(rng.randn(8, 64, 32).astype(np.float32))
        grads = {"w": g_global}
        err = {"w": jnp.zeros((8, 64, 32), jnp.float32)}

        @partial(shard_map, mesh=mesh,
                 in_specs=({"w": P("data", None, None)},
                           {"w": P("data", None, None)}),
                 out_specs=({"w": P(None, None)}, {"w": P("data", None, None)}),
                 check_rep=False)
        def run(g, e):
            g = {"w": g["w"][0]}
            e = {"w": e["w"][0]}
            mean, new_e = compressed_psum(g, e, "data")
            return mean, {"w": new_e["w"][None]}

        mean, new_err = run(grads, err)
        exact = jnp.mean(g_global, axis=0)
        rel = float(jnp.linalg.norm(mean["w"] - exact)
                    / jnp.linalg.norm(exact))
        print("REL", rel)
        assert rel < 0.05            # int8 quantization error bound
        # error feedback is non-trivial and bounded by one quant step
        enorm = float(jnp.max(jnp.abs(new_err["w"])))
        scale = float(jnp.max(jnp.abs(g_global)) / 127.0)
        print("ERR", enorm, "SCALE", scale)
        assert 0 < enorm <= scale * 1.01
        print("OK")
    """))
    assert "OK" in out


def test_dryrun_entrypoint_on_tiny_mesh():
    """dryrun machinery lowers+compiles on an 8-device (4,2) mesh (fast path
    of the 512-device production dry-run)."""
    out = _run(textwrap.dedent("""
        import jax
        from repro.configs import reduced_config, SHAPES
        from repro.launch import dryrun as dr
        from repro.launch import shardings as sh
        from repro.utils.hlo import normalize_cost_analysis
        import dataclasses

        cfg = reduced_config("qwen3-0.6b")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64,
                                    global_batch=8)
        fn, args, _, meta = dr.build_lowerable(cfg, shape, mesh)
        with mesh:
            compiled = jax.jit(fn).lower(*args).compile()
        # cost_analysis() is a dict on old JAX, a list of dicts on new JAX
        cost = normalize_cost_analysis(compiled.cost_analysis())
        assert cost.get("flops", 0) > 0
        print("OK", cost.get("flops"))
    """))
    assert "OK" in out


@pytest.mark.parametrize("ndev", [1, 2, 8])
def test_sharded_engine_token_identity(ndev):
    """A ServeEngine sharded over a {ndev}-device data-parallel mesh must
    generate exactly the tokens of the unsharded engine — across the causal
    (qwen3), sliding-window + RG-LRU (recurrentgemma), and Mamba SSM
    (falcon-mamba) state families — with zero recompiles after warmup."""
    out = _run(textwrap.dedent(f"""
        import jax, numpy as np
        from repro.configs import reduced_config
        from repro.launch.mesh import make_serve_mesh
        from repro.models import build_model
        from repro.serve.engine import Request, ServeEngine

        ndev = {ndev}
        for arch in ("qwen3-0.6b", "recurrentgemma-2b", "falcon-mamba-7b"):
            cfg = reduced_config(arch)
            cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))

            def trace():
                rng = np.random.RandomState(7)
                # short bucketed prompts + one beyond the largest bucket
                # (chunk-continuation path)
                lens = [3, 7, 12, 15, 9, 40]
                return [Request(rid=i,
                                prompt=rng.randint(1, cfg.vocab_size,
                                                   n).tolist(),
                                max_new_tokens=4)
                        for i, n in enumerate(lens)]

            def build(mesh):
                return ServeEngine(build_model(cfg), params, slots=8,
                                   max_len=64, buckets=(16,),
                                   max_prefill_per_step=4,
                                   max_prefill_batch=2, mesh=mesh)

            ref = build(None).run(trace())
            eng = build(make_serve_mesh(ndev, 1))
            eng.warmup()
            w = eng.stats.summary()
            assert w["prefill_compiles"] > 0, "compile counters unavailable"
            eng.reset_stats()
            done = eng.run(trace())
            s = eng.stats.summary()
            rec = (s["prefill_compiles"] - w["prefill_compiles"]) \\
                + (s["decode_compiles"] - w["decode_compiles"])
            assert rec == 0, f"{{arch}}: {{rec}} recompiles after warmup"
            assert [r.generated for r in done] \\
                == [r.generated for r in ref], f"{{arch}} diverged on mesh"
            print("FAMILY-OK", arch)
        print("OK")
    """))
    assert "OK" in out
    assert out.count("FAMILY-OK") == 3


@pytest.mark.parametrize("ndev", [2, 8])
def test_sharded_paged_prefix_engine(ndev):
    """The paged + prefix-cache engine on a sharded block pool: identical
    tokens to the unsharded paged engine, prefix hits intact, and per-shard
    pool accounting summing to the unsharded totals."""
    out = _run(textwrap.dedent(f"""
        import jax, numpy as np
        from repro.configs import reduced_config
        from repro.launch.mesh import make_serve_mesh
        from repro.models import build_model
        from repro.serve.engine import Request, ServeEngine

        ndev = {ndev}
        cfg = reduced_config("qwen3-0.6b")
        cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
        params = build_model(cfg).init(jax.random.PRNGKey(0))

        def trace():
            rng = np.random.RandomState(13)
            shared = rng.randint(1, cfg.vocab_size, 20).tolist()
            out = [Request(rid=i, prompt=shared + rng.randint(
                       1, cfg.vocab_size, 2 + i).tolist(), max_new_tokens=4)
                   for i in range(5)]
            out += [Request(rid=100 + i, prompt=rng.randint(
                        1, cfg.vocab_size, n).tolist(), max_new_tokens=4)
                    for i, n in enumerate([4, 11, 30])]
            return out

        def build(mesh):
            return ServeEngine(build_model(cfg), params, slots=8, max_len=64,
                               buckets=(16, 32), max_prefill_per_step=4,
                               kv_block_size=16, kv_blocks=24, mesh=mesh)

        ref = build(None)
        ref_done = ref.run(trace())
        ref_kv = ref.stats.summary()["kv"]

        eng = build(make_serve_mesh(ndev, 1))
        assert eng.kv.shards == ndev
        eng.warmup()
        w = eng.stats.summary()
        eng.reset_stats()
        done = eng.run(trace())
        s = eng.stats.summary()
        rec = (s["prefill_compiles"] - w["prefill_compiles"]) \\
            + (s["decode_compiles"] - w["decode_compiles"])
        assert rec == 0, f"{{rec}} recompiles after warmup"
        assert [r.generated for r in done] == [r.generated for r in ref_done]
        kv = s["kv"]
        assert kv["prefix_hit_rate"] > 0
        assert kv["prefix_hit_rate"] == ref_kv["prefix_hit_rate"]
        assert kv["shards"] == ndev
        assert sum(kv["in_use_per_shard"]) == kv["blocks_in_use"]
        assert sum(kv["peak_per_shard"]) == kv["blocks_peak"]
        assert kv["blocks_peak"] == ref_kv["blocks_peak"]
        print("OK")
    """))
    assert "OK" in out


def test_sharded_engine_tensor_parallel_mesh():
    """A (4, 2) data x model mesh (Mensa-cluster TP on the weights, sharded
    KV heads) keeps generated tokens identical to the unsharded engine on
    the pure-attention stack."""
    out = _run(textwrap.dedent("""
        import jax, numpy as np
        from repro.configs import reduced_config
        from repro.launch.mesh import make_serve_mesh
        from repro.models import build_model
        from repro.serve.engine import Request, ServeEngine

        cfg = reduced_config("qwen3-0.6b")
        cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
        params = build_model(cfg).init(jax.random.PRNGKey(0))

        def trace():
            rng = np.random.RandomState(3)
            return [Request(rid=i, prompt=rng.randint(
                        1, cfg.vocab_size, n).tolist(), max_new_tokens=4)
                    for i, n in enumerate([5, 9, 14, 30])]

        def build(mesh):
            return ServeEngine(build_model(cfg), params, slots=4, max_len=64,
                               buckets=(16,), mesh=mesh)

        ref = build(None).run(trace())
        eng = build(make_serve_mesh(4, 2))
        eng.warmup()
        w = eng.stats.summary()
        eng.reset_stats()
        done = eng.run(trace())
        s = eng.stats.summary()
        rec = (s["prefill_compiles"] - w["prefill_compiles"]) \\
            + (s["decode_compiles"] - w["decode_compiles"])
        assert rec == 0, f"{rec} recompiles after warmup"
        # empirical, not structural: model-axis collectives reorder
        # reductions, so a JAX/XLA upgrade could legitimately flip an
        # argmax tie here — if this trips with no serving change, relax to
        # a logits-closeness check rather than chasing bitwise TP identity
        assert [r.generated for r in done] == [r.generated for r in ref], \\
            "TP mesh tokens diverged (see comment: may be numeric drift)"
        print("OK")
    """))
    assert "OK" in out


@pytest.mark.parametrize("ndev", [1, 2, 8])
def test_disagg_engine_token_identity(ndev):
    """Role-split prefill/decode serving (a DisaggEngine moving finished
    prefills into the decode pool by KV-suitcase handoff) must generate
    exactly the interleaved engine's tokens across the causal, RG-LRU, and
    Mamba SSM state families, with zero recompiles after warmup on either
    role.  ndev=1 runs the meshless functional split; 2 and 8 pin the roles
    to disjoint (ndev/2, 1) submeshes, so the suitcase crosses device
    boundaries.  The 40-token prompt exceeds the largest bucket, so one
    suitcase carries a chunk-prefilled slot."""
    out = _run(textwrap.dedent(f"""
        import jax, numpy as np
        from repro.configs import reduced_config
        from repro.launch.mesh import RoleConfig, make_role_meshes
        from repro.models import build_model
        from repro.serve.disagg import DisaggEngine
        from repro.serve.engine import Request, ServeEngine

        ndev = {ndev}
        if ndev == 1:
            pm = dm = None
        else:
            pm, dm = make_role_meshes(RoleConfig(prefill=ndev // 2,
                                                 decode=ndev // 2))
            assert set(pm.devices.flat).isdisjoint(set(dm.devices.flat))
        for arch in ("qwen3-0.6b", "recurrentgemma-2b", "falcon-mamba-7b"):
            cfg = reduced_config(arch)
            cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))

            def trace():
                rng = np.random.RandomState(7)
                lens = [3, 7, 12, 15, 9, 40]      # 40 -> chunked prefill
                return [Request(rid=i,
                                prompt=rng.randint(1, cfg.vocab_size,
                                                   n).tolist(),
                                max_new_tokens=4)
                        for i, n in enumerate(lens)]

            ref = ServeEngine(model, params, slots=8, max_len=64,
                              buckets=(16,), max_prefill_per_step=4,
                              max_prefill_batch=2).run(trace())
            dis = DisaggEngine(model, params, prefill_mesh=pm,
                               decode_mesh=dm, prefill_slots=4,
                               decode_slots=8, max_len=64, buckets=(16,),
                               max_prefill_per_step=4, max_prefill_batch=2)
            dis.warmup()
            w = dis.summary()
            dis.reset_stats()
            done = dis.run(trace())
            s = dis.summary()
            rec = dis.recompiles_since(w)
            assert rec == 0, f"{{arch}}: {{rec}} recompiles after warmup"
            assert [r.generated for r in done] \\
                == [r.generated for r in ref], f"{{arch}} diverged"
            assert s["handoffs"] == 6 and s["handoffs_pending"] == 0, s
            print("FAMILY-OK", arch)
        print("OK")
    """))
    assert "OK" in out
    assert out.count("FAMILY-OK") == 3


@pytest.mark.parametrize("ndev", [2, 8])
def test_disagg_paged_prefix_handoff(ndev):
    """A COW'd shared prefix admitted on the prefill role must survive the
    suitcase block copy into the decode pool: the paged disaggregated pair
    generates tokens identical to the interleaved paged engine, the
    prefill-side prefix hit rate matches the interleaved one exactly, zero
    recompiles on either submesh, and the decode pool drains to zero blocks
    in use once every request retires."""
    out = _run(textwrap.dedent(f"""
        import jax, numpy as np
        from repro.configs import reduced_config
        from repro.launch.mesh import RoleConfig, make_role_meshes
        from repro.models import build_model
        from repro.serve.disagg import DisaggEngine
        from repro.serve.engine import Request, ServeEngine

        ndev = {ndev}
        pm, dm = make_role_meshes(RoleConfig(prefill=ndev // 2,
                                             decode=ndev // 2))
        cfg = reduced_config("qwen3-0.6b")
        cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        kw = dict(max_len=128, buckets=(16, 32), max_prefill_per_step=4,
                  kv_block_size=16, kv_blocks=56)

        def trace():
            rng = np.random.RandomState(13)
            shared = rng.randint(1, cfg.vocab_size, 40).tolist()  # 2.5 blocks
            out = [Request(rid=i, prompt=shared + rng.randint(
                       1, cfg.vocab_size, 2 + i).tolist(), max_new_tokens=4)
                   for i in range(5)]
            out += [Request(rid=100 + i, prompt=rng.randint(
                        1, cfg.vocab_size, n).tolist(), max_new_tokens=4)
                    for i, n in enumerate([4, 11, 30, 90])]   # 90 -> chunked
            return out

        ref = ServeEngine(model, params, slots=8, **kw)
        ref_done = ref.run(trace())
        ref_kv = ref.stats.summary()["kv"]
        assert ref_kv["prefix_hit_rate"] > 0, ref_kv

        dis = DisaggEngine(model, params, prefill_mesh=pm, decode_mesh=dm,
                           prefill_slots=4, decode_slots=8, **kw)
        dis.warmup()
        w = dis.summary()
        dis.reset_stats()
        done = dis.run(trace())
        s = dis.summary()
        rec = dis.recompiles_since(w)
        assert rec == 0, f"{{rec}} recompiles after warmup"
        assert [r.generated for r in done] \\
            == [r.generated for r in ref_done], "paged handoff diverged"
        pre_kv = s["roles"]["prefill"]["kv"]
        assert pre_kv["prefix_hit_rate"] == ref_kv["prefix_hit_rate"], \\
            (pre_kv, ref_kv)
        assert s["handoffs"] == 9 and s["handoffs_pending"] == 0, s
        assert s["roles"]["decode"]["kv"]["blocks_in_use"] == 0, s
        print("OK")
    """))
    assert "OK" in out
