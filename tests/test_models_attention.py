"""Attention core tests: flash == reference, local == reference-with-window,
decode path == forward path, across shapes/dtypes.  The randomized sweep runs
as seeded ``pytest.mark.parametrize`` cases (formerly a hypothesis property
test) so the suite collects offline with stdlib + jax only — see
tests/conftest.py."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (decode_attention, flash_attention,
                                    init_kv_cache, local_attention,
                                    reference_attention)


def _rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,skv,h,kvh,hd,block", [
    (16, 16, 4, 4, 8, 8),        # MHA
    (32, 32, 8, 2, 16, 16),      # GQA
    (24, 24, 6, 1, 32, 7),       # MQA + non-dividing block
    (8, 40, 4, 2, 8, 16),        # cross-length (q continues a cache)
])
def test_flash_matches_reference(dtype, sq, skv, h, kvh, hd, block):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(k1, 1, sq, h, hd, dtype=dtype)
    k = _rand(k2, 1, skv, kvh, hd, dtype=dtype)
    v = _rand(k3, 1, skv, kvh, hd, dtype=dtype)
    off = skv - sq
    out = flash_attention(q, k, v, causal=True, block_kv=block, q_offset=off)
    ref = reference_attention(q, k, v, causal=True, q_offset=off)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [4, 8, 16])
def test_flash_sliding_window_matches_reference(window):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    s, h, hd = 32, 4, 8
    q = _rand(k1, 2, s, h, hd)
    k = _rand(k2, 2, s, h, hd)
    v = _rand(k3, 2, s, h, hd)
    out = flash_attention(q, k, v, causal=True, window=window, block_kv=8)
    ref = reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("s,window,h,kvh", [(32, 8, 4, 2), (64, 16, 4, 1),
                                            (32, 16, 8, 8)])
def test_local_attention_matches_reference(s, window, h, kvh):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    hd = 8
    q = _rand(k1, 2, s, h, hd)
    k = _rand(k2, 2, s, kvh, hd)
    v = _rand(k3, 2, s, kvh, hd)
    out = local_attention(q, k, v, window=window)
    ref = reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_decode_matches_full_attention():
    """Decoding positions one by one against the cache reproduces the causal
    full-attention outputs."""
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    b, s, h, kvh, hd = 2, 12, 4, 2, 8
    q = _rand(k1, b, s, h, hd)
    k = _rand(k2, b, s, kvh, hd)
    v = _rand(k3, b, s, kvh, hd)
    ref = reference_attention(q, k, v, causal=True)
    cache = init_kv_cache(b, s, kvh, hd, dtype=jnp.float32)
    outs = []
    for t in range(s):
        o, cache = decode_attention(q[:, t:t + 1], k[:, t:t + 1],
                                    v[:, t:t + 1], cache)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)


def test_decode_with_ring_window_matches_local():
    key = jax.random.PRNGKey(4)
    k1, k2, k3 = jax.random.split(key, 3)
    b, s, h, kvh, hd, w = 1, 24, 2, 1, 8, 8
    q = _rand(k1, b, s, h, hd)
    k = _rand(k2, b, s, kvh, hd)
    v = _rand(k3, b, s, kvh, hd)
    ref = reference_attention(q, k, v, causal=True, window=w)
    cache = init_kv_cache(b, w, kvh, hd, dtype=jnp.float32)
    outs = []
    for t in range(s):
        o, cache = decode_attention(q[:, t:t + 1], k[:, t:t + 1],
                                    v[:, t:t + 1], cache, window=w)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("seed", range(25))
def test_flash_property_sweep(seed):
    rng = random.Random(3000 + seed)
    sq = rng.randint(2, 24)
    h, kvh = rng.choice([(4, 4), (4, 2), (6, 1)])
    hd = rng.choice([4, 8, 16])
    block = rng.choice([4, 8, 32])
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    q = _rand(k1, 1, sq, h, hd)
    k = _rand(k2, 1, sq, kvh, hd)
    v = _rand(k3, 1, sq, kvh, hd)
    out = flash_attention(q, k, v, causal=True, block_kv=block)
    ref = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)
