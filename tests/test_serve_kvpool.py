"""Paged KV-cache subsystem tests.

Three layers:
  * host-side bookkeeping — block pool refcounts/free-list/LRU eviction and
    the radix prefix tree (full-block + partial-block/COW matching), no JAX;
  * model-level identity — the paged attention ops are bitwise-identical to
    the dense ones (decode gate for the kernels package, prefill, chunked
    continuation);
  * engine-level identity — a paged engine generates token-for-token what
    the dense engine generates across the causal-attention, sliding-window +
    RG-LRU, and Mamba state families, including chunked prefill, and the
    shared-prefix + divergent-tail copy-on-write path matches a cold run;
  * sampling — greedy stays exact argmax, non-greedy is reproducible and
    respects top-k/top-p.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import build_model
from repro.models.attention import (KVCache, PagedKVCache, decode_attention,
                                    init_kv_cache, init_paged_kv_cache,
                                    paged_decode_attention)
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvpool import (KVBlockPool, PagedKVManager, RadixPrefixCache,
                                blocks_for)
from repro.serve.sampling import sample_tokens

ARCHS = ["qwen3-0.6b", "recurrentgemma-2b", "falcon-mamba-7b"]


def _tiny_model(arch="qwen3-0.6b", layers=2):
    cfg = reduced_config(arch)
    cfg = cfg.replace(num_layers=max(layers, len(cfg.block_pattern)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------- block pool
def test_pool_alloc_free_refcount():
    tree = RadixPrefixCache(4)
    pool = KVBlockPool(3, 4)
    a = pool.alloc(tree)
    b = pool.alloc(tree)
    c = pool.alloc(tree)
    assert sorted([a, b, c]) == [0, 1, 2]
    assert pool.alloc(tree) is None          # exhausted, nothing evictable
    assert pool.in_use == 3
    pool.retain(a)                           # second reference (shared)
    pool.release(a, tree)
    assert pool.in_use == 3                  # still referenced once
    pool.release(a, tree)
    assert pool.in_use == 2
    assert pool.alloc(tree) == a             # recycled through the free list
    with pytest.raises(AssertionError):
        pool.release(b, tree)
        pool.release(b, tree)                # double release


def test_pool_lru_eviction_prefers_oldest_cached():
    """Cached (published, refcount-0) blocks are evicted LRU when the free
    list runs dry; referenced and recently-touched blocks survive."""
    bs = 2
    tree = RadixPrefixCache(bs)
    pool = KVBlockPool(3, bs)
    b0 = pool.alloc(tree)
    b1 = pool.alloc(tree)
    tree.insert([1, 2], [b0])                # two independent single-block
    tree.insert([3, 4], [b1])                # prefixes -> both are leaves
    pool.release(b0, tree)
    pool.release(b1, tree)                   # both cached now
    tree.match([1, 2])                       # touch b0: b1 becomes LRU
    b2 = pool.alloc(tree)
    b3 = pool.alloc(tree)                    # must evict exactly b1
    assert b3 == b1 and pool.blocks_evicted == 1
    assert tree.contains(b0) and not tree.contains(b1)
    assert pool.alloc(tree) == b0            # then the remaining cached block
    assert pool.blocks_evicted == 2
    del b2


def test_radix_match_full_and_partial_blocks():
    bs = 4
    tree = RadixPrefixCache(bs)
    toks = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]
    tree.insert(toks, [10, 11, 12])
    m = tree.match(toks)
    assert m.blocks == [10, 11, 12] and m.partial_tokens == 0
    m = tree.match([1, 2, 3, 4, 5, 6])       # 1 full block + half a block
    assert m.blocks == [10]
    assert m.partial_block == 11 and m.partial_tokens == 2
    m = tree.match([9, 9, 9, 9])             # cold
    assert m.blocks == [] and m.partial_block is None
    # divergence inside the first block -> partial only
    m = tree.match([1, 2, 9, 9, 9])
    assert m.blocks == [] and m.partial_block == 10 and m.partial_tokens == 2


def test_radix_eviction_is_leaf_only():
    """Evicting a mid-path node would orphan its children's prefix — only
    childless nodes may go, oldest first."""
    bs = 2
    tree = RadixPrefixCache(bs)
    tree.insert([1, 2, 3, 4], [0, 1])        # 0 is 1's parent
    evictable = lambda b: True
    assert tree.evict_lru(evictable) == 1    # leaf first
    assert tree.evict_lru(evictable) == 0    # now childless
    assert tree.evict_lru(evictable) is None


def test_manager_admit_shares_allocates_and_cows():
    mgr = PagedKVManager(slots=2, max_len=32, block_size=4, num_blocks=16)
    prompt = list(range(100, 110))           # 10 tokens -> 3 blocks
    plan = mgr.admit(0, prompt)
    assert plan.matched_tokens == 0 and plan.copy is None
    assert mgr.owned[0] == blocks_for(10, 4) == 3
    donor_blocks = list(mgr.table[0][:2])
    mgr.finish(0, prompt)                    # publishes 2 full blocks
    assert mgr.owned[0] == 0 and mgr.in_use == 0 and mgr.cached == 2
    # same first 6 tokens: 1 full shared block + COW of the second
    plan = mgr.admit(1, prompt[:6] + [7, 7, 7, 7])
    assert plan.matched_tokens == 6
    src, dst = plan.copy
    assert src == donor_blocks[1]                 # the straddled block
    assert mgr.table[1][0] == donor_blocks[0]     # shared, refcounted
    assert mgr.table[1][1] == dst != src
    assert mgr.stats.blocks_copied == 1
    assert mgr.pool.ref[donor_blocks[0]] == 1     # slot 1's reference
    mgr.release(1)
    assert mgr.in_use == 0


def test_manager_never_matches_full_prompt():
    """At least one prompt token must run through prefill so the first
    token's logits exist — a fully-cached prompt matches len-1 tokens."""
    mgr = PagedKVManager(slots=2, max_len=32, block_size=4, num_blocks=16)
    prompt = list(range(8))                  # exactly 2 blocks
    mgr.admit(0, prompt)
    mgr.finish(0, prompt)
    plan = mgr.admit(1, prompt)              # identical prompt
    assert plan.matched_tokens == 7          # 1 full block + 3-token COW
    assert plan.copy is not None


def test_manager_capacity_refusal_has_no_side_effects():
    mgr = PagedKVManager(slots=2, max_len=16, block_size=4, num_blocks=2)
    assert mgr.admit(0, list(range(9))) is None   # needs 3 of 2 blocks
    assert mgr.in_use == 0 and mgr.owned[0] == 0
    assert mgr.admit(0, list(range(5))) is not None
    assert mgr.admit(1, list(range(5))) is None   # pool now empty
    assert mgr.owned[1] == 0


def test_available_excludes_cached_ancestors_of_referenced_blocks():
    """Regression: leaf-only eviction can never reclaim a cached block whose
    subtree still holds another slot's referenced block — counting it as
    supply made admit pass its pre-check and then fail mid-allocation.  Two
    same-prefix prompts admitted cold (same tick, no sharing) set this up:
    the longer one's tail publishes under the shorter one's path."""
    mgr = PagedKVManager(slots=3, max_len=16, block_size=4, num_blocks=7)
    p8 = list(range(50, 58))
    tail = [1, 2, 3, 4]
    assert mgr.admit(0, p8).matched_tokens == 0          # cold, 2 blocks
    assert mgr.admit(1, p8 + tail).matched_tokens == 0   # cold, 3 blocks
    mgr.publish(0, p8)
    mgr.publish(1, p8 + tail)        # slot 1's 3rd block lands under slot 0's
    mgr.finish(0, p8)                # slot 0's chain cached but UNRECLAIMABLE
    assert mgr.cached == 2
    assert mgr.pool.available(mgr.tree) == 2             # free blocks only
    # a 3-block cold prompt must requeue (2 allocatable), not crash
    assert mgr.admit(2, list(range(900, 912))) is None
    assert mgr.owned[2] == 0 and mgr.in_use == 3


def test_manager_extend_and_max_len_cap():
    mgr = PagedKVManager(slots=1, max_len=16, block_size=4, num_blocks=4)
    mgr.admit(0, [1, 2, 3])
    assert mgr.owned[0] == 1
    assert mgr.extend(0, 5)                  # crosses into block 2
    assert mgr.owned[0] == 2
    assert mgr.extend(0, 16)
    assert not mgr.extend(0, 17)             # beyond max_len
    mgr2 = PagedKVManager(slots=2, max_len=16, block_size=4, num_blocks=2)
    mgr2.admit(0, [1, 2, 3, 4, 5])           # 2 blocks
    assert not mgr2.extend(0, 9)             # pool exhausted


def test_manager_rejects_misaligned_max_len():
    with pytest.raises(ValueError, match="multiple"):
        PagedKVManager(slots=1, max_len=30, block_size=4, num_blocks=8)


# ------------------------------------------------- model-level bitwise gates
def test_paged_decode_ref_bitwise_matches_dense_decode():
    """The kernels-package gate: the pure-JAX paged decode (the Pallas
    kernel's oracle) is bitwise-identical to the dense ``decode_attention``
    when the block table is the contiguous identity layout."""
    rng = jax.random.PRNGKey(3)
    B, H, KVH, hd, smax, bs = 2, 4, 2, 16, 32, 8
    ks = jax.random.split(rng, 5)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    nk = jax.random.normal(ks[1], (B, 1, KVH, hd), jnp.float32)
    nv = jax.random.normal(ks[2], (B, 1, KVH, hd), jnp.float32)
    lengths = jnp.asarray([5, 19], jnp.int32)

    dense = init_kv_cache(B, smax, KVH, hd)
    prior_k = jax.random.normal(ks[3], (B, smax, KVH, hd), jnp.float32)
    prior_v = jax.random.normal(ks[4], (B, smax, KVH, hd), jnp.float32)
    dense = KVCache(prior_k.astype(dense.k.dtype),
                    prior_v.astype(dense.v.dtype), lengths)
    out_d, new_d = decode_attention(q, nk, nv, dense)

    nb = smax // bs
    table = jnp.asarray(np.arange(B * nb).reshape(B, nb), jnp.int32)
    paged = PagedKVCache(
        k=dense.k.reshape(B * nb, bs, KVH, hd),
        v=dense.v.reshape(B * nb, bs, KVH, hd), length=lengths)
    out_p, new_p = paged_decode_attention(q, nk, nv, paged, table)

    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_p))
    np.testing.assert_array_equal(
        np.asarray(new_d.k), np.asarray(new_p.k.reshape(B, smax, KVH, hd)))
    np.testing.assert_array_equal(np.asarray(new_d.length),
                                  np.asarray(new_p.length))
    # write_mask freezes masked rows bit-for-bit, like the dense path
    wm = jnp.asarray([True, False])
    _, mp = paged_decode_attention(q, nk, nv, paged, table, write_mask=wm)
    _, md = decode_attention(q, nk, nv, dense, write_mask=wm)
    np.testing.assert_array_equal(
        np.asarray(md.k), np.asarray(mp.k.reshape(B, smax, KVH, hd)))
    np.testing.assert_array_equal(np.asarray(md.length),
                                  np.asarray(mp.length))


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_model_prefill_decode_bitwise(arch):
    """Model-level: paged states produce bitwise-identical logits to dense
    states through prefill and several decode steps — KV, sliding-window
    ring (kept dense by design), RG-LRU, and SSM families."""
    _, model, params = _tiny_model(arch)
    max_len, bs = 32, 8
    B, nb = 2, 32 // 8
    toks = jnp.asarray([[5, 9, 2, 7, 0, 0], [4, 4, 3, 1, 8, 2]], jnp.int32)
    lens = jnp.asarray([4, 6], jnp.int32)
    table = jnp.asarray(np.arange(B * nb).reshape(B, nb), jnp.int32)

    sd = model.init_states(B, max_len)
    lgd, sd, _ = model.prefill(params, toks, sd, length=lens)
    sp = model.init_states(B, max_len, kv_block_size=bs, kv_blocks=B * nb)
    lgp, sp, _ = model.prefill(params, toks, sp, length=lens,
                               block_table=table)
    np.testing.assert_array_equal(np.asarray(lgd), np.asarray(lgp))
    pos = lens
    tok = jnp.argmax(lgd[:, :1, :], axis=-1).astype(jnp.int32)
    for _ in range(4):
        lgd, sd = model.decode_step(params, tok, sd, pos)
        lgp, sp = model.decode_step(params, tok, sp, pos,
                                    block_table=table)
        np.testing.assert_array_equal(np.asarray(lgd), np.asarray(lgp))
        tok = jnp.argmax(lgd[:, :1, :], axis=-1).astype(jnp.int32)
        pos = pos + 1


# --------------------------------------------------- engine-level identity
@pytest.mark.parametrize("arch", ARCHS)
def test_paged_engine_matches_dense_engine(arch):
    """A paged engine (paging on, prefix cache on where eligible) serves a
    mixed ragged trace token-for-token identically to the dense engine —
    causal, sliding-window + RG-LRU, and Mamba models."""
    _, model, params = _tiny_model(arch)
    rng = np.random.RandomState(3)
    prompts = [rng.randint(1, 400, 3 + 5 * i).tolist() for i in range(5)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]

    dense = ServeEngine(model, params, slots=3, max_len=64)
    paged = ServeEngine(model, params, slots=3, max_len=64, kv_block_size=8)
    rd = dense.run(reqs())
    rp = paged.run(reqs())
    assert [r.generated for r in rd] == [r.generated for r in rp]
    # finished slots released their blocks the same tick they retired
    assert paged.stats.kv_blocks_in_use == 0
    assert paged.stats.kv_blocks_peak > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_chunked_prefill_matches_dense(arch):
    """Long prompts through the paged chunk-continuation program generate
    exactly what the dense chunked engine generates."""
    _, model, params = _tiny_model(arch)
    prompt = np.random.RandomState(5).randint(1, 400, 45).tolist()
    kw = dict(slots=2, max_len=128, buckets=(16,), prefill_chunk=16)
    dense = ServeEngine(model, params, **kw)
    paged = ServeEngine(model, params, kv_block_size=16, **kw)
    (rd,) = dense.run([Request(rid=0, prompt=prompt, max_new_tokens=5)])
    (rp,) = paged.run([Request(rid=0, prompt=prompt, max_new_tokens=5)])
    assert paged.stats.prefill_chunks == 3
    assert rd.generated == rp.generated


def test_shared_prefix_cow_matches_cold_run():
    """The acceptance path: request B shares a (non-block-aligned) prefix
    with finished request A — B skips prefill for the shared portion, clones
    the straddling block copy-on-write, and still generates exactly what a
    cold engine generates."""
    _, model, params = _tiny_model()
    rng = np.random.RandomState(7)
    shared = rng.randint(1, 400, 20).tolist()      # 2.5 blocks of 8
    tail_a = rng.randint(1, 400, 7).tolist()
    tail_b = rng.randint(1, 400, 9).tolist()

    engine = ServeEngine(model, params, slots=2, max_len=64, kv_block_size=8)
    (ra,) = engine.run([Request(rid=0, prompt=shared + tail_a,
                                max_new_tokens=4)])
    (rb,) = engine.run([Request(rid=1, prompt=shared + tail_b,
                                max_new_tokens=4)])
    s = engine.stats.summary()["kv"]
    assert s["prefix_hits"] == 1
    assert s["prefix_tokens_reused"] == 20         # 2 full blocks + 4 COW
    assert s["blocks_copied"] == 1
    # fewer prompt tokens computed than submitted
    assert engine.stats.prefill_tokens_computed \
        < engine.stats.prefill_prompt_tokens

    cold = ServeEngine(model, params, slots=2, max_len=64, kv_block_size=8)
    (rc,) = cold.run([Request(rid=1, prompt=shared + tail_b,
                              max_new_tokens=4)])
    assert rb.generated == rc.generated
    dense = ServeEngine(model, params, slots=2, max_len=64)
    (rd,) = dense.run([Request(rid=1, prompt=shared + tail_b,
                               max_new_tokens=4)])
    assert rb.generated == rd.generated


def test_finish_never_publishes_the_unwritten_last_token():
    """Regression: the last generated token is sampled but never fed back
    through decode, so its KV is never written.  A finished block-aligned
    sequence (prompt + generated divisible by the block size) must not
    publish its final block, or a prompt extending the full sequence would
    attend to a garbage position on the prefix hit."""
    _, model, params = _tiny_model()
    bs = 8
    prompt = np.random.RandomState(21).randint(1, 400, 12).tolist()
    engine = ServeEngine(model, params, slots=2, max_len=64,
                         kv_block_size=bs)
    (ra,) = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=4)])
    total = len(prompt) + len(ra.generated)
    assert total % bs == 0                   # the dangerous alignment
    # only the WRITTEN prefix (total - 1 tokens) may be published: the final
    # block would expose one never-written KV position
    assert len(engine.kv.tree) == (total - 1) // bs
    follow = prompt + ra.generated + [7, 9, 11]
    (rb,) = engine.run([Request(rid=1, prompt=follow, max_new_tokens=4)])
    cold = ServeEngine(model, params, slots=2, max_len=64, kv_block_size=bs)
    (rc,) = cold.run([Request(rid=1, prompt=follow, max_new_tokens=4)])
    assert rb.generated == rc.generated


def test_prefix_cache_disabled_on_non_attention_models():
    """Hybrid/recurrent stacks have state the block pool can't share — the
    prefix cache must disable itself rather than corrupt outputs."""
    for arch in ["recurrentgemma-2b", "falcon-mamba-7b"]:
        _, model, params = _tiny_model(arch)
        eng = ServeEngine(model, params, slots=1, max_len=32, kv_block_size=8,
                          prefix_cache=True)
        assert not eng.kv.prefix_enabled
    _, model, params = _tiny_model("qwen3-0.6b")
    eng = ServeEngine(model, params, slots=1, max_len=32, kv_block_size=8)
    assert eng.kv.prefix_enabled


def test_paged_engine_rejects_misaligned_block_size():
    _, model, params = _tiny_model()
    with pytest.raises(ValueError, match="multiple"):
        ServeEngine(model, params, slots=1, max_len=30, kv_block_size=8)


def test_same_tick_block_release_while_neighbor_decodes():
    """Regression for the reclamation bug: a finished request's blocks free
    the same tick it retires, even while another slot keeps decoding —
    observable via submit()+step() as a drop in kv_blocks_in_use."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=64, kv_block_size=8,
                         max_prefill_per_step=2)
    long_req = Request(rid=0, prompt=[3, 4, 5], max_new_tokens=12)
    short = Request(rid=1, prompt=list(range(1, 18)), max_new_tokens=4)
    engine.submit(long_req)
    engine.submit(short)
    engine.step()
    in_use_both = engine.stats.kv_blocks_in_use
    assert in_use_both >= 3 + 1            # 17 tokens = 3 blocks, + 1
    while not short.done:
        engine.step()
    # the tick that finished `short` already reflects the release: only the
    # long request's blocks remain referenced
    assert engine.stats.kv_blocks_in_use < in_use_both
    assert engine.stats.kv_blocks_in_use == engine.kv.in_use
    while not long_req.done:
        engine.step()
    assert engine.stats.kv_blocks_in_use == 0


def test_paged_pool_exhaustion_raises_not_spins():
    """Two slots sharing a one-slot-worst-case pool: when both grow past the
    supply and neither can retire, the engine must fail loudly, not spin."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=32, kv_block_size=8,
                         kv_blocks=4, max_prefill_per_step=2)
    reqs = [Request(rid=i, prompt=list(range(1 + 9 * i, 14 + 9 * i)),
                    max_new_tokens=25) for i in range(2)]
    for r in reqs:
        engine.submit(r)
    with pytest.raises(RuntimeError, match="KV pool exhausted"):
        for _ in range(60):
            engine.step()


def test_paged_pool_floor_rejected_at_construction():
    """A pool smaller than one request's worst case would livelock admission
    of a long prompt — refuse it up front."""
    _, model, params = _tiny_model()
    with pytest.raises(ValueError, match="worst case"):
        ServeEngine(model, params, slots=1, max_len=32, kv_block_size=8,
                    kv_blocks=2)


def test_admit_does_not_count_pinned_cached_blocks_as_supply():
    """Regression: the shared blocks a plan pins (and the COW source) stop
    being evictable once retained — admit must requeue, not assert-crash,
    when the fresh allocations can't be covered without them."""
    mgr = PagedKVManager(slots=2, max_len=24, block_size=4, num_blocks=6)
    donor = list(range(100, 112))            # 3 blocks
    mgr.admit(0, donor)
    mgr.finish(0, donor)                     # 3 cached blocks, 3 free
    assert mgr.admit(1, list(range(200, 212))) is not None  # takes the 3 free
    # pool: 3 referenced (slot 1), 3 cached matching `donor`'s prefix.
    # a donor-prefixed prompt needing a fresh tail block must requeue —
    # the 3 cached blocks it would pin are not allocatable supply
    assert mgr.admit(0, donor + [7, 7, 7, 7]) is None
    assert mgr.owned[0] == 0 and mgr.in_use == 3


def test_paged_warmup_closes_program_inventory():
    """Paged engines: warmup compiles every (batch-bucket, bucket) prefill,
    the chunk continuation, the block-clone program, and decode; a trace
    with prefix hits, COW, chunked long prompts, and refills adds zero
    compile-cache entries."""
    _, model, params = _tiny_model()
    engine = ServeEngine(model, params, slots=2, max_len=128,
                         buckets=(16, 32), prefill_chunk=32,
                         max_prefill_per_step=2, max_prefill_batch=2,
                         kv_block_size=16)
    engine.warmup()
    warm_p = engine.stats.prefill_compiles
    warm_d = engine.stats.decode_compiles
    # 2 buckets x batch buckets (1, 2) + chunk + copy programs
    assert warm_p == 6
    assert warm_d == 1
    rng = np.random.RandomState(2)
    base = rng.randint(1, 400, 40).tolist()
    reqs = [Request(rid=i, prompt=rng.randint(1, 400, n).tolist(),
                    max_new_tokens=3)
            for i, n in enumerate([4, 9, 20, 30, 50, 100, 7, 25])]
    engine.run(reqs)
    # sequential runs so the second base-prefix request deterministically
    # sees the first one's published blocks (prefix hit + COW + chunk)
    engine.run([Request(rid=100, prompt=base + [7, 8], max_new_tokens=3)])
    engine.run([Request(rid=101, prompt=base + [9, 1, 2], max_new_tokens=3)])
    assert all(r.done for r in reqs)
    assert engine.stats.summary()["kv"]["prefix_hits"] >= 1
    assert engine.stats.prefill_compiles == warm_p    # zero recompiles
    assert engine.stats.decode_compiles == warm_d


def test_warmup_after_serving_drops_stale_prefix_cache():
    """warmup() re-zeroes the device pool, so every cached prefix describing
    the old contents must be forgotten — a post-warmup request must NOT hit
    blocks that no longer hold its KV."""
    _, model, params = _tiny_model()
    prompt = np.random.RandomState(1).randint(1, 400, 20).tolist()
    engine = ServeEngine(model, params, slots=2, max_len=64, kv_block_size=8)
    (r0,) = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=4)])
    assert engine.kv.cached > 0              # published blocks are cached
    engine.warmup()
    assert engine.kv.cached == 0 and len(engine.kv.tree) == 0
    (r1,) = engine.run([Request(rid=1, prompt=prompt, max_new_tokens=4)])
    assert r1.generated == r0.generated      # cold-served, identical output


# ------------------------------------------------------------------ sampling
def test_greedy_requests_unchanged_by_sampling_support():
    """Default (temperature 0) requests on an engine that also serves
    stochastic ones generate exactly the greedy reference."""
    _, model, params = _tiny_model()
    prompts = [[5, 9, 2], [7, 1, 4, 2], [3, 3, 8]]
    greedy_ref = ServeEngine(model, params, slots=3, max_len=32)
    ref = greedy_ref.run([Request(rid=i, prompt=p, max_new_tokens=4)
                          for i, p in enumerate(prompts)])
    mixed = ServeEngine(model, params, slots=3, max_len=32)
    out = mixed.run([
        Request(rid=0, prompt=prompts[0], max_new_tokens=4),
        Request(rid=1, prompt=prompts[1], max_new_tokens=4,
                temperature=1.3, top_k=5, seed=11),
        Request(rid=2, prompt=prompts[2], max_new_tokens=4)])
    assert out[0].generated == ref[0].generated
    assert out[2].generated == ref[2].generated


def test_sampled_requests_reproducible_and_seed_sensitive():
    _, model, params = _tiny_model()

    def run_once(seed):
        eng = ServeEngine(model, params, slots=1, max_len=32)
        (r,) = eng.run([Request(rid=0, prompt=[5, 9, 2], max_new_tokens=8,
                                temperature=1.0, seed=seed)])
        return r.generated

    a, b = run_once(7), run_once(7)
    assert a == b                            # same seed -> same stream
    seqs = {tuple(run_once(s)) for s in range(6)}
    assert len(seqs) > 1                     # seeds actually matter


def test_sample_tokens_semantics():
    rng = np.random.RandomState(0)
    B, V = 4, 40
    logits = jnp.asarray(rng.randn(B, V).astype(np.float32))
    zf = jnp.zeros((B,))
    zi = jnp.zeros((B,), jnp.int32)
    ones = jnp.ones((B,))
    pos = jnp.arange(B, dtype=jnp.int32)
    argmax = np.asarray(jnp.argmax(logits, -1))
    # temperature 0 rows: exact argmax
    np.testing.assert_array_equal(
        np.asarray(sample_tokens(logits, zf, zi, ones, zi, pos)), argmax)
    # top_k=1 and tiny top_p degenerate to argmax under any temperature
    np.testing.assert_array_equal(
        np.asarray(sample_tokens(logits, 2 * ones,
                                 jnp.full((B,), 1, jnp.int32), ones, zi,
                                 pos)), argmax)
    np.testing.assert_array_equal(
        np.asarray(sample_tokens(logits, 2 * ones, zi,
                                 jnp.full((B,), 1e-6), zi, pos)), argmax)
    # top-k=5 sampling stays inside the top-5 set and is deterministic
    top5 = np.argsort(-np.asarray(logits), axis=-1)[:, :5]
    for p in range(10):
        o = sample_tokens(logits, 3 * ones, jnp.full((B,), 5, jnp.int32),
                          ones, zi, jnp.full((B,), p, jnp.int32))
        o2 = sample_tokens(logits, 3 * ones, jnp.full((B,), 5, jnp.int32),
                           ones, zi, jnp.full((B,), p, jnp.int32))
        np.testing.assert_array_equal(np.asarray(o), np.asarray(o2))
        for b in range(B):
            assert int(o[b]) in top5[b]


def test_sampling_in_chunked_and_prefix_paths_reproducible():
    """The first token of a chunked (and prefix-hit) prefill samples from
    the same (seed, position) stream as the bucketed path: same request,
    same stream, regardless of which program produced it."""
    _, model, params = _tiny_model()
    prompt = np.random.RandomState(5).randint(1, 400, 45).tolist()
    chunked = ServeEngine(model, params, slots=1, max_len=128,
                          buckets=(16,), prefill_chunk=16)
    (rc,) = chunked.run([Request(rid=0, prompt=prompt, max_new_tokens=5,
                                 temperature=0.8, seed=3)])
    one_shot = ServeEngine(model, params, slots=1, max_len=128)
    (ro,) = one_shot.run([Request(rid=0, prompt=prompt, max_new_tokens=5,
                                  temperature=0.8, seed=3)])
    assert rc.generated == ro.generated


# ------------------------------------------------------------ paged init API
def test_init_paged_kv_cache_shapes():
    c = init_paged_kv_cache(3, 10, 8, 2, 16)
    assert c.k.shape == (10, 8, 2, 16) and c.v.shape == (10, 8, 2, 16)
    assert c.length.shape == (3,)
