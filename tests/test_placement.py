"""Placement-oracle tests: characterize -> cluster -> cost -> policy.

Pins down (a) the expected cluster structure for the three served state
families, (b) pure/deterministic policy resolution, (c) backend gating —
Pallas variants only where they lower natively, so CPU CI's auto plan is
the fixed engine, (d) engine integration: ``--policy auto`` generates
tokens bitwise-identical to the fixed-knob engine with zero recompiles
after warmup, and the stats placement section survives resets, (e) the
kernel-variant switches themselves: every ``impl="pallas"`` route through
the model entry points matches its XLA reference numerically (interpret
mode on CPU), and (f) k-means determinism including the degenerate
all-points-coincident input a pure-attention stack produces.
"""
import json
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.executor import RUNTIME_SAFE_KEYS, phase_profiles
from repro.serve.placement import (
    ExecutionOracle,
    PlacementPlan,
    fixed_plan,
    verify_kmeans_agreement,
)

GEOM = dict(slots=4, max_len=256, max_bucket=64)


# ------------------------------------------------------------ cluster shape
def test_oracle_qwen3_clusters():
    plan = ExecutionOracle(get_config("qwen3-0.6b"), backend="cpu",
                           **GEOM).resolve()
    # pure full-attention stack: every layer lands in cluster 2 (pascal),
    # embeddings + FC in cluster 3 (pavlov)
    assert set(plan.layer_clusters) == {2}
    assert set(plan.layer_kinds) == {"attn"}
    assert plan.policy_for("attn").accelerator == "pascal"
    assert plan.policy_for("ffn").cluster == 3
    assert plan.policy_for("ffn").accelerator == "pavlov"
    assert plan.policy_for("embed").cluster == 3
    assert plan.rule_kmeans_agreement > 0.9
    assert plan.buckets == (16, 32, 64) and plan.prefill_chunk == 64


def test_oracle_recurrentgemma_clusters():
    plan = ExecutionOracle(get_config("recurrentgemma-2b"), backend="cpu",
                           **GEOM).resolve()
    # Griffin interleave: local-attention layers cluster 2, RG-LRU layers
    # with the big recurrent footprint land in cluster 3 alongside FC
    assert set(plan.layer_clusters) == {2, 3}
    assert set(plan.layer_kinds) == {"local", "rec"}
    assert plan.policy_for("local").cluster == 2
    assert plan.policy_for("rec").cluster == 3
    assert plan.policy_for("rec").accelerator == "pavlov"
    assert plan.rule_kmeans_agreement > 0.6


def test_oracle_falcon_mamba_clusters():
    plan = ExecutionOracle(get_config("falcon-mamba-7b"), backend="cpu",
                           **GEOM).resolve()
    # homogeneous SSM stack: one cluster, one policy covering ssm + embed
    assert set(plan.layer_clusters) == {3}
    assert plan.policy_for("ssm").accelerator == "pavlov"
    assert plan.rule_kmeans_agreement > 0.9


# ------------------------------------------------------- purity/determinism
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "recurrentgemma-2b",
                                  "falcon-mamba-7b"])
def test_resolution_is_deterministic(arch):
    cfg = get_config(arch)
    a = ExecutionOracle(cfg, backend="cpu", **GEOM).resolve()
    b = ExecutionOracle(cfg, backend="cpu", **GEOM).resolve()
    assert a == b                      # frozen dataclasses, full deep equality
    assert a.dumps() == b.dumps()
    assert json.loads(a.dumps())["arch"] == cfg.name


def test_predictions_are_positive_and_phase_ordered():
    plan = ExecutionOracle(get_config("qwen3-0.6b"), backend="cpu",
                           **GEOM).resolve()
    assert plan.predicted_prefill_s > 0 and plan.predicted_decode_s > 0
    # a full 64-token chunk must cost more than one decode step
    assert plan.predicted_prefill_s > plan.predicted_decode_s


# ---------------------------------------------------------- backend gating
def test_cpu_backend_resolves_to_xla():
    for arch in ("qwen3-0.6b", "recurrentgemma-2b", "falcon-mamba-7b"):
        plan = ExecutionOracle(get_config(arch), backend="cpu",
                               **GEOM).resolve()
        assert plan.prefill_cfg_overrides == {}
        assert plan.decode_cfg_overrides == {}
        assert all(p.kernel == "xla" for p in plan.policies)


def test_tpu_backend_picks_pallas_variants():
    plan = ExecutionOracle(get_config("qwen3-0.6b"), backend="tpu",
                           **GEOM).resolve()
    assert plan.prefill_cfg_overrides == {"attn_impl": "pallas"}
    assert plan.decode_cfg_overrides == {"attn_impl": "pallas"}
    plan = ExecutionOracle(get_config("recurrentgemma-2b"), backend="tpu",
                           **GEOM).resolve()
    assert plan.decode_cfg_overrides == {"attn_impl": "pallas",
                                         "rglru_impl": "pallas"}
    # the serving SSM path needs h_last, which the fused kernel doesn't
    # return — the oracle must never pick it for serving
    plan = ExecutionOracle(get_config("falcon-mamba-7b"), backend="tpu",
                           **GEOM).resolve()
    assert plan.decode_cfg_overrides == {}
    assert "ssm_impl" not in plan.prefill_cfg_overrides


def test_overrides_are_runtime_safe():
    for arch in ("qwen3-0.6b", "recurrentgemma-2b", "falcon-mamba-7b"):
        plan = ExecutionOracle(get_config(arch), backend="tpu",
                               **GEOM).resolve()
        assert set(plan.prefill_cfg_overrides) <= RUNTIME_SAFE_KEYS
        assert set(plan.decode_cfg_overrides) <= RUNTIME_SAFE_KEYS


# --------------------------------------------------- phase-profile merging
def test_phase_profiles_merge_policy_overrides():
    cfg = get_config("qwen3-0.6b")
    plan = PlacementPlan(arch=cfg.name, source="auto", backend="tpu",
                         prefill_overrides=(("attn_impl", "pallas"),),
                         decode_overrides=(("attn_impl", "pallas"),))
    pre, dec = phase_profiles(cfg, policy=plan)
    assert pre.cfg_overrides["attn_impl"] == "pallas"
    assert dec.cfg_overrides["attn_impl"] == "pallas"
    assert pre.apply(cfg, runtime_only=True).attn_impl == "pallas"


def test_phase_profiles_reject_unsafe_policy_keys():
    cfg = get_config("qwen3-0.6b")
    bad = PlacementPlan(arch=cfg.name, source="auto", backend="cpu",
                        decode_overrides=(("d_model", "128"),))
    with pytest.raises(ValueError, match="not runtime-safe"):
        phase_profiles(cfg, policy=bad)


def test_fixed_plan_records_knobs_and_decides_nothing():
    cfg = get_config("qwen3-0.6b")
    plan = fixed_plan(cfg, buckets=(16, 32), prefill_chunk=32)
    assert plan.source == "fixed" and plan.policies == ()
    assert plan.prefill_cfg_overrides == {}
    assert plan.summary()["buckets"] == [16, 32]
    assert plan.policy_for("attn") is None


# ------------------------------------------------------- k-means hardening
def _char(footprint, flop_per_byte, macs):
    return SimpleNamespace(sched_param_bytes=footprint,
                           sched_flop_per_byte=flop_per_byte,
                           sched_macs=macs)


def test_kmeans_is_seed_deterministic():
    from repro.core.clustering import kmeans_cluster
    chars = [_char(10e3 * (i + 1), 100.0 / (i + 1), 1e6 * (i + 1))
             for i in range(12)]
    la, _ = kmeans_cluster(chars, seed=0)
    lb, _ = kmeans_cluster(chars, seed=0)
    assert np.array_equal(la, lb)


def test_kmeans_survives_coincident_points():
    # a pure-attention stack characterizes every layer identically: all
    # pairwise distances are zero and the k-means++ weighted draw is
    # undefined — this used to crash np.random.choice
    from repro.core.clustering import kmeans_cluster
    chars = [_char(64e3, 120.0, 30e6)] * 8
    la, _ = kmeans_cluster(chars, seed=0)
    lb, _ = kmeans_cluster(chars, seed=0)
    assert np.array_equal(la, lb)
    assert len(set(la.tolist())) == 1   # coincident points, one cluster


@pytest.mark.parametrize("arch,floor", [("qwen3-0.6b", 0.9),
                                        ("recurrentgemma-2b", 0.6),
                                        ("falcon-mamba-7b", 0.9)])
def test_rule_vs_kmeans_agreement(arch, floor):
    score = verify_kmeans_agreement(get_config(arch), max_len=256,
                                    min_agreement=floor)
    assert score >= floor


# ------------------------------------------------------ engine integration
def _tiny(arch="qwen3-0.6b"):
    cfg = reduced_config(arch)
    return cfg.replace(num_layers=max(2, len(cfg.block_pattern)))


def test_engine_constructor_knobs_beat_policy():
    from repro.models import build_model
    from repro.serve.engine import ServeEngine
    cfg = _tiny()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = ExecutionOracle(cfg, slots=2, max_len=64, max_bucket=32,
                           backend="cpu").resolve()
    # explicit constructor geometry wins over the plan's
    eng = ServeEngine(model, params, slots=2, max_len=64, buckets=(16,),
                      prefill_chunk=16, policy=plan)
    assert eng.buckets == (16,) and eng.prefill_chunk == 16
    # without explicit knobs the plan's geometry is adopted
    eng = ServeEngine(model, params, slots=2, max_len=64, policy=plan)
    assert eng.buckets == plan.buckets
    assert eng.prefill_chunk == plan.prefill_chunk


def test_policy_auto_token_identity_and_stats():
    from repro.launch.serve import build_engine
    from repro.models import build_model
    from repro.serve.engine import Request
    cfg = _tiny()
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    def trace():
        rng = np.random.RandomState(5)
        return [Request(rid=i,
                        prompt=rng.randint(1, cfg.vocab_size,
                                           6 + 9 * i).tolist(),
                        max_new_tokens=6) for i in range(3)]

    def run(policy):
        eng = build_engine(cfg, params, slots=2, max_len=64, max_bucket=32,
                           policy=policy)
        eng.warmup()
        w = eng.stats.summary()
        eng.reset_stats()
        done = eng.run(trace())
        s = eng.stats.summary()
        rec = (s["prefill_compiles"] - w["prefill_compiles"]) \
            + (s["decode_compiles"] - w["decode_compiles"])
        return [r.generated for r in done], s, rec

    fixed_toks, fixed_s, _ = run("fixed")
    auto_toks, auto_s, auto_rec = run("auto")
    assert auto_toks == fixed_toks
    assert auto_rec == 0
    # the stats placement section: plan summary + measured phase times,
    # surviving the reset_stats() between warmup and the measured run
    p = auto_s["placement"]
    assert p["source"] == "auto" and p["policies"]
    assert p["measured"]["decode_step_s"] > 0
    assert p["predicted"]["decode_step_s"] > 0
    assert fixed_s["placement"]["source"] == "fixed"


def test_build_engine_rejects_unknown_policy():
    from repro.launch.serve import build_engine
    cfg = _tiny()
    with pytest.raises(ValueError, match="policy"):
        build_engine(cfg, slots=2, max_len=64, policy="oracle")


# ------------------------------------------- kernel-variant switch numerics
def test_flash_attention_impl_switch_matches_xla():
    from repro.models.attention import flash_attention
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(2, 32, 4, 16), jnp.float32)
    k = jnp.asarray(rng.randn(2, 32, 2, 16), jnp.float32)
    v = jnp.asarray(rng.randn(2, 32, 2, 16), jnp.float32)
    for window in (0, 16):
        ref = flash_attention(q, k, v, causal=True, window=window)
        out = flash_attention(q, k, v, causal=True, window=window,
                              impl="pallas")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_rglru_impl_switch_matches_xla():
    from repro.models.recurrent import rglru_core
    rng = np.random.RandomState(1)
    d = 32
    params = {
        "w_a": jnp.asarray(rng.randn(d, d) * 0.05, jnp.float32),
        "w_i": jnp.asarray(rng.randn(d, d) * 0.05, jnp.float32),
        "lambda": jnp.asarray(rng.randn(d), jnp.float32),
    }
    x = jnp.asarray(rng.randn(2, 24, d) * 0.1, jnp.float32)
    h0 = jnp.asarray(rng.randn(2, d) * 0.1, jnp.float32)
    mask = jnp.asarray(np.arange(24)[None, :] < np.array([[24], [17]]))
    ref_h, ref_last = rglru_core(params, x, h0=h0, seq_mask=mask)
    out_h, out_last = rglru_core(params, x, h0=h0, seq_mask=mask,
                                 impl="pallas")
    np.testing.assert_allclose(np.asarray(out_h), np.asarray(ref_h),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(out_last), np.asarray(ref_last),
                               atol=2e-5, rtol=2e-5)


def test_model_forward_with_pallas_overrides_matches_xla():
    """End-to-end: a reduced model lowered with the oracle's TPU override
    set must produce the same logits as the XLA reference (interpret mode
    executes the kernels on CPU)."""
    from repro.models import build_model
    for arch in ("qwen3-0.6b", "recurrentgemma-2b"):
        cfg = _tiny(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = jnp.asarray(
            np.random.RandomState(2).randint(1, cfg.vocab_size, (2, 32)))
        ref, _ = model.forward(params, tokens)
        plan = ExecutionOracle(cfg, slots=2, max_len=64, max_bucket=32,
                               backend="tpu").resolve()
        fast_cfg = cfg.replace(**plan.prefill_cfg_overrides)
        out, _ = build_model(fast_cfg).forward(params, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=5e-4, rtol=5e-4)
