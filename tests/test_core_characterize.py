"""Unit tests for layer characterization (paper §3.2)."""

import pytest

from repro.core import (LayerKind, LayerSpec, characterize_layer,
                        characterize_model, variation_report)
from repro.edge import edge_zoo


def _conv(hw=56, cin=64, cout=64, k=3, s=1):
    return LayerSpec(name="c", kind=LayerKind.CONV2D, in_hw=hw, in_ch=cin,
                     out_ch=cout, kernel=k, stride=s)


def test_conv_macs_and_params():
    spec = _conv()
    assert spec.param_count == 3 * 3 * 64 * 64
    assert spec.macs == 56 * 56 * 64 * 9 * 64
    c = characterize_layer("m", 0, spec)
    # stride-1 3x3 conv FLOP/B (int8) is exactly 2 * HW^2
    assert c.param_flop_per_byte == pytest.approx(2 * 56 * 56)


def test_depthwise_params_small():
    spec = LayerSpec(name="d", kind=LayerKind.DWCONV2D, in_hw=14, in_ch=384,
                     kernel=3)
    assert spec.param_count == 9 * 384
    assert spec.macs == 14 * 14 * 384 * 9


def test_lstm_gate_granularity():
    # paper: each gate has ~2.1M params on average; clustering sees per-gate
    spec = LayerSpec(name="l", kind=LayerKind.LSTM, in_features=1024,
                     hidden=1024, seq_len=100)
    assert spec.param_count == 4 * (1024 * 1024 + 1024 * 1024)
    c = characterize_layer("m", 0, spec)
    assert c.sched_param_bytes == pytest.approx(spec.param_bytes / 4)
    # per-gate-per-step MACs = in*h + h*h
    assert c.sched_macs == pytest.approx(2 * 1024 * 1024)
    # parameters are touched once per step: FLOP/B == 2 (2 FLOPs per MAC, int8)
    assert c.sched_flop_per_byte == pytest.approx(2.0)
    assert c.recurrent


def test_fc_flopb_is_two():
    spec = LayerSpec(name="f", kind=LayerKind.FC, in_features=1024,
                     out_features=1000)
    c = characterize_layer("m", 0, spec)
    assert c.param_flop_per_byte == pytest.approx(2.0)


def test_lstm_footprint_up_to_70m_params():
    # paper: LSTM layer footprints reach 70M parameters
    zoo = edge_zoo()
    biggest = max(l.param_count for g in zoo for l in g.layers
                  if l.kind is LayerKind.LSTM)
    assert 50e6 <= biggest <= 80e6


def test_intra_model_variation_orders_of_magnitude():
    """Paper: MACs vary 200x and FLOP/B 244x within single models."""
    chars = []
    for g in edge_zoo():
        chars.extend(characterize_model(g))
    rep = variation_report(chars)
    max_flopb = max(v["flopb_variation_x"] for v in rep.values())
    max_macs = max(v["mac_variation_x"] for v in rep.values())
    assert max_flopb >= 200.0
    assert max_macs >= 200.0


def test_avg_lstm_transducer_layer_footprint():
    """Paper: LSTM/Transducer layers average ~33.4 MB parameter footprint."""
    zoo = edge_zoo()
    foot = [l.param_bytes for g in zoo if g.family in ("lstm", "transducer")
            for l in g.layers if l.kind is LayerKind.LSTM]
    avg_mb = sum(foot) / len(foot) / (1024 * 1024)
    assert 15.0 <= avg_mb <= 50.0
