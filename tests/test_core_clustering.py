"""Clustering tests (paper §5.1): five clusters, high coverage, k-means agreement."""
import collections

from repro.core import (agreement, characterize_zoo, cluster_all, rule_cluster,
                        strict_fraction)
from repro.core.layerspec import LayerKind
from repro.edge import edge_zoo


def _chars():
    return characterize_zoo(edge_zoo())


def test_all_layers_assigned_1_to_5():
    for c in _chars():
        cl = rule_cluster(c).cluster
        assert 1 <= cl <= 5


def test_five_clusters_all_populated():
    counts = collections.Counter(a.cluster for a in cluster_all(_chars()))
    assert set(counts) == {1, 2, 3, 4, 5}
    for cid, n in counts.items():
        assert n >= 5, f"cluster {cid} nearly empty ({n})"


def test_coverage_fraction():
    """Paper: 97% of layers group into the five clusters. The published bounds
    are rounded descriptors; with a modest pad they cover >=90% of weighty
    layers, literal boxes >=30%."""
    chars = _chars()
    assert strict_fraction(chars, pad=1.0) >= 0.30
    assert strict_fraction(chars, pad=2.5) >= 0.70
    assert strict_fraction(chars, pad=4.0) >= 0.85


def test_structural_priors():
    chars = _chars()
    for c in chars:
        cl = rule_cluster(c).cluster
        if c.kind is LayerKind.LSTM:
            assert cl == 3, f"LSTM layer {c.name} -> cluster {cl}"
        if c.kind is LayerKind.DWCONV2D:
            assert cl == 5, f"depthwise layer {c.name} -> cluster {cl}"


def test_kmeans_agreement_with_rules():
    """k-means on log-features should substantially agree with the rule
    clusters — the structure is in the data (paper's 'natural grouping')."""
    chars = [c for c in _chars() if c.param_bytes > 256 and c.macs > 0]
    assert agreement(chars) >= 0.55


def test_clusters_match_paper_populations():
    """C1/2 are convs, C3 recurrent/FC, C5 depthwise-dominated."""
    chars = _chars()
    kinds_by_cluster = collections.defaultdict(collections.Counter)
    for c in chars:
        kinds_by_cluster[rule_cluster(c).cluster][c.kind] += 1
    c5 = kinds_by_cluster[5]
    assert c5[LayerKind.DWCONV2D] >= 0.5 * sum(c5.values())
    c3 = kinds_by_cluster[3]
    rec_fc = c3[LayerKind.LSTM] + c3[LayerKind.FC] + c3[LayerKind.EMBEDDING]
    assert rec_fc >= 0.8 * sum(c3.values())
