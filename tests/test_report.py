"""Golden-rendering tests for benchmarks/report.py's serve-side subcommands.

``trace`` and ``ledger`` render review-pasteable markdown from artifacts the
serving stack writes (a Chrome trace, the perf ledger); these tests pin the
exact rendering over tiny committed fixtures in tests/data/ — stdlib-only
for ``trace``; ``ledger`` pulls in ``repro.obs.ledger`` (also stdlib-only),
never jax.
"""
import importlib.util
import json
import shutil
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DATA = REPO / "tests" / "data"


def _report_mod():
    spec = importlib.util.spec_from_file_location(
        "bench_report", REPO / "benchmarks" / "report.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


report = _report_mod()


def test_trace_table_golden():
    out = report.trace_table(DATA / "serve_trace_tiny.json")
    assert out == "\n".join([
        "| rid | slot | prompt | prefix hit | queue ms | prefill ms "
        "| chunks | span ms | tokens | stalls |",
        "|---|---|---|---|---|---|---|---|---|---|",
        "| 0 | slot 0 | 5 | 0 | 2.0 | 3.0 | 0 | 8.0 | 4 | 0 |",
        "| 1 | slot 1 | 40 | 16 | 0.0 | 3.0 | 2 | 10.0 | 6 | 1 |",
    ])


def test_trace_table_reports_ring_drops(tmp_path):
    doc = json.loads((DATA / "serve_trace_tiny.json").read_text())
    doc["otherData"]["dropped_events"] = 9
    p = tmp_path / "t.json"
    p.write_text(json.dumps(doc))
    out = report.trace_table(p)
    assert "9 events dropped by the ring buffer" in out


def test_ledger_table_golden():
    out = report.ledger_table(DATA / "perf_ledger_tiny.jsonl")
    lines = out.splitlines()
    assert lines[:5] == [
        "| run | git sha | arch | tokens/s | TTFT p50 ms | prefix hit "
        "| trace ovh | recompiles |",
        "|---|---|---|---|---|---|---|---|",
        "| 1 | deadbeef0 | qwen3-0.6b | 1000.0 | 20.0 | 0.55 | 0.010 | 0 |",
        "| 2 | cafe00441 | qwen3-0.6b | 1010.0 | 19.0 | 0.55 | 0.020 | 0 |",
        "| 3 | beefbeef9 | qwen3-0.6b | 990.0 | 21.0 | 0.55 | 0.015 | 0 |",
    ]
    # newest record vs the rolling median of its two predecessors
    assert lines[-1] == ("trend (3 runs, band 50%): ok — "
                         "tokens_per_s 990.0 vs median 1005.0, "
                         "ttft_p50_ms 21.0 vs median 19.5")


def test_ledger_table_flags_regression(tmp_path):
    p = tmp_path / "ledger.jsonl"
    shutil.copy(DATA / "perf_ledger_tiny.jsonl", p)
    bad = {"arch": "qwen3-0.6b", "git_sha": "bad", "tokens_per_s": 100.0,
           "ttft_p50_ms": 21.0, "version": 1, "ts": 0.0}
    with p.open("a") as f:
        f.write(json.dumps(bad) + "\n")
    out = report.ledger_table(p)
    assert "REGRESSED" in out
    assert "| 4 | bad |" in out


def test_ledger_table_empty_path(tmp_path):
    out = report.ledger_table(tmp_path / "absent.jsonl")
    assert out.startswith("(no ledger at")


def test_ledger_cli_renders_committed_ledger():
    """`report.py ledger` end-to-end over the repo's committed ledger — the
    acceptance path: the results/perf_ledger.jsonl this repo ships must
    actually render."""
    import subprocess
    proc = subprocess.run(
        [sys.executable, str(REPO / "benchmarks" / "report.py"), "ledger",
         str(REPO / "results" / "perf_ledger.jsonl")],
        capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0, proc.stderr
    assert "### Perf ledger: run trajectory" in proc.stdout
    assert "| run | git sha |" in proc.stdout
    assert "trend (" in proc.stdout
