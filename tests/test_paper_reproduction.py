"""Validation of the paper's headline claims (§7) over our reconstructed zoo.

Tolerances are deliberately loose-but-meaningful: the 24 Google models are not
public, so our zoo is a reconstruction from the paper's published statistics;
we require every headline ratio to land in the right regime and the exact
values are reported side-by-side in EXPERIMENTS.md.
"""
import pytest

from repro.core import evaluate_zoo, summarize
from repro.edge import edge_zoo


@pytest.fixture(scope="module")
def summary():
    return summarize(evaluate_zoo(edge_zoo()))


def test_zoo_composition():
    zoo = edge_zoo()
    assert len(zoo) == 24
    fams = [g.family for g in zoo]
    assert fams.count("cnn") == 13
    assert fams.count("lstm") == 4
    assert fams.count("transducer") == 4
    assert fams.count("rcnn") == 3


def test_mensa_energy_reduction(summary):
    # paper: 66.0%
    assert 0.55 <= summary.energy_reduction_vs_baseline <= 0.75


def test_mensa_energy_efficiency(summary):
    # paper: 3.0x vs baseline, 2.4x vs Eyeriss v2
    assert 2.4 <= summary.energy_eff_x_vs_baseline <= 3.6
    assert 1.8 <= summary.energy_eff_x_vs_eyeriss <= 3.2


def test_mensa_throughput(summary):
    # paper: 3.1x vs baseline, 1.3x vs Base+HB, 4.3x vs Eyeriss v2
    assert 2.4 <= summary.throughput_x_vs_baseline <= 3.8
    assert 1.1 <= summary.throughput_x_vs_base_hb <= 1.6
    assert 3.2 <= summary.throughput_x_vs_eyeriss <= 6.5


def test_mensa_latency(summary):
    # paper: 1.96x vs baseline, 1.17x vs Base+HB
    assert 1.6 <= summary.latency_x_vs_baseline <= 3.2
    assert 1.05 <= summary.latency_x_vs_base_hb <= 1.45


def test_base_hb_alone_insufficient(summary):
    # paper: Base+HB reduces energy only 7.5% despite 2.5x throughput
    assert summary.base_hb_energy_reduction <= 0.20
    assert 1.7 <= summary.base_hb_throughput_x <= 3.0


def test_baseline_underutilization(summary):
    # paper: 27.3% average utilization; LSTMs/Transducers < 1%
    assert 0.15 <= summary.baseline_mean_utilization <= 0.40
    assert summary.lstm_transducer_baseline_util < 0.02


def test_lstm_transducer_gain(summary):
    # paper: 5.7x throughput for LSTMs/Transducers
    assert 4.0 <= summary.lstm_transducer_throughput_x <= 8.0
