"""Runtime substrate tests: data determinism/resume, checkpoint atomicity +
auto-resume, failure injection, watchdog, serving engine parity, optimizer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs import reduced_config
from repro.data.pipeline import DataConfig, PrefetchingLoader, SyntheticTokens
from repro.ft.watchdog import (FailureInjector, StepWatchdog,
                               run_with_restarts)
from repro.models import build_model
from repro.train import optim


# ---------------------------------------------------------------------- data
def test_data_deterministic_and_stateless():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    src = SyntheticTokens(cfg)
    b1 = src.batch(7)
    b2 = SyntheticTokens(cfg).batch(7)       # fresh instance, same step
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 32)
    assert b1["labels"].shape == (8, 32)
    # next-token alignment
    full = SyntheticTokens(DataConfig(1000, 33, 8)).batch(7)
    assert not np.array_equal(b1["tokens"], b1["labels"])


def test_data_steps_differ():
    src = SyntheticTokens(DataConfig(1000, 32, 8))
    assert not np.array_equal(src.batch(0)["tokens"], src.batch(1)["tokens"])


def test_data_host_sharding_partitions_global_batch():
    whole = SyntheticTokens(DataConfig(1000, 16, 8)).batch(3)["tokens"]
    parts = [SyntheticTokens(DataConfig(1000, 16, 8, num_hosts=4, host_id=h)
                             ).batch(3)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), whole)


def test_prefetch_loader_resume():
    src = SyntheticTokens(DataConfig(1000, 16, 4))
    loader = PrefetchingLoader(src, start_step=5, prefetch=2)
    step, batch = next(loader)
    assert step == 5
    np.testing.assert_array_equal(batch["tokens"], src.batch(5)["tokens"])
    loader.close()


# ---------------------------------------------------------------- checkpoint
def test_ckpt_roundtrip_and_latest(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((), jnp.int32)]}
    ckpt_lib.save(tmp_path, 10, tree)
    ckpt_lib.save(tmp_path, 20, jax.tree.map(lambda x: x + 1, tree))
    assert ckpt_lib.latest_step(tmp_path) == 20
    got = ckpt_lib.restore(tmp_path, 10, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(got["a"], tree["a"])
    assert got["b"][0].dtype == jnp.bfloat16


def test_ckpt_ignores_partial_writes(tmp_path):
    tree = {"x": jnp.ones((3,))}
    ckpt_lib.save(tmp_path, 5, tree)
    # simulate a crash mid-write at step 7: only a .tmp dir exists
    (tmp_path / "step_00000007.tmp").mkdir()
    (tmp_path / "step_00000007.tmp" / "junk").write_text("partial")
    assert ckpt_lib.latest_step(tmp_path) == 5


def test_ckpt_latest_falls_back_to_scan(tmp_path):
    tree = {"x": jnp.ones((3,))}
    ckpt_lib.save(tmp_path, 5, tree)
    (tmp_path / "LATEST").unlink()
    assert ckpt_lib.latest_step(tmp_path) == 5


# ------------------------------------------------------------ fault tolerance
def test_failure_injection_and_restart_resumes_exactly(tmp_path):
    """Loss trace with an injected failure + restart == uninterrupted trace."""
    from repro.launch.train import train_once
    cfg = reduced_config("smollm-135m").replace(num_layers=2)
    kw = dict(steps=12, global_batch=4, seq_len=32, ckpt_every=4,
              log_every=100)

    # uninterrupted reference
    ref = train_once(cfg, ckpt_dir=str(tmp_path / "ref"), **kw)

    # failure at step 9, restart from the step-8 checkpoint
    injector = FailureInjector(fail_at_step=9)
    metrics: list = []

    def once():
        train_once(cfg, ckpt_dir=str(tmp_path / "ft"), injector=injector,
                   metrics_out=metrics, **kw)

    restarts = run_with_restarts(once, max_restarts=2)
    assert restarts == 1
    final = dict(metrics)
    for step in (9, 10, 11):
        assert final[step] == pytest.approx(ref["losses"][step], rel=1e-5), \
            f"step {step}: resumed {final[step]} != reference {ref['losses'][step]}"


def test_watchdog_flags_persistent_straggler():
    wd = StepWatchdog(consecutive=3)
    for _ in range(20):
        wd.observe(1.0)
    assert wd.stragglers_detected == 0
    flagged = False
    for _ in range(4):
        flagged |= wd.observe(10.0)
    assert flagged and wd.stragglers_detected >= 1


def test_watchdog_tolerates_single_blip():
    wd = StepWatchdog(consecutive=3)
    for _ in range(20):
        wd.observe(1.0)
    assert not wd.observe(8.0)
    for _ in range(5):
        wd.observe(1.0)
    assert wd.stragglers_detected == 0


# ------------------------------------------------------------------ optimizer
def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    st = optim.adamw_init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, st = optim.adamw_update(g, st, params, lr=jnp.float32(0.05),
                                     weight_decay=0.0)
        params = optim.apply_updates(params, upd)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules_shapes():
    f = optim.cosine_schedule(1e-3, warmup=10, total=100)
    assert float(f(jnp.int32(0))) == 0.0
    assert float(f(jnp.int32(10))) == pytest.approx(1e-3)
    assert float(f(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)


# -------------------------------------------------------------------- serving
def test_serve_engine_batched_requests():
    from repro.serve.engine import Request, ServeEngine
    cfg = reduced_config("qwen3-0.6b").replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=5)
            for i in range(5)]
    done = engine.run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.generated) == 5 for r in done)


def test_serve_engine_matches_direct_decode():
    """Engine output == manual prefill+decode for a single request."""
    from repro.serve.engine import Request, ServeEngine
    cfg = reduced_config("qwen3-0.6b").replace(num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = [5, 9, 2, 7]
    n_new = 4

    engine = ServeEngine(model, params, slots=2, max_len=32)
    (req,) = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=n_new)])

    states = model.init_states(1, 32)
    logits, states, memory = model.prefill(
        params, jnp.asarray([prompt], jnp.int32), states)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, states = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), states,
            jnp.asarray([pos], jnp.int32), memory)
        toks.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    assert req.generated == toks


# -------------------------------------------------------- Level-B Mensa plan
def test_strategy_planner_outputs():
    from repro.core.strategy import plan
    from repro.configs import get_config
    p = plan(get_config("smollm-135m"), tokens=256 * 4096, batch=256,
             train=True, shape_name="train_4k")
    assert p.strategy_for("attn") == "pascal_dp"   # 9 heads % 16 != 0
    assert p.strategy_for("embed") == "jacquard_shard"
    p2 = plan(get_config("starcoder2-7b"), tokens=256 * 4096, batch=256,
              train=True)
    assert p2.strategy_for("ffn") == "pascal_tp"   # 7B replicated won't fit
    p3 = plan(get_config("phi3.5-moe-42b-a6.6b"), tokens=256 * 4096,
              batch=256, train=True)
    assert p3.strategy_for("moe") == "jacquard_shard"
    p4 = plan(get_config("falcon-mamba-7b"), tokens=256 * 4096, batch=256,
              train=True)
    assert p4.strategy_for("ssm") == "pavlov_seq"


def test_strategy_planner_clusters_match_paper_semantics():
    from repro.core.strategy import plan
    from repro.configs import get_config
    p = plan(get_config("falcon-mamba-7b"), tokens=256 * 4096, batch=256,
             train=True)
    ssm = [b for b in p.blocks if b.name == "ssm"][0]
    assert ssm.cluster == 3      # recurrent layers are the paper's Cluster 3
