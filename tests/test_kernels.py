"""Per-kernel allclose tests vs the pure-jnp oracles, swept over shapes and
dtypes, all in interpret mode on CPU.  The randomized sweeps run as seeded
``pytest.mark.parametrize`` cases (formerly hypothesis property tests) so
the suite collects offline with stdlib + jax only — see tests/conftest.py."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.jacquard_gemv import jacquard_gemv, jacquard_gemv_ref
from repro.kernels.paged_attention import (paged_decode_attention,
                                           paged_decode_attention_ref)
from repro.kernels.pascal_matmul import pascal_matmul, pascal_matmul_ref
from repro.kernels.pavlov_lstm import pavlov_lstm, pavlov_lstm_ref
from repro.kernels.pavlov_rglru import pavlov_rglru, pavlov_rglru_ref
from repro.kernels.pavlov_ssm import pavlov_ssm, pavlov_ssm_ref


def _rand(key, *shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def _tol(dtype):
    return dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------- pascal_matmul
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (64, 128, 64, 32, 32, 64),
    (100, 96, 50, 64, 32, 32),      # padding path
    (8, 256, 512, 8, 128, 128),
    (1, 64, 33, 8, 16, 64),         # degenerate M
])
def test_pascal_matmul(dtype, m, k, n, bm, bn, bk):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = _rand(k1, m, k, dtype=dtype)
    w = _rand(k2, k, n, dtype=dtype)
    out = pascal_matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
    ref = pascal_matmul_ref(x, w)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_pascal_matmul_batched_lead_dims():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = _rand(k1, 2, 3, 32, 64)
    w = _rand(k2, 64, 48)
    out = pascal_matmul(x, w, block_m=16, block_n=16, block_k=32)
    np.testing.assert_allclose(out, jnp.einsum("abmk,kn->abmn", x, w),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("seed", range(15))
def test_pascal_matmul_property(seed):
    rng = random.Random(seed)
    m = rng.randint(1, 40)
    k = rng.choice([32, 64, 96])
    n = rng.randint(1, 70)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k1, m, k)
    w = _rand(k2, k, n)
    out = pascal_matmul(x, w, block_m=16, block_n=16, block_k=32)
    np.testing.assert_allclose(out, pascal_matmul_ref(x, w),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------- jacquard_gemv
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [(1, 256, 512), (4, 1024, 300), (8, 96, 64)])
def test_jacquard_gemv(dtype, m, k, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = _rand(k1, m, k, dtype=dtype)
    w = _rand(k2, k, n, dtype=dtype)
    out = jacquard_gemv(x, w, block_n=128, block_k=256)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(jacquard_gemv_ref(x, w), np.float32),
                               **_tol(dtype))


# --------------------------------------------------------------- pavlov_lstm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h", [(2, 8, 16), (1, 20, 32), (4, 5, 64)])
def test_pavlov_lstm(dtype, b, t, h):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    xg = _rand(k1, b, t, 4 * h, dtype=dtype, scale=0.5)
    wh = _rand(k2, h, 4 * h, dtype=dtype, scale=0.3)
    out = pavlov_lstm_fused(xg, wh)
    ref = pavlov_lstm_ref(xg, wh)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


def pavlov_lstm_fused(xg, wh):
    from repro.kernels.pavlov_lstm.kernel import pavlov_lstm_raw
    from repro.kernels.common import use_interpret
    return pavlov_lstm_raw(xg, wh, interpret=use_interpret())


def test_pavlov_lstm_full_layer_matches_model_lstm():
    """ops.pavlov_lstm (decoupled GEMM + kernel) == models.recurrent.lstm_layer."""
    from repro.models.recurrent import init_lstm_layer, lstm_layer
    key = jax.random.PRNGKey(4)
    p = init_lstm_layer(key, 24, 16)
    x = _rand(jax.random.PRNGKey(5), 2, 10, 24, scale=0.5)
    ref, _ = lstm_layer(p, x)
    # model lstm adds +1.0 forget bias inside; kernel does the same
    out = pavlov_lstm(x, p["w_x"], p["w_h"], p["b"])
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


# -------------------------------------------------------------- pavlov_rglru
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,e,bt,be", [
    (2, 32, 64, 8, 32), (1, 16, 128, 16, 128), (3, 64, 32, 16, 32)])
def test_pavlov_rglru(dtype, b, t, e, bt, be):
    k1, k2 = jax.random.split(jax.random.PRNGKey(6))
    a = jax.nn.sigmoid(_rand(k1, b, t, e)).astype(dtype)   # decay in (0,1)
    bb = _rand(k2, b, t, e, dtype=dtype, scale=0.5)
    out = pavlov_rglru(a, bb, block_t=bt, block_e=be)
    ref = pavlov_rglru_ref(a, bb)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


@pytest.mark.parametrize("seed", range(15))
def test_pavlov_rglru_property(seed):
    rng = random.Random(1000 + seed)
    b = rng.randint(1, 3)
    t = rng.choice([8, 24, 48])
    e = rng.choice([16, 64])
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.nn.sigmoid(_rand(k1, b, t, e))
    bb = _rand(k2, b, t, e, scale=0.5)
    out = pavlov_rglru(a, bb, block_t=8, block_e=16)
    np.testing.assert_allclose(out, pavlov_rglru_ref(a, bb),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------- pavlov_ssm
@pytest.mark.parametrize("b,t,d,n,bt,bd", [
    (2, 16, 32, 4, 8, 16), (1, 32, 64, 8, 16, 64), (2, 8, 16, 16, 8, 16)])
def test_pavlov_ssm(b, t, d, n, bt, bd):
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    delta = jax.nn.softplus(_rand(ks[0], b, t, d, scale=0.5))
    x = _rand(ks[1], b, t, d, scale=0.5)
    bc = _rand(ks[2], b, t, n, scale=0.5)
    cc = _rand(ks[3], b, t, n, scale=0.5)
    a = -jax.nn.softplus(_rand(ks[4], d, n))        # negative (stable)
    dskip = _rand(ks[5], d)
    out = pavlov_ssm(delta, x, bc, cc, a, dskip, block_t=bt, block_d=bd)
    ref = pavlov_ssm_ref(delta, x, bc, cc, a, dskip)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_pavlov_ssm_matches_model_mamba_core():
    """Kernel == the mamba_ssm inner recurrence used by falcon-mamba."""
    from repro.models.recurrent import mamba_ssm, init_mamba_block
    key = jax.random.PRNGKey(8)
    d_model, d_inner, d_state, dt_rank = 16, 32, 4, 4
    p = init_mamba_block(key, d_model, d_inner, d_state, 4, dt_rank)
    x = _rand(jax.random.PRNGKey(9), 2, 12, d_inner, scale=0.5)
    ref, _ = mamba_ssm(p, x, dt_rank, d_state, chunk=4)
    # recompute the kernel inputs exactly as mamba_ssm does
    xf = x.astype(jnp.float32)
    proj = jnp.einsum("bsd,dr->bsr", xf, p["x_proj"].astype(jnp.float32))
    dt_in, b_in, c_in = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in, p["dt_proj"].astype(jnp.float32))
        + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    out = pavlov_ssm(delta, xf, b_in, c_in, a, p["d_skip"].astype(jnp.float32),
                     block_t=4, block_d=16)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------- flash_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,skv,h,kvh,hd,bq,bk,window", [
    (32, 32, 4, 4, 16, 16, 16, 0),
    (32, 32, 8, 2, 16, 8, 16, 0),       # GQA
    (64, 64, 4, 1, 32, 32, 32, 16),     # MQA + sliding window
    (16, 48, 4, 2, 16, 16, 16, 0),      # q continues a cache
])
def test_flash_kernel(dtype, sq, skv, h, kvh, hd, bq, bk, window):
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    q = _rand(ks[0], 2, sq, h, hd, dtype=dtype)
    k = _rand(ks[1], 2, skv, kvh, hd, dtype=dtype)
    v = _rand(ks[2], 2, skv, kvh, hd, dtype=dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=bq, block_kv=bk)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("seed", range(15))
def test_flash_kernel_property(seed):
    rng = random.Random(2000 + seed)
    s = rng.choice([16, 32, 64])
    h, kvh = rng.choice([(4, 4), (4, 2), (8, 1)])
    hd = rng.choice([8, 16])
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(ks[0], 1, s, h, hd)
    k = _rand(ks[1], 1, s, kvh, hd)
    v = _rand(ks[2], 1, s, kvh, hd)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_kv=16)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------- paged_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("h,kvh,hd,n,bs,nb", [
    (4, 4, 16, 8, 8, 4),
    (4, 2, 16, 10, 8, 4),               # GQA
    (8, 1, 8, 6, 16, 2),                # MQA
    (2, 2, 32, 12, 4, 8),               # many small blocks
])
def test_paged_decode_kernel(dtype, h, kvh, hd, n, bs, nb):
    """Block-table gather kernel vs the pure-jnp paged reference: scattered
    pools, ragged per-slot lengths, sentinel (unallocated) table entries."""
    B = 3
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    q = _rand(ks[0], B, 1, h, hd, dtype=dtype)
    nk = _rand(ks[1], B, 1, kvh, hd, dtype=dtype)
    nv = _rand(ks[2], B, 1, kvh, hd, dtype=dtype)
    kp = _rand(ks[3], n, bs, kvh, hd, dtype=dtype)
    vp = _rand(ks[4], n, bs, kvh, hd, dtype=dtype)
    rng = np.random.RandomState(7)
    # distinct physical blocks per slot, rest sentinel (= n, "no block")
    perm = rng.permutation(n)
    table = np.full((B, nb), n, np.int32)
    lengths = np.zeros((B,), np.int32)
    off = 0
    for b in range(B):
        owned = rng.randint(1, nb + 1)
        owned = min(owned, n - off)
        table[b, :owned] = perm[off:off + owned]
        off += owned
        lengths[b] = rng.randint(0, owned * bs)   # write pos inside coverage
    out, k2, v2 = paged_decode_attention(q, nk, nv, kp, vp,
                                         jnp.asarray(table),
                                         jnp.asarray(lengths))
    outr, k2r, v2r = paged_decode_attention_ref(q, nk, nv, kp, vp,
                                                jnp.asarray(table),
                                                jnp.asarray(lengths))
    np.testing.assert_array_equal(np.asarray(k2), np.asarray(k2r))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v2r))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(outr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("seed", range(8))
def test_paged_decode_kernel_property(seed):
    rng = random.Random(3000 + seed)
    h, kvh = rng.choice([(4, 4), (4, 2), (8, 1)])
    hd = rng.choice([8, 16])
    bs = rng.choice([4, 8])
    nb = rng.choice([2, 4])
    B = rng.choice([1, 2, 4])
    n = B * nb + rng.choice([0, 3])
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = _rand(ks[0], B, 1, h, hd)
    nk = _rand(ks[1], B, 1, kvh, hd)
    nv = _rand(ks[2], B, 1, kvh, hd)
    kp = _rand(ks[3], n, bs, kvh, hd)
    vp = _rand(ks[4], n, bs, kvh, hd)
    nrng = np.random.RandomState(seed)
    table = nrng.permutation(n)[:B * nb].reshape(B, nb).astype(np.int32)
    lengths = nrng.randint(0, nb * bs, size=B).astype(np.int32)
    out, k2, v2 = paged_decode_attention(q, nk, nv, kp, vp,
                                         jnp.asarray(table),
                                         jnp.asarray(lengths))
    outr, k2r, v2r = paged_decode_attention_ref(q, nk, nv, kp, vp,
                                                jnp.asarray(table),
                                                jnp.asarray(lengths))
    np.testing.assert_array_equal(np.asarray(k2), np.asarray(k2r))
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr),
                               atol=1e-4, rtol=1e-4)
