"""docs/serving.md is a drift-checked artifact: its flag tables must match
the live ``launch/serve.py`` argparse parser exactly — every flag present,
no stale rows, every default the ``repr`` of the parser's default.  A flag
added without its doc row (or a doc row whose flag/default no longer
exists) fails tier-1."""
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC = REPO / "docs" / "serving.md"

# | `--flag` | `default` | consumed by | ... |
ROW = re.compile(r"^\|\s*`(--[a-z][a-z0-9-]*)`\s*\|\s*`([^`]*)`\s*\|")


def _doc_rows() -> dict:
    rows = {}
    for line in DOC.read_text().splitlines():
        m = ROW.match(line)
        if m:
            assert m.group(1) not in rows, f"duplicate doc row {m.group(1)}"
            rows[m.group(1)] = m.group(2)
    return rows


def _parser_flags() -> dict:
    from repro.launch.serve import build_parser
    out = {}
    for a in build_parser()._actions:
        if not a.option_strings or a.option_strings[0] == "-h":
            continue
        out[a.option_strings[0]] = repr(a.default)
    return out


def test_serving_doc_covers_every_flag():
    doc, live = _doc_rows(), _parser_flags()
    assert doc, f"{DOC} has no parseable flag rows"
    missing = sorted(set(live) - set(doc))
    stale = sorted(set(doc) - set(live))
    assert not missing and not stale, (
        f"docs/serving.md drifted from launch/serve.py build_parser():\n"
        f"  undocumented flags: {missing}\n"
        f"  stale doc rows:     {stale}\n"
        f"add/remove the table rows in the same commit as the parser change")


def test_serving_doc_defaults_match_parser():
    doc, live = _doc_rows(), _parser_flags()
    wrong = {f: (doc[f], live[f]) for f in sorted(set(doc) & set(live))
             if doc[f] != live[f]}
    assert not wrong, (
        "docs/serving.md defaults drifted (doc, parser): "
        f"{wrong} — the Default column is repr(action.default)")


def test_docs_linked_from_readme():
    """The two architecture/operator docs must stay reachable from the
    README (the repo's front door)."""
    readme = (REPO / "README.md").read_text()
    for doc in ("docs/architecture.md", "docs/serving.md",
                "docs/observability.md", "docs/placement.md"):
        assert doc in readme, f"README.md no longer links {doc}"
        assert (REPO / doc).exists(), f"{doc} missing"
