"""Seeded JL005 violations: Pallas grid/BlockSpec discipline.

Never executed — parsed by tests/test_analysis.py only (with the rule's
`paths` widened to see this directory).
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _plain_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def _prefetch_kernel(table_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _masked_kernel(x_ref, o_ref, *, m: int, block: int):
    i = pl.program_id(0)
    pos = i * block + jax.lax.iota(jnp.int32, block)
    o_ref[...] = jnp.where(pos < m, x_ref[...] * 2, 0)


def bad_index_map_arity(x, block):
    m, n = x.shape
    assert m % block == 0 and n % block == 0
    return pl.pallas_call(
        _plain_kernel,
        grid=(m // block, n // block),
        in_specs=[
            pl.BlockSpec((block, block),
                         lambda i: (i, 0)),              # expect[JL005]
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
    )(x)


def dropped_remainder(x, block):
    (m,) = x.shape
    return pl.pallas_call(
        _plain_kernel,
        grid=(m // block,),                              # expect[JL005]
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
    )(x)


def overrun_tail_unmasked(x, block):
    (m,) = x.shape
    return pl.pallas_call(
        _plain_kernel,
        grid=(pl.cdiv(m, block),),                       # expect[JL005]
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
    )(x)


def bad_prefetch_kernel_arity(x, table, block):
    (m,) = x.shape
    assert m % block == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // block,),
        in_specs=[pl.BlockSpec((block,), lambda i, tbl: (tbl[i],))],
        out_specs=pl.BlockSpec((block,), lambda i, tbl: (i,)),
        scratch_shapes=[pltpu.VMEM((block,), jnp.float32)],
    )
    return pl.pallas_call(                               # expect[JL005]
        _prefetch_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
    )(table, x)


def bad_operand_count(x, table, block):
    (m,) = x.shape
    assert m % block == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // block,),
        in_specs=[pl.BlockSpec((block,), lambda i, tbl: (tbl[i],))],
        out_specs=pl.BlockSpec((block,), lambda i, tbl: (i,)),
    )
    return pl.pallas_call(                               # expect[JL005]
        _prefetch_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
    )(x)


def clean_masked_tail(x, block):
    # ceil-div grid + in-kernel masking + closure-captured index-map default:
    # the disciplined form, no findings
    import functools
    (m,) = x.shape
    return pl.pallas_call(
        functools.partial(_masked_kernel, m=m, block=block),
        grid=(pl.cdiv(m, block),),
        in_specs=[pl.BlockSpec((block,), lambda i, b=block: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
    )(x)
