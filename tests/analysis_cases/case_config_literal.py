"""Seeded JL002 violations: hardware-magnitude literals outside accelerators.

Never executed — parsed by tests/test_analysis.py only (with the rule's
`paths` widened to see this directory).
"""

PEAK_FLOPS = 123e12                 # expect[JL002]
HBM_BW = 819e9                      # expect[JL002]
DRAM_BYTES = 34_359_738_368         # expect[JL002]


def utilization(flops: float) -> float:
    return flops / 456e9            # expect[JL002]


# --- below the band, powers of ten, or non-decimal: no findings ---
SMALL = 5e6
UNIT_GIGA = 1e9
UNIT_TERA = 1e12
SEED_MASK = 0x7FFFFFFF
TINY = 1e-30
BIG_SENTINEL = 1e30
