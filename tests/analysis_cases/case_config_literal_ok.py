"""Pragma-suppressed twin of case_config_literal.py — must lint clean."""

PEAK_FLOPS = 123e12                 # jitlint: ignore[JL002]
HBM_BW = 819e9                      # jitlint: ignore[config-literal]
DRAM_BYTES = 34_359_738_368         # jitlint: ignore[JL002]


def utilization(flops: float) -> float:
    return flops / 456e9            # jitlint: ignore[JL002]
