"""Seeded JL004 violations: hard top-level optional-dep imports.

Never executed — parsed by tests/test_analysis.py only.  Lives under
tests/ so the rule's default `tests/*` path filter applies to it.
"""
from typing import TYPE_CHECKING

import hypothesis                                  # expect[JL004]
from hypothesis import given                       # expect[JL004]
from hypothesis.strategies import integers         # expect[JL004]

try:
    import hypothesis as hyp_guarded               # guarded: clean
except ImportError:
    hyp_guarded = None

if TYPE_CHECKING:
    from hypothesis import settings                # type-only: clean


def test_property():
    from hypothesis import strategies              # function-local: clean
    return strategies, given, integers, hypothesis, hyp_guarded
