"""Pragma-suppressed twin of case_policy_knob.py — must lint clean."""


def pick_kernel(cfg):
    if cfg.attn_impl == "pallas":                 # jitlint: ignore[JL007]
        return "flash"
    return cfg.rglru_impl                         # jitlint: ignore[policy-owned-knob]


def chunk_width(cfg, bucket: int) -> int:
    return min(bucket, cfg.scan_chunk)            # jitlint: ignore[JL007]


def hand_tuned(cfg):
    return cfg.replace(remat=False)               # jitlint: ignore[JL007]
