"""Pragma-suppressed twin of case_optional_dep.py — must lint clean."""

import hypothesis                                  # jitlint: ignore[JL004]
from hypothesis import given                       # jitlint: ignore[optional-dep]
# jitlint: ignore[JL004]
from hypothesis.strategies import integers


def test_property():
    return given, integers, hypothesis
