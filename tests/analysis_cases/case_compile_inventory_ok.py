"""Pragma-suppressed twin of case_compile_inventory.py — must lint clean."""
import jax
import numpy as np


class LeakyEngine:
    def __init__(self, model):
        self._decode = jax.jit(model.decode)
        self._prefill = jax.jit(model.prefill)

    def warmup(self, tokens):
        self._decode(tokens)

    def step(self, tokens, prompts):
        out = self._decode(tokens)
        first = self._prefill(prompts)                   # jitlint: ignore[JL006]
        late = jax.jit(self._post)                       # jitlint: ignore[compile-inventory]
        # jitlint: ignore[JL006]
        batch = np.zeros((len(prompts), 4))
        return first, late(out), batch

    def _post(self, t):
        return t


class NeverWarmed:                                       # jitlint: ignore[JL006]
    def __init__(self, model):
        self._decode = jax.jit(model.decode)

    def step(self, tokens):
        return self._decode(tokens)
