"""Seeded JL001 violations: trace-time concretization + per-call programs.

Never executed — parsed by tests/test_analysis.py only.
"""
from functools import partial

import jax


@jax.jit
def decode_step(x, position):
    n = int(position)                          # expect[JL001]
    scale = float(x.mean())                    # expect[JL001]
    flag = bool(x.any())                       # expect[JL001]
    host = x.item()                            # expect[JL001]
    if x.shape[0] > 4:                         # expect[JL001]
        x = x * 2
    return x + n + scale + flag + host


def helper_called_from_jit(y):
    # reachable: decode_bridge below is passed to jax.jit and calls this
    return y.item()                            # expect[JL001]


def decode_bridge(y):
    return helper_called_from_jit(y)


_bridge = jax.jit(decode_bridge)


@partial(jax.jit, static_argnames=("widths",))
def bucketed(x, widths=(8, 16)):
    return x[: widths[0]]


def not_reachable(z):
    # identical body, but nothing jit-reachable calls it: must NOT fire
    return z.item()


def serve_once(fn, x):
    out = jax.jit(fn)(x)                       # expect[JL001]
    lam = jax.jit(lambda t: t + 1)             # expect[JL001]

    def local_step(t):
        return t * 2

    prog = jax.jit(local_step)                 # expect[JL001]
    return out, lam(x), prog(x)


def caller(x):
    return bucketed(x, widths=[8, 16])         # expect[JL001]


MODULE_LEVEL = jax.jit(lambda t: t)            # module-level lambda: built once


def safe_casts(xs):
    return int(len(xs)) + float(3) + bool(0)   # literals / len: no finding
