"""Seeded JL006 violations: compile-inventory drift in an engine-like class.

Never executed — parsed by tests/test_analysis.py only.
"""
import jax
import jax.numpy as jnp
import numpy as np


class LeakyEngine:
    """Warms _decode but not _prefill; also jits and allocates in methods."""

    def __init__(self, model):
        self._decode = jax.jit(model.decode)
        self._prefill = jax.jit(model.prefill)

    def warmup(self, tokens):
        self._decode(tokens)

    def step(self, tokens, prompts):
        out = self._decode(tokens)
        first = self._prefill(prompts)                   # expect[JL006]
        late = jax.jit(self._post)                       # expect[JL006]
        batch = np.zeros((len(prompts), 4))              # expect[JL006]
        return first, late(out), batch

    def _post(self, t):
        return t


class NeverWarmed:                                       # expect[JL006]
    """Builds jitted programs but has no warmup() at all."""

    def __init__(self, model):
        self._decode = jax.jit(model.decode)

    def step(self, tokens):
        return self._decode(tokens)


class CleanEngine:
    """Every program is warmed, directly or through a helper — no findings."""

    def __init__(self, model):
        self._decode = jax.jit(model.decode)
        self._prefill = jax.jit(model.prefill)

    def warmup(self, tokens, prompts):
        self._decode(tokens)
        self._warm_prefill(prompts)

    def _warm_prefill(self, prompts):
        self._prefill(prompts)

    def step(self, tokens, prompts):
        pad = jnp.zeros((8, 4))
        return self._decode(tokens), self._prefill(prompts), pad
