"""Seeded JL008 violations: host clocks vs async dispatch.

Never executed — parsed by tests/test_analysis.py only.
"""
import time

import jax


@jax.jit
def traced_step(x):
    t0 = time.perf_counter()                               # expect[JL008]
    return x * t0


def helper(x):
    # jit-reachable transitively (traced_entry below calls it)
    return x + time.time()                                 # expect[JL008]


@jax.jit
def traced_entry(x):
    return helper(x)


def dispatch_timed_decode(step, state):
    """The engine bug this rule exists for: perf_counter around a jitted
    call with no sync — measures XLA enqueue, not execution."""
    t0 = time.perf_counter()
    out = step(state)
    dur = time.perf_counter() - t0                         # expect[JL008]
    return out, dur


def synced_decode(step, state):
    t0 = time.perf_counter()
    out = jax.block_until_ready(step(state))        # synced: clean
    return out, time.perf_counter() - t0


def single_stamp(req):
    req.t_submit = time.perf_counter()              # one read, no section
    return req
