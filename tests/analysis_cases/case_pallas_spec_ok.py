"""Pragma-suppressed twin of case_pallas_spec.py — must lint clean."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _plain_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def _prefetch_kernel(table_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_index_map_arity(x, block):
    m, n = x.shape
    assert m % block == 0 and n % block == 0
    return pl.pallas_call(
        _plain_kernel,
        grid=(m // block, n // block),
        in_specs=[
            pl.BlockSpec((block, block),
                         lambda i: (i, 0)),              # jitlint: ignore[JL005]
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
    )(x)


def dropped_remainder(x, block):
    (m,) = x.shape
    return pl.pallas_call(
        _plain_kernel,
        grid=(m // block,),                              # jitlint: ignore[pallas-spec]
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
    )(x)


def bad_prefetch_kernel_arity(x, table, block):
    (m,) = x.shape
    assert m % block == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // block,),
        in_specs=[pl.BlockSpec((block,), lambda i, tbl: (tbl[i],))],
        out_specs=pl.BlockSpec((block,), lambda i, tbl: (i,)),
        scratch_shapes=[pltpu.VMEM((block,), jnp.float32)],
    )
    # jitlint: ignore[JL005]
    return pl.pallas_call(
        _prefetch_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
    )(table, x)
