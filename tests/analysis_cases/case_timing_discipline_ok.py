"""Pragma-suppressed twin of case_timing_discipline.py — must lint clean."""
import time

import jax


@jax.jit
def traced_step(x):
    t0 = time.perf_counter()                # jitlint: ignore[JL008]
    return x * t0


def helper(x):
    return x + time.time()                  # jitlint: ignore[timing-discipline]


@jax.jit
def traced_entry(x):
    return helper(x)


def compile_timed(lowered):
    # blocking host work (AOT compile) — the sanctioned pragma use case
    t0 = time.perf_counter()
    compiled = lowered.compile()
    dur = time.perf_counter() - t0          # jitlint: ignore[JL008]
    return compiled, dur
