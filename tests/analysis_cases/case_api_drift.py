"""Seeded JL003 violations: raw `.cost_analysis()` / `.memory_analysis()`.

Never executed — parsed by tests/test_analysis.py only.
"""
from repro.utils.hlo import normalize_cost_analysis, normalize_memory_analysis


def probe(compiled):
    cost = compiled.cost_analysis()                        # expect[JL003]
    flops = compiled.cost_analysis()["flops"]              # expect[JL003]
    ok = normalize_cost_analysis(compiled.cost_analysis())  # routed: clean
    return cost, flops, ok


def probe_memory(compiled):
    mem = compiled.memory_analysis()                       # expect[JL003]
    tmp = compiled.memory_analysis().temp_size_in_bytes    # expect[JL003]
    ok = normalize_memory_analysis(compiled.memory_analysis())  # routed
    return mem, tmp, ok
