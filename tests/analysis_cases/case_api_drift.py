"""Seeded JL003 violations: raw `.cost_analysis()` access.

Never executed — parsed by tests/test_analysis.py only.
"""
from repro.utils.hlo import normalize_cost_analysis


def probe(compiled):
    cost = compiled.cost_analysis()                        # expect[JL003]
    flops = compiled.cost_analysis()["flops"]              # expect[JL003]
    ok = normalize_cost_analysis(compiled.cost_analysis())  # routed: clean
    return cost, flops, ok
