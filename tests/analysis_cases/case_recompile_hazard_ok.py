"""Pragma-suppressed twin of case_recompile_hazard.py — must lint clean.

Exercises every suppression spelling: rule ID, rule name, comma lists,
same-line and line-above placement, and `*`.
"""
from functools import partial

import jax


@jax.jit
def decode_step(x, position):
    n = int(position)                          # jitlint: ignore[JL001]
    scale = float(x.mean())                    # jitlint: ignore[recompile-hazard]
    flag = bool(x.any())                       # jitlint: ignore[JL001, JL002]
    # jitlint: ignore[JL001]
    host = x.item()
    if x.shape[0] > 4:                         # jitlint: ignore[*]
        x = x * 2
    return x + n + scale + flag + host


def helper_called_from_jit(y):
    return y.item()                            # jitlint: ignore[JL001]


def decode_bridge(y):
    return helper_called_from_jit(y)


_bridge = jax.jit(decode_bridge)


@partial(jax.jit, static_argnames=("widths",))
def bucketed(x, widths=(8, 16)):
    return x[: widths[0]]


def serve_once(fn, x):
    out = jax.jit(fn)(x)                       # jitlint: ignore[JL001]
    lam = jax.jit(lambda t: t + 1)             # jitlint: ignore[recompile-hazard]

    def local_step(t):
        return t * 2

    # jitlint: ignore[JL001]
    prog = jax.jit(local_step)
    return out, lam(x), prog(x)


def caller(x):
    return bucketed(x, widths=[8, 16])         # jitlint: ignore[JL001]
