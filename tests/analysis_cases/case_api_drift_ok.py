"""Pragma-suppressed twin of case_api_drift.py — must lint clean."""
from repro.utils.hlo import normalize_cost_analysis, normalize_memory_analysis


def probe(compiled):
    cost = compiled.cost_analysis()                        # jitlint: ignore[JL003]
    flops = compiled.cost_analysis()["flops"]              # jitlint: ignore[api-drift]
    ok = normalize_cost_analysis(compiled.cost_analysis())
    return cost, flops, ok


def probe_memory(compiled):
    mem = compiled.memory_analysis()                       # jitlint: ignore[JL003]
    tmp = compiled.memory_analysis().temp_size_in_bytes    # jitlint: ignore[api-drift]
    ok = normalize_memory_analysis(compiled.memory_analysis())
    return mem, tmp, ok
