"""Seeded JL007 violations: serving code touching policy-owned knobs.

Never executed — parsed by tests/test_analysis.py only (with the rule's
`paths` widened to see this directory).  In the real tree the rule fires
only under ``src/repro/serve/`` and exempts ``serve/placement.py`` (the
knob owner) via its default ``allow_paths``.
"""


def pick_kernel(cfg):
    if cfg.attn_impl == "pallas":                 # expect[JL007]
        return "flash"
    return cfg.rglru_impl                         # expect[JL007]


def chunk_width(cfg, bucket: int) -> int:
    return min(bucket, cfg.scan_chunk)            # expect[JL007]


def hand_tuned(cfg):
    return cfg.replace(remat=False)               # expect[JL007]


# --- non-knob attributes and bare names: no findings ---
def fine(cfg, policy):
    width = policy.prefill_chunk                  # plan geometry, not a knob
    attn_impl = "xla"                             # bare name, not an access
    return width, attn_impl, cfg.vocab_size
