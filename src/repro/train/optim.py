"""Optimizers + schedules, implemented from scratch (no optax dependency).

AdamW with decoupled weight decay, global-norm clipping, and cosine/linear
warmup schedules.  Optimizer state is a pytree congruent with params, so the
parameter sharding specs apply to it unchanged (fully sharded optimizer state
comes for free from the `model`-axis parameter sharding).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array          # ()
    mu: PyTree               # first moment  (fp32, like params)
    nu: PyTree               # second moment


def adamw_init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads: PyTree, state: AdamWState, params: PyTree, *,
                 lr: jax.Array, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1
                 ) -> tuple[PyTree, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:  # decoupled decay on matrices only
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (-lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    updates = treedef.unflatten([o[0] for o in out])
    mu = treedef.unflatten([o[1] for o in out])
    nu = treedef.unflatten([o[2] for o in out])
    return updates, AdamWState(step=step, mu=mu, nu=nu)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)


# -------------------------------------------------------------------- schedules
def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def f(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return f


def linear_schedule(base_lr: float, warmup: int, total: int):
    def f(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm, base_lr * (1 - prog))
    return f


# ----------------------------------------------------------------- SGD (ablation)
class SGDState(NamedTuple):
    step: jax.Array
    momentum: PyTree


def sgd_init(params: PyTree) -> SGDState:
    return SGDState(step=jnp.zeros((), jnp.int32),
                    momentum=jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def sgd_update(grads: PyTree, state: SGDState, params: PyTree, *,
               lr: jax.Array, momentum: float = 0.9):
    step = state.step + 1

    def upd(g, m, p):
        m = momentum * m + g.astype(jnp.float32)
        return (-lr * m).astype(p.dtype), m

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.momentum)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
    return (treedef.unflatten([o[0] for o in out]),
            SGDState(step, treedef.unflatten([o[1] for o in out])))
