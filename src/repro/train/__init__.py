"""Training substrate: optimizers, schedules, trainer, gradient compression."""
from . import optim
from .trainer import make_train_step

__all__ = ["optim", "make_train_step"]
