"""Training step factory: grad accumulation (lax.scan over microbatches),
global-norm clipping, AdamW, bf16 compute / fp32 masters, optional int8
error-feedback gradient compression (see grad.py).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models.transformer import Model
from . import optim

PyTree = Any


def make_train_step(model: Model, *, accum_steps: int = 1,
                    schedule: Callable | None = None,
                    max_grad_norm: float = 1.0,
                    weight_decay: float = 0.1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    `batch` leaves have a leading global-batch axis; with accum_steps > 1 the
    step reshapes to (A, B/A, ...) and accumulates grads over a lax.scan so
    peak activation memory is one microbatch.
    """
    schedule = schedule or optim.cosine_schedule(3e-4, 100, 10_000)

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(params, opt_state, batch):
        if accum_steps > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def acc(carry, mb):
                gsum, lsum = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)),
                                           micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)

        grads, gnorm = optim.clip_by_global_norm(grads, max_grad_norm)
        lr = schedule(opt_state.step)
        updates, opt_state = optim.adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay)
        params = optim.apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step
