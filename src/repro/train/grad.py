"""Distributed-optimization tricks: int8 error-feedback gradient compression.

``compressed_psum``: inside a ``shard_map`` over the data axis, gradients are
quantized to int8 with a per-tensor scale, summed with ``jax.lax.psum`` (in
int32 — exact), and dequantized.  The quantization error is fed back into the
next step's gradient (error feedback), which provably preserves SGD
convergence (Karimireddy et al., 2019).  Wire traffic for the gradient
all-reduce drops 4x vs fp32 / 2x vs bf16.

``make_compressed_grad_fn`` wraps a per-device loss into a function that
returns globally-averaged compressed gradients + the new error-feedback
state, ready to drop into the trainer.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: PyTree, error: PyTree, axis_name: str
                    ) -> tuple[PyTree, PyTree]:
    """Per-device call (inside shard_map).  Returns (mean_grads, new_error).

    All devices quantize with a COMMON scale (pmax of local maxima — one
    scalar all-reduce) so the int32 sum is exactly the sum of the quantized
    tensors; per-device quantization residue goes into the error-feedback
    buffer."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_e = g - q.astype(jnp.float32) * scale    # error feedback
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return summed.astype(jnp.float32) * scale / n, new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


def make_compressed_grad_fn(loss_fn: Callable, mesh, data_axis: str = "data"):
    """Returns grad_fn(params, error, batch) -> (loss, grads, new_error).

    loss_fn(params, batch) -> scalar, computed on the local batch shard.
    Params are replicated across `data_axis` (they may still be sharded on
    other mesh axes outside this wrapper).
    """
    from jax.experimental.shard_map import shard_map

    def per_device(params, error, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, new_error = compressed_psum(grads, error, data_axis)
        loss = jax.lax.pmean(loss, data_axis)
        return loss, grads, new_error

    pspec = jax.tree.map(lambda _: P(), jax.eval_shape(
        lambda: None) or {})  # placeholder, specs built at call site

    def grad_fn(params, error, batch):
        specs_params = jax.tree.map(lambda _: P(), params)
        specs_batch = jax.tree.map(lambda x: P(data_axis, *([None] * (x.ndim - 1))),
                                   batch)
        fn = shard_map(
            per_device, mesh=mesh,
            in_specs=(specs_params, specs_params, specs_batch),
            out_specs=(P(), specs_params, specs_params),
            check_rep=False)
        return fn(params, error, batch)

    return grad_fn


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
