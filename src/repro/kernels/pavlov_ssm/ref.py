"""Pure-jnp oracle for the selective scan (materializes alpha/beta)."""
import jax
import jax.numpy as jnp


def pavlov_ssm_ref(delta, x, bc, cc, a, d_skip):
    """delta,x: (B,T,D); bc,cc: (B,T,N); a: (D,N); d_skip: (D,) -> (B,T,D)."""
    deltaf = delta.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    alpha = jnp.exp(deltaf[..., None] * a.astype(jnp.float32)[None, None])
    beta = (deltaf * xf)[..., None] * bc.astype(jnp.float32)[:, :, None, :]

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (alpha, beta), axis=1)
    y = jnp.einsum("btdn,btn->btd", h, cc.astype(jnp.float32)) \
        + xf * d_skip.astype(jnp.float32)
    return y.astype(delta.dtype)
