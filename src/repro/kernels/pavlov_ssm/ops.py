"""Jit'd wrapper for the Pavlov fused selective-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from ..common import use_interpret
from .kernel import pavlov_ssm_raw


@partial(jax.jit, static_argnames=("block_t", "block_d"))
def pavlov_ssm(delta, x, bc, cc, a, d_skip, *, block_t: int = 64,
               block_d: int = 512):
    return pavlov_ssm_raw(delta, x, bc, cc, a, d_skip, block_t=block_t,
                          block_d=block_d, interpret=use_interpret())
