"""Pavlov SSM kernel — fused Mamba-1 selective scan with VMEM-resident state.

Per (channel-tile, step): h = exp(delta*A) * h + (delta*x) * B_t ;
y_t = <h, C_t> + D*x_t.  The (B, bd, N) state tensor stays in VMEM scratch
across all T steps; A (the recurrence weights) is fetched once and stays
resident (Pavlov); delta/x/B/C stream sequentially from HBM exactly once.

Avoids ever materializing the (B, T, D, N) alpha/beta tensors in HBM that the
naive associative-scan formulation needs — this is the kernel-level win over
the pure-jnp path (ref.py) on memory-bound recurrent layers.

Grid: (D/bd, T/bt), T innermost (sequential), D-tiles independent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(delta_ref, x_ref, bc_ref, cc_ref, a_ref, dskip_ref, o_ref,
                h_ref, *, bt: int):
    t_idx = pl.program_id(1)

    @pl.when(t_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    delta = delta_ref[...].astype(jnp.float32)   # (B, bt, bd)
    x = x_ref[...].astype(jnp.float32)           # (B, bt, bd)
    bc = bc_ref[...].astype(jnp.float32)         # (B, bt, N)
    cc = cc_ref[...].astype(jnp.float32)         # (B, bt, N)
    a = a_ref[...].astype(jnp.float32)           # (bd, N)
    dskip = dskip_ref[...].astype(jnp.float32)   # (1, bd)

    def step(i, h):                              # h: (B, bd, N)
        alpha = jnp.exp(delta[:, i, :, None] * a[None])          # (B,bd,N)
        beta = (delta[:, i, :] * x[:, i, :])[..., None] \
            * bc[:, i, None, :]                                  # (B,bd,N)
        h = alpha * h + beta
        y = jnp.sum(h * cc[:, i, None, :], axis=-1) \
            + x[:, i, :] * dskip[0][None]                        # (B,bd)
        o_ref[:, i, :] = y.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, bt, step, h_ref[...])


def pavlov_ssm_raw(delta: jax.Array, x: jax.Array, bc: jax.Array,
                   cc: jax.Array, a: jax.Array, d_skip: jax.Array, *,
                   block_t: int = 64, block_d: int = 512,
                   interpret: bool = False) -> jax.Array:
    """delta,x: (B,T,D); bc,cc: (B,T,N); a: (D,N); d_skip: (D,) -> y: (B,T,D)."""
    bb, t, d = delta.shape
    n = a.shape[1]
    block_t = min(block_t, t)
    block_d = min(block_d, d)
    assert t % block_t == 0 and d % block_d == 0
    return pl.pallas_call(
        functools.partial(_ssm_kernel, bt=block_t),
        grid=(d // block_d, t // block_t),
        in_specs=[
            pl.BlockSpec((bb, block_t, block_d), lambda j, tt: (0, tt, j)),
            pl.BlockSpec((bb, block_t, block_d), lambda j, tt: (0, tt, j)),
            pl.BlockSpec((bb, block_t, n), lambda j, tt: (0, tt, 0)),
            pl.BlockSpec((bb, block_t, n), lambda j, tt: (0, tt, 0)),
            pl.BlockSpec((block_d, n), lambda j, tt: (j, 0)),  # A resident
            pl.BlockSpec((1, block_d), lambda j, tt: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, block_t, block_d),
                               lambda j, tt: (0, tt, j)),
        out_shape=jax.ShapeDtypeStruct((bb, t, d), delta.dtype),
        scratch_shapes=[pltpu.VMEM((bb, block_d, n), jnp.float32)],
        interpret=interpret,
    )(delta, x, bc, cc, a, d_skip.reshape(1, -1))
