from .kernel import pavlov_ssm_raw
from .ops import pavlov_ssm
from .ref import pavlov_ssm_ref

__all__ = ["pavlov_ssm", "pavlov_ssm_raw", "pavlov_ssm_ref"]
