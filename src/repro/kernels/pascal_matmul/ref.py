"""Pure-jnp oracle for pascal_matmul."""
import jax
import jax.numpy as jnp


def pascal_matmul_ref(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)
                   ).astype(out_dtype)
