from .kernel import pascal_matmul_raw
from .ops import pascal_matmul
from .ref import pascal_matmul_ref

__all__ = ["pascal_matmul", "pascal_matmul_raw", "pascal_matmul_ref"]
