"""Pascal kernel — output-stationary MXU matmul with explicit VMEM tiling.

The paper's Pascal dataflow (§5.3): spatially distribute *output* elements,
temporally reduce partial sums in per-PE registers, multicast parameters.  On
TPU this is exactly an output-stationary blocked matmul: each (bm x bn) output
tile owns a fp32 VMEM accumulator, the K dimension streams through the MXU
innermost (temporal reduction), and each (bk x bn) parameter tile is read from
HBM once per output tile row (spatial multicast across the MXU lanes).

Block shapes default to MXU-aligned multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _write():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def pascal_matmul_raw(x: jax.Array, w: jax.Array, *,
                      block_m: int = 256, block_n: int = 256,
                      block_k: int = 512, out_dtype=None,
                      interpret: bool = False) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N).  Dims must divide by the blocks."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        (x.shape, w.shape, block_m, block_n, block_k)
    nk = k // block_k
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w)
