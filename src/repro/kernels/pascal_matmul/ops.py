"""Jit'd public wrapper for the Pascal matmul kernel (pads to block multiples,
flattens leading batch dims, picks interpret mode off-TPU)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import round_up, use_interpret
from .kernel import pascal_matmul_raw


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def pascal_matmul(x: jax.Array, w: jax.Array, *, block_m: int = 256,
                  block_n: int = 256, block_k: int = 512) -> jax.Array:
    """(..., K) @ (K, N) -> (..., N) via the Pascal output-stationary kernel."""
    *lead, k = x.shape
    n = w.shape[1]
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, k)
    bm = min(block_m, max(8, m))
    bn = min(block_n, n)
    bk = min(block_k, k)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    if (mp, kp) != (m, k):
        x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    out = pascal_matmul_raw(x2, wp, block_m=bm, block_n=bn, block_k=bk,
                            out_dtype=x.dtype, interpret=use_interpret())
    return out[:m, :n].reshape(*lead, n)
