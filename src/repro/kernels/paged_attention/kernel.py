"""Paged decode-attention kernel — block-table gather with online softmax.

One query token per slot attends to its logical KV sequence, which lives
scattered across a global pool of fixed-size blocks (vLLM-style paging).  The
block table is a *scalar-prefetch* operand: the KV BlockSpec index maps read
``table[b, j]`` before the kernel body runs, so each grid step DMAs exactly
the physical block that holds the slot's j-th logical block — K/V are never
materialized per-slot in HBM, which is the whole point of paging.

Grid: (B, nb) with the logical-block dimension innermost (sequential), so the
fp32 VMEM scratch (m, l, acc) accumulates the online softmax across a slot's
blocks exactly like the flash kernel accumulates across KV tiles.  GQA is
handled in-kernel (q reshaped to (KVH, G, hd) against the block's (bs, KVH,
hd)); per-slot lengths ride in the second scalar-prefetch operand and mask
both the not-yet-written tail of the last block and whole unallocated blocks
(whose table entries are clamped by ops.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(table_ref, length_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float, bs: int,
                         nb: int, kvh: int, group: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = kvh * group
    hd = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32) * scale            # (H, hd)
    k = k_ref[0].astype(jnp.float32)                    # (bs, KVH, hd)
    v = v_ref[0].astype(jnp.float32)

    qg = q.reshape(kvh, group, hd)
    s = jnp.einsum("nGd,tnd->nGt", qg, k)               # (KVH, G, bs)
    s = s.reshape(h, bs)

    kv_pos = j * bs + jax.lax.iota(jnp.int32, bs)[None, :]
    s = jnp.where(kv_pos <= length_ref[b], s, NEG_INF)  # incl. the new token

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                              # (H, bs)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jnp.einsum("nGt,tnd->nGd", p.reshape(kvh, group, bs), v)
    acc_ref[...] = acc_ref[...] * corr + pv.reshape(h, hd)
    m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _write():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_raw(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_table: jax.Array,
                               lengths: jax.Array, *,
                               interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k_pool/v_pool: (N, bs, KVH, hd); block_table: (B, nb)
    int32 with every entry in [0, N); lengths: (B,) int32 — the highest valid
    logical position per slot (the freshly written token's position).
    Returns (B, H, hd)."""
    b, h, hd = q.shape
    n, bs, kvh, _ = k_pool.shape
    _, nb = block_table.shape
    assert h % kvh == 0
    group = h // kvh
    kernel = functools.partial(
        _paged_decode_kernel, scale=1.0 / math.sqrt(hd), bs=bs, nb=nb,
        kvh=kvh, group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # block_table, lengths
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda i, j, tbl, lens: (i, 0, 0)),
            # the paging gather: logical block j of slot i lives at
            # physical block table[i, j] of the pool
            pl.BlockSpec((1, bs, kvh, hd),
                         lambda i, j, tbl, lens: (tbl[i, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, kvh, hd),
                         lambda i, j, tbl, lens: (tbl[i, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda i, j, tbl, lens: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(block_table, lengths, q, k_pool, v_pool)
