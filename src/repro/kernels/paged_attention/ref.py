"""Pure-jnp oracle — re-exports the model-level paged decode attention.

``models.attention.paged_decode_attention`` is the canonical jnp
implementation (the serving path's CPU/dry-run lowering); it is itself gated
bitwise-identical to the dense ``decode_attention`` in
tests/test_serve_kvpool.py, so kernel == ref == dense transitively."""
import jax.numpy as jnp

from ...models.attention import PagedKVCache
from ...models.attention import paged_decode_attention as _model_paged


def paged_decode_attention_ref(q, new_k, new_v, k_pool, v_pool, block_table,
                               lengths):
    """Same contract as ops.paged_decode_attention."""
    cache = PagedKVCache(k=k_pool, v=v_pool,
                         length=lengths.astype(jnp.int32))
    out, cache = _model_paged(q, new_k, new_v, cache, block_table)
    return out, cache.k, cache.v
