"""Jit'd wrapper: scatter the new token's K/V through the block table, then
run the paged gather-attention kernel over the updated pool."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..common import use_interpret
from .kernel import paged_decode_attention_raw


@jax.jit
def paged_decode_attention(q: jax.Array, new_k: jax.Array, new_v: jax.Array,
                           k_pool: jax.Array, v_pool: jax.Array,
                           block_table: jax.Array, lengths: jax.Array):
    """One-token paged attention.

    q/new_k/new_v: (B,1,H|KVH,hd); k_pool/v_pool: (N,bs,KVH,hd);
    block_table: (B,nb) — entries >= N mean "no block" (writes through them
    drop; reads clamp and are masked by ``lengths``); lengths: (B,) tokens
    already cached.  Writes each slot's new KV at logical position
    ``lengths[b]``, attends over positions 0..lengths[b], and returns
    (out (B,1,H,hd), k_pool, v_pool).
    """
    b, _, h, hd = q.shape
    n, bs = k_pool.shape[0], k_pool.shape[1]
    blk = jnp.take_along_axis(block_table, (lengths // bs)[:, None],
                              axis=1)[:, 0]
    off = lengths % bs
    k_pool = k_pool.at[blk, off].set(new_k[:, 0].astype(k_pool.dtype),
                                     mode="drop")
    v_pool = v_pool.at[blk, off].set(new_v[:, 0].astype(v_pool.dtype),
                                     mode="drop")
    table = jnp.minimum(block_table, n - 1).astype(jnp.int32)
    out = paged_decode_attention_raw(
        q[:, 0], k_pool, v_pool, table, lengths.astype(jnp.int32),
        interpret=use_interpret())
    return out[:, None], k_pool, v_pool
