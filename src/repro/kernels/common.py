"""Shared kernel utilities: interpret-mode selection and tiling helpers."""
from __future__ import annotations

import jax


def use_interpret() -> bool:
    """Pallas TPU kernels execute natively on TPU; everywhere else (this CPU
    container) they run in interpret mode, which executes the kernel body in
    Python for correctness validation."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
