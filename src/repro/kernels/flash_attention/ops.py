"""Jit'd wrapper: (B,S,H,hd) layout -> flattened (B*H, S, hd) kernel call."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import use_interpret
from .kernel import flash_attention_raw


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 256, block_kv: int = 256) -> jax.Array:
    """q: (B,Sq,H,hd); k,v: (B,Skv,KVH,hd) -> (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * kvh, skv, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * kvh, skv, hd)
    out = flash_attention_raw(qf, kf, vf, causal=causal, window=window,
                              block_q=block_q, block_kv=block_kv,
                              group=group, interpret=use_interpret())
    return jnp.moveaxis(out.reshape(b, h, sq, hd), 1, 2)
