"""Pure-jnp oracle (re-exports the model-level reference attention)."""
from ...models.attention import reference_attention


def flash_attention_ref(q, k, v, *, causal=True, window: int = 0):
    """q: (B,S,H,hd); k,v: (B,S,KVH,hd) -> (B,S,H,hd)."""
    sq, skv = q.shape[1], k.shape[1]
    return reference_attention(q, k, v, causal=causal, window=window,
                               q_offset=skv - sq)
