"""Blockwise (flash) attention kernel — online softmax with VMEM accumulators.

Grid: (BH, Sq/bq, Skv/bk) with the KV dimension innermost (sequential).  Each
(q-tile) owns fp32 VMEM scratch (m, l, acc); KV tiles stream through the MXU.
Causal and sliding-window masks are applied per tile.  GQA is handled by the
caller (ops.py) via logical head expansion in the BlockSpec index map — KV
heads are never materialized H/KVH times in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int, sq: int, skv: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = pl.program_id(1) * bq + jax.lax.iota(jnp.int32, bq)[:, None] \
        + (skv - sq)                                    # align q to kv end
    kv_pos = kv_idx * bk + jax.lax.iota(jnp.int32, bk)[None, :]
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kv_pos <= q_pos
    if window:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_idx == nk - 1)
    def _write():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_raw(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 256, block_kv: int = 256,
                        group: int = 1,
                        interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, d); k, v: (BKV, Skv, d) with BH == BKV * group.
    Returns (BH, Sq, d)."""
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    assert bh == bkv * group
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0
    nk = skv // block_kv
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(d), causal=causal,
        window=window, bq=block_q, bk=block_kv, nk=nk, sq=sq, skv=skv)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // block_q, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            # GQA: `group` consecutive q-heads share one kv head
            pl.BlockSpec((1, block_kv, d),
                         lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_kv, d),
                         lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
