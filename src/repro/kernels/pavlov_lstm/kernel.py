"""Pavlov kernel — fused LSTM recurrence with VMEM-resident hidden weights.

The paper's Pavlov dataflow (§5.4) for LSTM layers:
  1. *Decouple* input MVMs from hidden MVMs: all x_t @ W_x products for the
     whole sequence are computed ahead of the recurrence as one large GEMM
     (done by the caller / ops.py with the Pascal kernel) so W_x is fetched
     from HBM exactly once.
  2. The recurrence then only needs W_h, which this kernel fetches into VMEM
     ONCE and keeps resident across all T steps (the TPU analogue of
     parameters staying in PE register files), with h/c state in VMEM scratch
     (temporal reduction of partial sums, K concurrent rows = the batch).

Grid: (T,) sequential; per step the kernel reads one (B, 4H) slice of the
precomputed input gates, performs h_{t-1} @ W_h on the MXU, applies the four
gates, and writes h_t.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lstm_kernel(xg_ref, wh_ref, out_ref, h_ref, c_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    h = h_ref[...]
    gates = xg_ref[:, 0, :].astype(jnp.float32) + jnp.dot(
        h, wh_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)
    hd = h.shape[-1]
    i = jax.nn.sigmoid(gates[:, 0 * hd:1 * hd])
    f = jax.nn.sigmoid(gates[:, 1 * hd:2 * hd] + 1.0)
    g = jnp.tanh(gates[:, 2 * hd:3 * hd])
    o = jax.nn.sigmoid(gates[:, 3 * hd:4 * hd])
    c = f * c_ref[...] + i * g
    h_new = o * jnp.tanh(c)
    c_ref[...] = c
    h_ref[...] = h_new
    out_ref[:, 0, :] = h_new.astype(out_ref.dtype)


def pavlov_lstm_raw(xg: jax.Array, w_h: jax.Array, *,
                    interpret: bool = False) -> jax.Array:
    """xg: (B, T, 4H) precomputed input gates (+bias); w_h: (H, 4H).
    Returns h: (B, T, H)."""
    b, t, h4 = xg.shape
    hd = h4 // 4
    assert w_h.shape == (hd, h4), (w_h.shape, (hd, h4))
    return pl.pallas_call(
        _lstm_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((b, 1, h4), lambda tt: (0, tt, 0)),
            pl.BlockSpec((hd, h4), lambda tt: (0, 0)),   # resident across T
        ],
        out_specs=pl.BlockSpec((b, 1, hd), lambda tt: (0, tt, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, hd), xg.dtype),
        scratch_shapes=[pltpu.VMEM((b, hd), jnp.float32),
                        pltpu.VMEM((b, hd), jnp.float32)],
        interpret=interpret,
    )(xg, w_h)
