"""Pure-jnp oracle for the Pavlov fused LSTM recurrence."""
import jax
import jax.numpy as jnp


def pavlov_lstm_ref(xg: jax.Array, w_h: jax.Array) -> jax.Array:
    """xg: (B,T,4H) precomputed input gates; w_h: (H,4H) -> h: (B,T,H)."""
    b, t, h4 = xg.shape
    hd = h4 // 4
    wh = w_h.astype(jnp.float32)

    def step(carry, x_t):
        h, c = carry
        gates = x_t.astype(jnp.float32) + h @ wh
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((b, hd), jnp.float32), jnp.zeros((b, hd), jnp.float32))
    _, hs = jax.lax.scan(step, init, jnp.moveaxis(xg, 1, 0))
    return jnp.moveaxis(hs, 0, 1).astype(xg.dtype)
