from .kernel import pavlov_lstm_raw
from .ops import pavlov_lstm
from .ref import pavlov_lstm_ref

__all__ = ["pavlov_lstm", "pavlov_lstm_raw", "pavlov_lstm_ref"]
