"""Jit'd wrapper: full Pavlov LSTM layer = decoupled input GEMM (W_x read
once) + fused VMEM-resident recurrence kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import use_interpret
from .kernel import pavlov_lstm_raw


@jax.jit
def pavlov_lstm(x: jax.Array, w_x: jax.Array, w_h: jax.Array,
                b: jax.Array) -> jax.Array:
    """x: (B,T,Din); w_x: (Din,4H); w_h: (H,4H); b: (4H,) -> h: (B,T,H).

    Phase 1 (decoupled input MVMs, paper §5.4): one big GEMM over all
    timesteps.  Phase 2: the sequential recurrence kernel."""
    xg = jnp.einsum("btd,dh->bth", x, w_x.astype(x.dtype)) + b.astype(x.dtype)
    return pavlov_lstm_raw(xg, w_h, interpret=use_interpret())
