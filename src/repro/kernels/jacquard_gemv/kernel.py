"""Jacquard kernel — weight-stationary streaming GEMV for memory-bound
(decode-time) matmuls.

The paper's Jacquard dataflow (§5.5): parameters are spatially distributed and
*pinned* (weight-stationary); activations stream past them.  The TPU-native
reading for a skinny y = x @ W (M small, W huge): the grid walks W's (K, N)
tiles exactly once — every parameter byte is read from HBM exactly once, in
sequential order (the streaming access pattern Pavlov/Jacquard exploit for
full bandwidth) — while the tiny x block stays VMEM-resident across the whole
sweep.  Arithmetic intensity is ~M FLOP/byte, so the kernel is structured to
be bandwidth-optimal, not MXU-optimal.

Grid: (N/bn, K/bk) with K innermost -> per output tile, partial sums reduce
temporally in a fp32 VMEM accumulator (never spilled to HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemv_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(1) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == nk - 1)
    def _write():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def jacquard_gemv_raw(x: jax.Array, w: jax.Array, *,
                      block_n: int = 512, block_k: int = 1024,
                      out_dtype=None, interpret: bool = False) -> jax.Array:
    """x: (M, K) with small M; w: (K, N) streamed once. -> (M, N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert n % block_n == 0 and k % block_k == 0
    nk = k // block_k
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        functools.partial(_gemv_kernel, nk=nk),
        grid=(n // block_n, nk),
        in_specs=[
            pl.BlockSpec((m, block_k), lambda j, kk: (0, kk)),
            pl.BlockSpec((block_k, block_n), lambda j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w)
