"""Jit'd wrapper for the Jacquard weight-stationary GEMV."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import round_up, use_interpret
from .kernel import jacquard_gemv_raw


@partial(jax.jit, static_argnames=("block_n", "block_k"))
def jacquard_gemv(x: jax.Array, w: jax.Array, *, block_n: int = 512,
                  block_k: int = 1024) -> jax.Array:
    """(..., K) @ (K, N) -> (..., N); intended for small leading dims
    (decode-time batch)."""
    *lead, k = x.shape
    n = w.shape[1]
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, k)
    bn = min(block_n, n)
    bk = min(block_k, k)
    np_, kp = round_up(n, bn), round_up(k, bk)
    if kp != k:
        x2 = jnp.pad(x2, ((0, 0), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    out = jacquard_gemv_raw(x2, wp, block_n=bn, block_k=bk,
                            out_dtype=x.dtype, interpret=use_interpret())
    return out[:, :n].reshape(*lead, n)
