from .kernel import jacquard_gemv_raw
from .ops import jacquard_gemv
from .ref import jacquard_gemv_ref

__all__ = ["jacquard_gemv", "jacquard_gemv_raw", "jacquard_gemv_ref"]
