"""Pallas TPU kernels for the performance-critical compute layers.

Each kernel subpackage has kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd public wrapper, interpret mode off-TPU), and ref.py
(pure-jnp oracle used by the allclose tests):

  pascal_matmul   -- output-stationary MXU matmul (Mensa Pascal dataflow)
  jacquard_gemv   -- weight-stationary streaming GEMV (Jacquard dataflow)
  pavlov_lstm     -- fused LSTM recurrence, W_h VMEM-resident (Pavlov)
  pavlov_rglru    -- RG-LRU gated linear recurrence (Pavlov)
  pavlov_ssm      -- fused Mamba selective scan (Pavlov)
  flash_attention -- blockwise online-softmax attention
"""
