from .kernel import pavlov_rglru_raw
from .ops import pavlov_rglru
from .ref import pavlov_rglru_ref

__all__ = ["pavlov_rglru", "pavlov_rglru_raw", "pavlov_rglru_ref"]
