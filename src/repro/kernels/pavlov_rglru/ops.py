"""Jit'd wrapper for the Pavlov RG-LRU linear-recurrence kernel."""
from __future__ import annotations

from functools import partial

import jax

from ..common import use_interpret
from .kernel import pavlov_rglru_raw


@partial(jax.jit, static_argnames=("block_t", "block_e"))
def pavlov_rglru(a: jax.Array, b: jax.Array, *, block_t: int = 128,
                 block_e: int = 512) -> jax.Array:
    return pavlov_rglru_raw(a, b, block_t=block_t, block_e=block_e,
                            interpret=use_interpret())
