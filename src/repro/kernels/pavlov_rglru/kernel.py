"""Pavlov RG-LRU kernel — gated linear recurrence with VMEM-resident state.

h_t = a_t * h_{t-1} + b_t, elementwise over the recurrence width E.  The grid
tiles E across cores (each E-tile's recurrence is independent) and walks T
sequentially innermost; the running state h lives in VMEM scratch, giving the
Pavlov temporal-reduction pattern (state never leaves the core between steps).
Each (a, b) element streams from HBM exactly once — sequential, full-bandwidth
access, which is the whole point of the Pavlov design for zero-reuse data.

Inputs are the precomputed per-step decay a and driving term b (the gate
projections are large GEMMs hoisted out of the recurrence — the decoupled
schedule again).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, bt: int):
    t_idx = pl.program_id(1)

    @pl.when(t_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)      # (B, bt, be)
    b = b_ref[...].astype(jnp.float32)

    def step(i, h):
        h = a[:, i, :] * h + b[:, i, :]
        o_ref[:, i, :] = h.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, bt, step, h_ref[...])


def pavlov_rglru_raw(a: jax.Array, b: jax.Array, *, block_t: int = 128,
                     block_e: int = 512, interpret: bool = False) -> jax.Array:
    """a, b: (B, T, E) -> h: (B, T, E) with h_t = a_t*h_{t-1} + b_t."""
    bb, t, e = a.shape
    block_t = min(block_t, t)
    block_e = min(block_e, e)
    assert t % block_t == 0 and e % block_e == 0, (a.shape, block_t, block_e)
    return pl.pallas_call(
        functools.partial(_rglru_kernel, bt=block_t),
        grid=(e // block_e, t // block_t),   # E outer, T sequential inner
        in_specs=[
            pl.BlockSpec((bb, block_t, block_e), lambda j, tt: (0, tt, j)),
            pl.BlockSpec((bb, block_t, block_e), lambda j, tt: (0, tt, j)),
        ],
        out_specs=pl.BlockSpec((bb, block_t, block_e),
                               lambda j, tt: (0, tt, j)),
        out_shape=jax.ShapeDtypeStruct((bb, t, e), a.dtype),
        scratch_shapes=[pltpu.VMEM((bb, block_e), jnp.float32)],
        interpret=interpret,
    )(a, b)
