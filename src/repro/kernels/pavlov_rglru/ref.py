"""Pure-jnp oracle: first-order linear recurrence via associative scan."""
import jax
import jax.numpy as jnp


def pavlov_rglru_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t, h_{-1} = 0.  a,b: (B,T,E)."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return h.astype(a.dtype)
