"""repro.ckpt"""
