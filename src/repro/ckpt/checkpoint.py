"""Sharded checkpointing with atomic manifest commit and elastic restore.

Layout (one directory per step):
    ckpt_dir/step_000100.tmp/        <- written first
        shard_00000.npz              <- this process's param/opt shards
        tree.json                    <- pytree structure + leaf metadata
    ckpt_dir/step_000100/            <- atomic rename == commit
    ckpt_dir/LATEST                  <- text file, updated last

Fault-tolerance contract:
  * a crash mid-write leaves only *.tmp, which restore ignores and a later
    save overwrites — a checkpoint is visible iff it is complete;
  * ``latest_step`` + ``restore`` implement auto-resume;
  * restore reshards: each leaf is saved un-sharded per-process chunk with its
    global offsets, so a job restarted on a DIFFERENT mesh/process-count
    reassembles the global array and re-shards to the new topology (elastic
    scaling).  On one host the chunk is the full array and restore is exact.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def jnp_astype(arr, dtype):
    return np.asarray(jnp.asarray(arr).astype(dtype))


def _leaf_paths(tree: PyTree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        out.append((jax.tree_util.keystr(path), leaf))
    return out


def save(ckpt_dir: str | Path, step: int, tree: PyTree,
         process_index: int | None = None) -> Path:
    """Write this process's shards + manifest; atomic-commit the directory."""
    ckpt_dir = Path(ckpt_dir)
    pidx = jax.process_index() if process_index is None else process_index
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    final = ckpt_dir / f"step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)

    leaves = _leaf_paths(tree)
    arrays = {}
    meta = []
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if dtype_name not in ("float64", "float32", "float16", "int64",
                              "int32", "int16", "int8", "uint8", "uint16",
                              "uint32", "uint64", "bool"):
            # npz cannot round-trip ml_dtypes (bf16/fp8): store a raw view
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        arrays[f"leaf_{i}"] = arr
        meta.append({"path": name, "shape": list(arr.shape),
                     "dtype": dtype_name})
    np.savez(tmp / f"shard_{pidx:05d}.npz", **arrays)
    (tmp / "tree.json").write_text(json.dumps(
        {"step": step, "leaves": meta, "num_processes": jax.process_count()}))
    if pidx == 0:
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                     # atomic commit
        (ckpt_dir / "LATEST").write_text(str(step))
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    marker = ckpt_dir / "LATEST"
    if marker.exists():
        step = int(marker.read_text().strip())
        if (ckpt_dir / f"step_{step:08d}" / "tree.json").exists():
            return step
    # fall back to scanning committed directories
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
                   if p.is_dir() and not p.name.endswith(".tmp")
                   and (p / "tree.json").exists())
    return steps[-1] if steps else None


def restore(ckpt_dir: str | Path, step: int, target: PyTree,
            shardings: PyTree | None = None) -> PyTree:
    """Restore into the structure of `target` (a pytree of arrays or
    ShapeDtypeStructs).  If `shardings` is given, device_put each leaf with
    its (possibly different — elastic) sharding."""
    ckpt_dir = Path(ckpt_dir)
    d = ckpt_dir / f"step_{step:08d}"
    meta = json.loads((d / "tree.json").read_text())
    shard_files = sorted(d.glob("shard_*.npz"))
    assert shard_files, f"no shards in {d}"
    import ml_dtypes
    data = np.load(shard_files[0])        # single-host: full arrays
    leaves = []
    for i, m in enumerate(meta["leaves"]):
        arr = data[f"leaf_{i}"]
        if str(arr.dtype) != m["dtype"]:  # stored as a raw uint view
            arr = arr.view(np.dtype(getattr(ml_dtypes, m["dtype"], m["dtype"])))
        leaves.append(arr)

    target_leaves, treedef = jax.tree_util.tree_flatten(target)
    assert len(target_leaves) == len(leaves), \
        f"checkpoint has {len(leaves)} leaves, target {len(target_leaves)}"
    out = []
    if shardings is not None:
        shard_leaves = treedef.flatten_up_to(shardings)
    else:
        shard_leaves = [None] * len(leaves)
    for arr, tgt, shd in zip(leaves, target_leaves, shard_leaves):
        assert tuple(arr.shape) == tuple(tgt.shape), \
            f"shape mismatch {arr.shape} vs {tgt.shape}"
        if arr.dtype != tgt.dtype:
            arr = np.asarray(jnp_astype(arr, tgt.dtype))
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return treedef.unflatten(out)
