"""JL004 optional-dep: hard top-level imports of optional dev dependencies.

A module-level ``import hypothesis`` in any test file kills *collection* of
the whole module on an environment without the wheel — PR 1's seed state had
exactly this, and the tier-1 suite reported collection errors instead of
test results.  The contract (requirements-dev.txt): optional dev deps are
imported inside a guard, and every property test has a seeded-parametrize
fallback.

Flags ``import X`` / ``from X import ...`` of configured optional modules
(default: ``hypothesis``) at module level in test files, unless the import
sits inside ``try/except ImportError`` (or ``ModuleNotFoundError``) or an
``if TYPE_CHECKING:`` block.  Function-local imports are fine — they only
run when the test that needs them runs.
"""
from __future__ import annotations

import ast

from ..astutil import dotted_name
from ..findings import Severity
from ..registry import Rule, register

_DEFAULT_MODULES = ("hypothesis",)


def _guarded_by_import_error(handlers) -> bool:
    for h in handlers:
        if h.type is None:
            return True
        types = h.type.elts if isinstance(h.type, ast.Tuple) else [h.type]
        for t in types:
            if dotted_name(t).rsplit(".", 1)[-1] in (
                    "ImportError", "ModuleNotFoundError", "Exception"):
                return True
    return False


def _is_type_checking(test: ast.AST) -> bool:
    return dotted_name(test).rsplit(".", 1)[-1] == "TYPE_CHECKING"


@register
class OptionalDep(Rule):
    id = "JL004"
    name = "optional-dep"
    severity = Severity.ERROR
    paths = ("tests/*", "*/tests/*")

    def check(self, mod, options):
        modules = tuple(options.get("modules", _DEFAULT_MODULES))
        yield from self._scan(mod, mod.tree.body, modules)

    def _scan(self, mod, body, modules, guarded: bool = False):
        for stmt in body:
            if isinstance(stmt, (ast.Import, ast.ImportFrom)):
                if guarded:
                    continue
                names = [a.name for a in stmt.names] \
                    if isinstance(stmt, ast.Import) else [stmt.module or ""]
                for name in names:
                    root = name.split(".")[0]
                    if root in modules:
                        yield self.finding(
                            mod, stmt,
                            f"top-level import of optional dev dependency "
                            f"`{root}` breaks collection when the wheel is "
                            f"absent — guard with try/except ImportError or "
                            f"import inside the test")
            elif isinstance(stmt, ast.Try):
                ok = _guarded_by_import_error(stmt.handlers)
                yield from self._scan(mod, stmt.body, modules,
                                      guarded=guarded or ok)
                for h in stmt.handlers:
                    yield from self._scan(mod, h.body, modules, guarded)
                yield from self._scan(mod, stmt.orelse, modules, guarded)
                yield from self._scan(mod, stmt.finalbody, modules, guarded)
            elif isinstance(stmt, ast.If):
                ok = _is_type_checking(stmt.test)
                yield from self._scan(mod, stmt.body, modules,
                                      guarded=guarded or ok)
                yield from self._scan(mod, stmt.orelse, modules, guarded)
