"""JL006 compile-inventory: the zero-recompile invariant, checked statically.

``benchmarks/serve_bench.py`` asserts zero recompiles after warmup — at
runtime, after compiling the engine and running a trace.  This rule proves
the same property's *structure* before anything runs, on any class that owns
jitted programs (``self.X = jax.jit(...)`` in ``__init__`` — in this repo,
``serve.engine.ServeEngine``):

  * every program constructor lives in ``__init__`` — a ``jax.jit`` call in
    any other method mints programs outside the declared inventory;
  * the class has a ``warmup`` method, and every program that has a runtime
    call site is also called (directly or through same-class helpers) from
    ``warmup`` — an unwarmed program compiles on its first real request,
    which is a latency spike in serving and a hole in the bench's gate;
  * no array fed to a program takes its shape from ``len(...)`` — a
    ``np.zeros((len(xs), ...))`` at a program call site keys the compile
    cache on data cardinality (the exact pre-batch-bucketing bug: one
    compile per admission-group size).
"""
from __future__ import annotations

import ast

from ..astutil import dotted_name, is_jit_callable
from ..findings import Severity
from ..registry import Rule, register

_ARRAY_CTORS = ("zeros", "ones", "full", "empty")


def _jit_value(value: ast.AST) -> ast.Call | None:
    """The ``jax.jit(...)`` call inside an assigned value, seeing through
    ``x if cond else None``-style conditional constructors."""
    candidates = [value]
    if isinstance(value, ast.IfExp):
        candidates = [value.body, value.orelse]
    for c in candidates:
        if isinstance(c, ast.Call) and is_jit_callable(c.func):
            return c
    return None


def _self_attr_calls(func: ast.AST) -> set:
    """Names X for every ``self.X(...)`` call in the function."""
    out = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == "self":
            out.add(node.func.attr)
    return out


@register
class CompileInventory(Rule):
    id = "JL006"
    name = "compile-inventory"
    severity = Severity.ERROR

    def check(self, mod, options):
        warmup_name = options.get("warmup_method", "warmup")
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                yield from self._check_class(mod, node, warmup_name)

    def _check_class(self, mod, cls: ast.ClassDef, warmup_name: str):
        methods = {n.name: n for n in cls.body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        init = methods.get("__init__")

        # -------- program constructors: self.X = jax.jit(...) in __init__
        programs: dict = {}
        if init is not None:
            for stmt in ast.walk(init):
                if not isinstance(stmt, ast.Assign):
                    continue
                call = _jit_value(stmt.value)
                if call is None:
                    continue
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Attribute) \
                            and isinstance(tgt.value, ast.Name) \
                            and tgt.value.id == "self":
                        programs[tgt.attr] = stmt

        # -------- jit constructors outside __init__ leak the inventory
        for name, func in methods.items():
            if name == "__init__":
                continue
            for node in ast.walk(func):
                if isinstance(node, ast.Call) and is_jit_callable(node.func):
                    yield self.finding(
                        mod, node,
                        f"`jax.jit` inside `{cls.name}.{name}`: program "
                        f"constructors belong in __init__ so the compiled "
                        f"inventory is enumerable (and warmable)")

        if not programs:
            return

        warmup = methods.get(warmup_name)
        if warmup is None:
            yield self.finding(
                mod, cls,
                f"`{cls.name}` owns jitted programs "
                f"({', '.join(sorted(programs))}) but has no "
                f"`{warmup_name}()` to close the compiled inventory")
            return

        # -------- warmed = programs reachable from warmup via self.* calls
        warmed: set = set()
        frontier = [warmup_name]
        seen = {warmup_name}
        while frontier:
            func = methods.get(frontier.pop())
            if func is None:
                continue
            for attr in _self_attr_calls(func):
                if attr in programs:
                    warmed.add(attr)
                elif attr in methods and attr not in seen:
                    seen.add(attr)
                    frontier.append(attr)

        # -------- every runtime call site of an unwarmed program is a leak
        for name, func in methods.items():
            if name in ("__init__", warmup_name):
                continue
            for node in ast.walk(func):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and isinstance(node.func.value, ast.Name) \
                        and node.func.value.id == "self" \
                        and node.func.attr in programs \
                        and node.func.attr not in warmed:
                    yield self.finding(
                        mod, node,
                        f"program `self.{node.func.attr}` is called at "
                        f"serving time but never from `{warmup_name}()` — "
                        f"its first real call compiles outside the warmed "
                        f"inventory")

        # -------- shapes fed to programs must not key on data cardinality
        for name, func in methods.items():
            if name == "__init__":
                continue
            yield from self._check_len_shapes(mod, cls, name, func)

    def _check_len_shapes(self, mod, cls, name, func):
        for node in ast.walk(func):
            if not (isinstance(node, ast.Call)
                    and dotted_name(node.func).rsplit(".", 1)[-1]
                    in _ARRAY_CTORS and node.args):
                continue
            shape = node.args[0]
            elts = shape.elts if isinstance(shape, (ast.Tuple, ast.List)) \
                else [shape]
            for e in elts:
                if isinstance(e, ast.Call) and dotted_name(e.func) == "len":
                    yield self.finding(
                        mod, e,
                        f"array shape takes `{mod.segment(e)}` in "
                        f"`{cls.name}.{name}`: shapes reaching compiled "
                        f"programs must come from the bucket ladder, not "
                        f"data cardinality — one compile per distinct "
                        f"count otherwise")
