"""JL002 config-literal: hardware-magnitude constants outside accelerators.py.

``mensa.summarize`` once hardcoded a 2e12 peak-FLOPS (PR 1's bug class):
utilization math silently keyed to one accelerator no matter which config
was under analysis.  The invariant since then: every peak-FLOPS / bandwidth /
byte-budget magnitude lives in ``core/accelerators.py`` (or ``configs/``)
and is *imported*, so a design-point change edits one file.

The rule flags decimal numeric literals in the hardware-magnitude band
(default |v| in [1e9, 1e15): GB/s bandwidths through hundreds of TFLOP/s)
in ``src/``, excluding the config homes.  Deliberate blind spots, so the
band stays quiet enough to gate on:

  * hex/octal/binary literals (bit masks, e.g. ``0x7FFFFFFF``);
  * exact powers of ten (``1e9``/``1e12`` are unit conversions far more
    often than they are hardware constants).
"""
from __future__ import annotations

import ast

from ..astutil import literal_source_is_decimal
from ..findings import Severity
from ..registry import Rule, register
from fnmatch import fnmatch

_DEFAULT_ALLOW = ("src/repro/core/accelerators.py", "src/repro/configs/*")


def _is_power_of_ten(v: float) -> bool:
    while v >= 10 and v == int(v) and int(v) % 10 == 0:
        v /= 10
    return v == 1.0


@register
class ConfigLiteral(Rule):
    id = "JL002"
    name = "config-literal"
    severity = Severity.ERROR
    paths = ("src/*",)

    def check(self, mod, options):
        lo = float(options.get("min_magnitude", 1e9))
        hi = float(options.get("max_magnitude", 1e15))
        allow = tuple(options.get("allow_paths", _DEFAULT_ALLOW))
        if any(fnmatch(mod.relpath, p) for p in allow):
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Constant):
                continue
            v = node.value
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            mag = abs(float(v))
            if not lo <= mag < hi:
                continue
            if _is_power_of_ten(mag):
                continue
            if not literal_source_is_decimal(mod, node):
                continue
            yield self.finding(
                mod, node,
                f"hardware-magnitude literal {v!r}: peak-FLOPS/bandwidth/"
                f"byte-budget constants belong in core/accelerators.py (or "
                f"configs/) and get imported from there")
