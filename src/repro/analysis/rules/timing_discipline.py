"""JL008 timing-discipline: host clocks vs JAX's async dispatch.

``time.perf_counter()`` around a jitted call stamps *dispatch*, not
execution — the call returns as soon as XLA enqueues the work, so the
"measured" interval is microseconds of Python while the device still runs.
This is exactly the under-reporting bug the serving engine's chunked-prefill
path shipped with until the observability layer routed every timed section
through :class:`repro.obs.Timed` (which calls ``jax.block_until_ready``
before stamping ``t1``).

Two checks:

  * a host-clock call (``time.time`` / ``time.perf_counter`` /
    ``time.monotonic`` and their ``_ns`` variants) inside a *jit-reachable*
    function — ERROR.  At trace time the clock freezes into the compiled
    program as a constant; there is no correct use.
  * a host-side function that brackets work between two or more host-clock
    calls with no synchronization marker anywhere in its body — WARNING
    (gates ``--strict``).  Markers: ``jax.block_until_ready``, a ``Timed``
    section (``Timed(...)`` / ``self._timed(...)`` / ``tm.sync(...)``),
    ``jax.device_get``, or an ``asarray``/``np.array`` materialization.
    This is a per-function heuristic, not a dataflow proof: it cannot pair
    each clock read with its section, so a single marker clears the whole
    function.  Timing that wraps genuinely blocking host work (``.lower()``
    / ``.compile()``, file IO) is a legitimate pragma site.
"""
from __future__ import annotations

import ast

from ..astutil import dotted_name, jit_reachability
from ..findings import Severity
from ..registry import Rule, register

_CLOCKS = frozenset({
    "time.time", "time.perf_counter", "time.monotonic",
    "time.time_ns", "time.perf_counter_ns", "time.monotonic_ns",
})

# call-name suffixes that force (or encapsulate) a device sync
_SYNC_SUFFIXES = ("block_until_ready", "device_get", "asarray", "sync")
_SYNC_NAMES = frozenset({"Timed", "np.array", "numpy.array"})


def _clock_calls(func: ast.AST) -> list:
    out = []
    for node in ast.walk(func):
        if isinstance(node, ast.Call) and dotted_name(node.func) in _CLOCKS:
            out.append(node)
    return sorted(out, key=lambda n: (n.lineno, n.col_offset))


def _has_sync_marker(func: ast.AST) -> bool:
    for node in ast.walk(func):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name in _SYNC_NAMES:
            return True
        bare = name.rsplit(".", 1)[-1]
        if bare.endswith(_SYNC_SUFFIXES) or bare == "_timed":
            return True
    return False


@register
class TimingDiscipline(Rule):
    id = "JL008"
    name = "timing-discipline"
    severity = Severity.ERROR

    def check(self, mod, options):
        reach = jit_reachability(mod)
        seen = set()
        for name in sorted(reach.reachable):
            for func in reach.functions.get(name, []):
                seen.add(func)
                for call in _clock_calls(func):
                    yield self.finding(
                        mod, call,
                        f"host clock `{dotted_name(call.func)}` inside "
                        f"jit-reachable `{func.name}` freezes into the "
                        f"traced program as a constant — clock on the host "
                        f"side of the jit boundary")

        for funcs in reach.functions.values():
            for func in funcs:
                if func in seen:
                    continue
                clocks = _clock_calls(func)
                if len(clocks) < 2 or _has_sync_marker(func):
                    continue
                yield self.finding(
                    mod, clocks[1],
                    f"`{func.name}` times a section between host-clock "
                    f"reads with no device sync in scope — under JAX's "
                    f"async dispatch this stamps enqueue time, not "
                    f"execution; route it through `repro.obs.Timed` and "
                    f"`sync()` before reading the clock",
                    severity=Severity.WARNING)
