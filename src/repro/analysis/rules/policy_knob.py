"""JL007 policy-owned-knob: serving code must not read execution knobs.

The placement refactor moved ownership of the runtime-safe execution knobs
(kernel variants, scan chunking, attention blocking, remat) out of the
serving layer: ``serve/placement.ExecutionOracle`` resolves them per layer
cluster into an ``ExecutionPolicy``, and they reach the engine only as
``cfg_overrides`` merged by ``core/executor.phase_profiles``.  An engine
that reads ``cfg.attn_impl`` (or branches on ``cfg.scan_chunk``) re-opens
the split-brain the refactor closed — two places deciding how a phase
lowers, which is exactly how a "zero recompiles after warmup" invariant
rots: the oracle picks one variant, the engine quietly another, and the
divergence only shows up as a mid-serve recompile.

The rule flags any attribute access whose name is a policy-owned knob
inside ``src/repro/serve/`` — reads and writes alike (a write is the same
ownership violation with worse aim).  ``serve/placement.py`` is the owner
and is allowed by default (``allow_paths``); model/core code is out of
scope (models *consume* the knobs; the executor *merges* them — both by
design).
"""
from __future__ import annotations

import ast
from fnmatch import fnmatch

from ..findings import Severity
from ..registry import Rule, register

# the runtime-safe execution knobs (core/executor.RUNTIME_SAFE_KEYS) — the
# set the oracle owns.  Mirrored literally rather than imported: jitlint is
# stdlib-only and must run in the no-jax lint job.
_KNOBS = frozenset({
    "remat", "moe_impl", "unroll_scans", "scan_chunk", "attn_block_kv",
    "attn_f32", "attn_impl", "rglru_impl", "ssm_impl",
})

_DEFAULT_ALLOW = ("src/repro/serve/placement.py",)


@register
class PolicyOwnedKnob(Rule):
    id = "JL007"
    name = "policy-owned-knob"
    severity = Severity.ERROR
    paths = ("src/repro/serve/*",)

    def check(self, mod, options):
        allow = tuple(options.get("allow_paths", _DEFAULT_ALLOW))
        if any(fnmatch(mod.relpath, p) for p in allow):
            return
        knobs = frozenset(options.get("knobs", _KNOBS))
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and node.attr in knobs:
                yield self.finding(
                    mod, node,
                    f"serving code accesses policy-owned knob "
                    f"'{node.attr}': execution knobs are resolved per "
                    f"cluster by serve/placement.ExecutionOracle and reach "
                    f"the engine only as phase-profile cfg_overrides "
                    f"(core/executor.phase_profiles)")
            elif isinstance(node, ast.keyword) and node.arg in knobs:
                # cfg.replace(attn_impl=...) — the engine picking a kernel
                # variant by hand is the same ownership violation
                yield self.finding(
                    mod, node.value,
                    f"serving code sets policy-owned knob '{node.arg}' "
                    f"directly: kernel-variant / chunking decisions belong "
                    f"to the placement oracle's ExecutionPolicy")
