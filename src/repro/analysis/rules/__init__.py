"""Built-in jitlint rules — importing this package registers them all."""
from . import (  # noqa: F401
    api_drift,
    compile_inventory,
    config_literal,
    optional_dep,
    pallas_spec,
    policy_knob,
    recompile_hazard,
    timing_discipline,
)
