"""JL003 api-drift: raw ``.cost_analysis()`` access.

``compiled.cost_analysis()`` returned a dict for years, then newer JAX made
it a list with one dict per executable program — code indexing the old shape
crashes (or worse, silently reads the wrong program).  PR 1 centralized the
flattening in ``utils/hlo.normalize_cost_analysis``; this rule pins that
routing: any ``X.cost_analysis()`` call must appear as the *direct argument*
of ``normalize_cost_analysis(...)`` (or live in ``utils/hlo.py`` itself,
which owns the normalization).
"""
from __future__ import annotations

import ast

from ..astutil import dotted_name
from ..findings import Severity
from ..registry import Rule, register

_NORMALIZER = "normalize_cost_analysis"
_OWNER_SUFFIX = "utils/hlo.py"


@register
class ApiDrift(Rule):
    id = "JL003"
    name = "api-drift"
    severity = Severity.ERROR

    def check(self, mod, options):
        owner = options.get("owner_suffix", _OWNER_SUFFIX)
        if mod.relpath.endswith(owner):
            return
        wrapped = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) \
                    and dotted_name(node.func).rsplit(".", 1)[-1] \
                    == _NORMALIZER:
                wrapped.update(id(a) for a in node.args)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if not (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "cost_analysis"):
                continue
            if id(node) in wrapped:
                continue
            yield self.finding(
                mod, node,
                "raw `.cost_analysis()` access: the return shape drifts "
                "across JAX versions — route it through "
                "`utils.hlo.normalize_cost_analysis(compiled."
                "cost_analysis())`")
