"""JL003 api-drift: raw ``.cost_analysis()`` / ``.memory_analysis()`` access.

``compiled.cost_analysis()`` returned a dict for years, then newer JAX made
it a list with one dict per executable program — code indexing the old shape
crashes (or worse, silently reads the wrong program).  PR 1 centralized the
flattening in ``utils/hlo.normalize_cost_analysis``; this rule pins that
routing: any ``X.cost_analysis()`` call must appear as the *direct argument*
of ``normalize_cost_analysis(...)`` (or live in ``utils/hlo.py`` itself,
which owns the normalization).  ``compiled.memory_analysis()`` drifts the
same way (``CompiledMemoryStats`` object vs per-program list vs ``None`` on
backends without it) and gets the same treatment through
``normalize_memory_analysis``.
"""
from __future__ import annotations

import ast

from ..astutil import dotted_name
from ..findings import Severity
from ..registry import Rule, register

#: raw accessor -> the utils/hlo normalizer that must wrap it directly
_NORMALIZERS = {
    "cost_analysis": "normalize_cost_analysis",
    "memory_analysis": "normalize_memory_analysis",
}
_OWNER_SUFFIX = "utils/hlo.py"


@register
class ApiDrift(Rule):
    id = "JL003"
    name = "api-drift"
    severity = Severity.ERROR

    def check(self, mod, options):
        owner = options.get("owner_suffix", _OWNER_SUFFIX)
        if mod.relpath.endswith(owner):
            return
        normalizers = set(_NORMALIZERS.values())
        wrapped = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) \
                    and dotted_name(node.func).rsplit(".", 1)[-1] \
                    in normalizers:
                wrapped.update(id(a) for a in node.args)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if not (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _NORMALIZERS):
                continue
            if id(node) in wrapped:
                continue
            accessor = node.func.attr
            yield self.finding(
                mod, node,
                f"raw `.{accessor}()` access: the return shape drifts "
                f"across JAX versions — route it through "
                f"`utils.hlo.{_NORMALIZERS[accessor]}(compiled."
                f"{accessor}())`")
