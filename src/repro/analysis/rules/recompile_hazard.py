"""JL001 recompile-hazard: trace-time concretization and per-call programs.

Inside jit-reachable functions (see ``astutil.jit_reachability``):

  * ``x.item()`` — concretizes a traced value; at best a device sync, at
    trace time a ``ConcretizationTypeError`` waiting for the right input.
  * ``int(x)`` / ``float(x)`` / ``bool(x)`` on a non-literal — same failure
    mode, the form that actually bit PR 1's bucketing path.
  * ``if``/``while`` on a ``.shape``-derived expression — legal (shapes are
    static) but every distinct shape now mints a distinct program; in the
    serving hot path that is exactly the unbounded-inventory bug the bucket
    ladder exists to prevent.  WARNING severity: it gates only --strict.

Anywhere in the module (reachability not required):

  * ``jax.jit(f)(args)`` — the wrapper (and its compile cache) dies with the
    expression, so every execution recompiles.
  * ``jax.jit(<lambda or locally-defined function>)`` inside a function
    body — a fresh callable per call means a fresh cache key per call.
  * passing a ``list``/``dict``/``set`` literal for a known static argname —
    unhashable static args raise at call time on newer JAX and silently
    defeat caching on older.
"""
from __future__ import annotations

import ast

from ..astutil import (FunctionNode, dotted_name, enclosing_function,
                       is_jit_callable, jit_reachability, jit_static_argnames,
                       unwrap_partial)
from ..findings import Severity
from ..registry import Rule, register

_CASTS = ("int", "float", "bool")


def _is_safe_cast_arg(arg: ast.AST) -> bool:
    """Casts of literals and of host-side ``len(...)`` are not hazards."""
    if isinstance(arg, ast.Constant):
        return True
    if isinstance(arg, ast.Call) and dotted_name(arg.func) == "len":
        return True
    return False


def _mentions_shape(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr == "shape"
               for n in ast.walk(node))


@register
class RecompileHazard(Rule):
    id = "JL001"
    name = "recompile-hazard"
    severity = Severity.ERROR

    def check(self, mod, options):
        reach = jit_reachability(mod)

        for name in sorted(reach.reachable):
            for func in reach.functions.get(name, []):
                yield from self._check_traced_body(mod, func)

        yield from self._check_jit_sites(mod, reach)
        yield from self._check_static_args(mod, reach)

    # ------------------------------------------------ traced-value hazards
    def _check_traced_body(self, mod, func):
        for node in ast.walk(func):
            if isinstance(node, ast.Call):
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" and not node.args:
                    yield self.finding(
                        mod, node,
                        f"`.item()` inside jit-reachable `{func.name}` "
                        f"concretizes a traced value (sync or trace error)")
                elif isinstance(node.func, ast.Name) \
                        and node.func.id in _CASTS \
                        and len(node.args) == 1 and not node.keywords \
                        and not _is_safe_cast_arg(node.args[0]):
                    yield self.finding(
                        mod, node,
                        f"`{node.func.id}(...)` on a non-literal inside "
                        f"jit-reachable `{func.name}` concretizes a traced "
                        f"value; hoist it to the host side of the call")
            elif isinstance(node, (ast.If, ast.While)) \
                    and _mentions_shape(node.test):
                yield self.finding(
                    mod, node.test,
                    f"branch on `.shape` inside jit-reachable `{func.name}`: "
                    f"every distinct shape mints a distinct compiled "
                    f"program — route shapes through the bucket ladder",
                    severity=Severity.WARNING)

    # -------------------------------------------------- per-call jit mints
    def _check_jit_sites(self, mod, reach):
        for call in reach.jit_calls:
            parent = mod.parent(call)
            if isinstance(parent, ast.Call) and parent.func is call:
                yield self.finding(
                    mod, call,
                    "`jax.jit(f)(...)` builds a fresh wrapper per call — its "
                    "compile cache dies with the expression; bind the jitted "
                    "function once and reuse it")
            if not call.args:
                continue
            target = call.args[0]
            inner = unwrap_partial(target) if isinstance(target, ast.Call) \
                else None
            candidate = inner if inner is not None else target
            if enclosing_function(mod, call) is None:
                continue                     # module-level binding: built once
            if isinstance(candidate, ast.Lambda):
                yield self.finding(
                    mod, call,
                    "`jax.jit` over a lambda inside a function body mints a "
                    "fresh cache key per call (program-inventory leak)")
            elif isinstance(candidate, ast.Name):
                func = enclosing_function(mod, call)
                local_defs = {n.name for n in ast.walk(func)
                              if isinstance(n, FunctionNode)}
                if candidate.id in local_defs:
                    yield self.finding(
                        mod, call,
                        f"`jax.jit({candidate.id})` over a function defined "
                        f"in the enclosing body mints a fresh cache key per "
                        f"call (program-inventory leak)")

    # --------------------------------------------- unhashable static args
    def _check_static_args(self, mod, reach):
        statics = {}
        for name, funcs in reach.functions.items():
            for func in funcs:
                argnames = jit_static_argnames(func)
                if argnames:
                    statics[name] = argnames
        if not statics:
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            bare = dotted_name(node.func).rsplit(".", 1)[-1]
            declared = statics.get(bare)
            if not declared:
                continue
            for kw in node.keywords:
                if kw.arg in declared \
                        and isinstance(kw.value,
                                       (ast.List, ast.Dict, ast.Set)):
                    yield self.finding(
                        mod, kw.value,
                        f"unhashable {type(kw.value).__name__.lower()} "
                        f"literal for static argname `{kw.arg}` of "
                        f"`{bare}` — every call re-traces (use a tuple)")
