"""JL005 pallas-spec: grid/BlockSpec discipline in ``kernels/``.

Pallas failure modes this repo has to re-learn the hard way every time they
ship: an ``index_map`` whose arity silently disagrees with the grid (lambdas
are not arity-checked until trace time, and under interpret mode some
mismatches "work"), a grid built with ``//`` that drops the array's
remainder rows, and scalar-prefetch operands miscounted against the kernel
signature.  All three are statically visible in the call expression:

  * **index-map arity** — every ``BlockSpec`` index_map lambda must take
    ``len(grid)`` args, plus ``num_scalar_prefetch`` trailing refs under
    ``pltpu.PrefetchScalarGridSpec`` (the prefetch operands are appended to
    the index-map signature).
  * **dropped remainder** — a grid element ``X // b`` needs a divisibility
    guard (an ``assert`` mentioning ``% b``) in the enclosing function;
    ``pl.cdiv(X, b)``-shaped elements need masking in the kernel body
    (``pl.when`` / ``jnp.where`` / an iota-based bound check) since the last
    block runs past the array.
  * **scalar-prefetch arity** — the kernel function must take exactly
    ``num_scalar_prefetch + len(in_specs) + n_out + len(scratch_shapes)``
    refs, and the pallas_call invocation must pass
    ``num_scalar_prefetch + len(in_specs)`` operands (scalars first).

Checks only fire when the relevant expressions are statically literal
(tuple grids, list in_specs, same-module kernel defs) — anything dynamic is
skipped, not guessed at.
"""
from __future__ import annotations

import ast

from ..astutil import FunctionNode, dotted_name, enclosing_function
from ..findings import Severity
from ..registry import Rule, register

_MASK_MARKERS = ("when", "where", "iota", "broadcasted_iota")


def _bare(node: ast.AST) -> str:
    return dotted_name(node).rsplit(".", 1)[-1]


def _kwarg(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _resolve_name(mod, scope: ast.AST | None, name: str):
    """Find ``name = <expr>`` in the scope body (else module body)."""
    bodies = []
    if scope is not None:
        bodies.append(scope.body)
    bodies.append(mod.tree.body)
    for body in bodies:
        for stmt in body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and stmt.targets[0].id == name:
                return stmt.value
    return None


def _as_spec_list(node) -> list | None:
    if node is None:
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


def _is_cdiv(node: ast.AST) -> bool:
    """``pl.cdiv(x, b)`` or the ``-(-x // b)`` ceil-div idiom."""
    if isinstance(node, ast.Call) and _bare(node.func) == "cdiv":
        return True
    return (isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub)
            and isinstance(node.operand, ast.BinOp)
            and isinstance(node.operand.op, ast.FloorDiv))


def _mod_guard_names(func: ast.AST | None) -> set:
    """Names appearing on either side of a ``%`` inside an assert test."""
    out: set = set()
    if func is None:
        return out
    for node in ast.walk(func):
        if not isinstance(node, ast.Assert):
            continue
        for sub in ast.walk(node.test):
            if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod):
                for side in (sub.left, sub.right):
                    if isinstance(side, ast.Name):
                        out.add(side.id)
    return out


def _kernel_def(mod, scope, kernel_expr):
    """Resolve the pallas_call kernel operand to (FunctionDef, n_bound):
    a bare name, or ``functools.partial(name, ...)`` with keyword bindings."""
    bound = 0
    target = kernel_expr
    if isinstance(target, ast.Call) \
            and _bare(target.func) == "partial" and target.args:
        bound = len(target.args) - 1      # positionally-bound params
        target = target.args[0]
    if isinstance(target, ast.Name):
        for node in ast.walk(mod.tree):
            if isinstance(node, FunctionNode) and node.name == target.id:
                return node, bound
    return None, bound


@register
class PallasSpec(Rule):
    id = "JL005"
    name = "pallas-spec"
    severity = Severity.ERROR
    paths = ("*kernels/*",)

    def check(self, mod, options):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) \
                    and _bare(node.func) == "pallas_call":
                yield from self._check_call(mod, node)

    # ------------------------------------------------------------ plumbing
    def _check_call(self, mod, call: ast.Call):
        scope = enclosing_function(mod, call)
        grid = _kwarg(call, "grid")
        prefetch = 0
        spec_src = call                   # where in/out/scratch kwargs live
        grid_spec = _kwarg(call, "grid_spec")
        if grid_spec is not None:
            if isinstance(grid_spec, ast.Name):
                grid_spec = _resolve_name(mod, scope, grid_spec.id)
            if isinstance(grid_spec, ast.Call):
                spec_src = grid_spec
                grid = _kwarg(grid_spec, "grid")
                if _bare(grid_spec.func) == "PrefetchScalarGridSpec":
                    n = _kwarg(grid_spec, "num_scalar_prefetch")
                    if isinstance(n, ast.Constant) \
                            and isinstance(n.value, int):
                        prefetch = n.value
            else:
                return                    # dynamic grid_spec: nothing to say

        in_specs = _as_spec_list(_kwarg(spec_src, "in_specs"))
        out_specs = _as_spec_list(_kwarg(spec_src, "out_specs"))
        scratch = _as_spec_list(_kwarg(spec_src, "scratch_shapes")) or []

        rank = len(grid.elts) if isinstance(grid, (ast.Tuple, ast.List)) \
            else None
        if rank is not None:
            yield from self._check_index_maps(
                mod, in_specs, out_specs, rank, prefetch)
            yield from self._check_grid_division(mod, call, scope, grid)
        yield from self._check_arity(mod, call, scope, prefetch,
                                     in_specs, out_specs, scratch)

    # ------------------------------------------------- index-map arity
    def _check_index_maps(self, mod, in_specs, out_specs, rank, prefetch):
        want = rank + prefetch
        for spec in (in_specs or []) + (out_specs or []):
            if not (isinstance(spec, ast.Call)
                    and _bare(spec.func) == "BlockSpec"):
                continue
            index_map = _kwarg(spec, "index_map")
            if index_map is None and len(spec.args) >= 2:
                index_map = spec.args[1]
            if not isinstance(index_map, ast.Lambda):
                continue                  # memory_space-only or indirect
            # default args are closure captures (`lambda h, i, j, g=group:`),
            # never filled by the grid — only non-default args must match
            total = len(index_map.args.args)
            required = total - len(index_map.args.defaults)
            if not required <= want <= total:
                yield self.finding(
                    mod, index_map,
                    f"BlockSpec index_map takes {required} arg(s) but the "
                    f"grid has rank {rank}"
                    + (f" plus {prefetch} scalar-prefetch ref(s)"
                       if prefetch else "")
                    + f" — expected {want}")

    # --------------------------------------------- remainder discipline
    def _check_grid_division(self, mod, call, scope, grid):
        guards = _mod_guard_names(scope)
        kernel_def, _ = _kernel_def(mod, scope, call.args[0]) \
            if call.args else (None, 0)
        masked = kernel_def is not None and any(
            _bare(n.func) in _MASK_MARKERS
            for n in ast.walk(kernel_def) if isinstance(n, ast.Call))
        for elt in grid.elts:
            if isinstance(elt, ast.BinOp) \
                    and isinstance(elt.op, ast.FloorDiv) \
                    and isinstance(elt.right, ast.Name):
                if elt.right.id not in guards and not masked:
                    yield self.finding(
                        mod, elt,
                        f"grid element `{mod.segment(elt)}` floor-divides "
                        f"without an `assert ... % {elt.right.id} == 0` "
                        f"guard or in-kernel masking — remainder rows are "
                        f"silently dropped")
            elif _is_cdiv(elt) and kernel_def is not None and not masked:
                yield self.finding(
                    mod, elt,
                    f"ceil-div grid element `{mod.segment(elt)}` overruns "
                    f"the array on the last block but the kernel has no "
                    f"masking guard (pl.when / jnp.where / iota bound)")

    # ------------------------------------------- scalar-prefetch arity
    def _check_arity(self, mod, call, scope, prefetch, in_specs, out_specs,
                     scratch):
        if in_specs is None:
            return
        n_out = len(out_specs) if out_specs is not None else 1
        want_refs = prefetch + len(in_specs) + n_out + len(scratch)
        kernel_def, bound = _kernel_def(mod, scope, call.args[0]) \
            if call.args else (None, 0)
        if kernel_def is not None:
            a = kernel_def.args
            has_var = a.vararg is not None
            got = len(a.posonlyargs) + len(a.args) - bound
            if not has_var and got != want_refs:
                yield self.finding(
                    mod, call,
                    f"kernel `{kernel_def.name}` takes {got} ref(s) but the "
                    f"specs provide {want_refs} ({prefetch} scalar-prefetch "
                    f"+ {len(in_specs)} in + {n_out} out + {len(scratch)} "
                    f"scratch) — scalar-prefetch operands come first")
        parent = mod.parent(call)
        if isinstance(parent, ast.Call) and parent.func is call \
                and not any(isinstance(a, ast.Starred) for a in parent.args):
            got = len(parent.args)
            want = prefetch + len(in_specs)
            if got != want:
                yield self.finding(
                    mod, parent,
                    f"pallas_call invocation passes {got} operand(s) but "
                    f"the specs expect {want} ({prefetch} scalar-prefetch "
                    f"first, then {len(in_specs)} inputs)")
