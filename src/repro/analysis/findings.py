"""Finding/severity model shared by every jitlint rule."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Severity(enum.Enum):
    # errors gate CI; warnings are reported (and land in the JSON artifact)
    # but only fail the run under --strict
    ERROR = "error"
    WARNING = "warning"

    def __str__(self) -> str:  # pragma: no cover - trivial
        return self.value


@dataclass(frozen=True)
class Finding:
    rule_id: str                # "JL001"
    rule_name: str              # "recompile-hazard"
    severity: Severity
    path: str                   # posix relpath from the lint root
    line: int                   # 1-based
    col: int                    # 0-based, matching ast
    message: str
    end_line: int = 0
    end_col: int = 0
    # set by the runner when an allowlist entry absorbed this finding
    allowed_by: str = field(default="", compare=False)

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}"

    def render(self) -> str:
        return (f"{self.location()} {self.rule_id} {self.rule_name} "
                f"[{self.severity.value}] {self.message}")

    def to_dict(self) -> dict:
        out = {
            "rule_id": self.rule_id,
            "rule_name": self.rule_name,
            "severity": self.severity.value,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "end_line": self.end_line,
            "end_col": self.end_col,
            "message": self.message,
        }
        if self.allowed_by:
            out["allowed_by"] = self.allowed_by
        return out
