"""jitlint.toml loading: excludes, per-rule options, and the allowlist.

The allowlist is the *documented* escape hatch — every entry must carry a
``reason`` so "why is this exempt" lives next to the exemption, not in a PR
discussion nobody can find::

    [jitlint]
    exclude = ["tests/analysis_cases/*"]

    [rules.config-literal]
    allow_paths = ["src/repro/core/accelerators.py"]

    [[allow]]
    rule = "JL002"                     # ID or name; "*" for any rule
    path = "src/repro/launch/shardings.py"
    reason = "20e9 is a parameter-count threshold, not a hardware constant"
    # line = 112                       # optional: pin to one line

Parsing uses stdlib ``tomllib`` (3.11+) with a ``tomli`` fallback; when
neither is importable a present config file is a hard error rather than a
silently unconfigured run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from fnmatch import fnmatch
from pathlib import Path

try:
    import tomllib as _toml
except ImportError:                                    # Python < 3.11
    try:
        import tomli as _toml
    except ImportError:                                # pragma: no cover
        _toml = None

DEFAULT_CONFIG_NAME = "jitlint.toml"


@dataclass(frozen=True)
class AllowEntry:
    rule: str                  # rule ID, rule name, or "*"
    path: str                  # fnmatch pattern over posix relpaths
    reason: str
    line: int = 0              # 0 = any line

    def matches(self, finding) -> bool:
        if self.rule not in ("*", finding.rule_id, finding.rule_name):
            return False
        if self.line and self.line != finding.line:
            return False
        return fnmatch(finding.path, self.path)

    def describe(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"allow[{self.rule} @ {loc}]: {self.reason}"


@dataclass
class LintConfig:
    exclude: list = field(default_factory=list)
    rule_options: dict = field(default_factory=dict)   # rule name -> options
    allow: list = field(default_factory=list)          # [AllowEntry]
    source: str = ""                                   # where it was loaded

    def options_for(self, rule_name: str) -> dict:
        return self.rule_options.get(rule_name, {})

    def excluded(self, relpath: str) -> bool:
        return any(fnmatch(relpath, pat) or relpath.startswith(pat.rstrip("*"))
                   for pat in self.exclude)

    def allowed_by(self, finding) -> AllowEntry | None:
        for entry in self.allow:
            if entry.matches(finding):
                return entry
        return None


def load_config(path: str | Path | None = None,
                root: str | Path = ".") -> LintConfig:
    """Load ``path``, or ``<root>/jitlint.toml`` when it exists, else an
    empty config (rules fall back to their built-in defaults)."""
    if path is None:
        candidate = Path(root) / DEFAULT_CONFIG_NAME
        if not candidate.is_file():
            return LintConfig()
        path = candidate
    path = Path(path)
    if _toml is None:
        raise RuntimeError(
            f"cannot parse {path}: no tomllib/tomli available on this "
            f"interpreter — run jitlint on Python 3.11+ or install tomli")
    data = _toml.loads(path.read_text())
    top = data.get("jitlint", {})
    allow = []
    for raw in data.get("allow", []):
        missing = {"rule", "path", "reason"} - set(raw)
        if missing:
            raise ValueError(f"{path}: [[allow]] entry {raw!r} missing "
                             f"required key(s) {sorted(missing)}")
        if not str(raw["reason"]).strip():
            raise ValueError(f"{path}: [[allow]] entry for {raw['path']!r} "
                             f"has an empty reason — document why")
        allow.append(AllowEntry(rule=str(raw["rule"]), path=str(raw["path"]),
                                reason=str(raw["reason"]),
                                line=int(raw.get("line", 0))))
    return LintConfig(exclude=list(top.get("exclude", [])),
                      rule_options=dict(data.get("rules", {})),
                      allow=allow, source=str(path))
