"""Text and JSON rendering of a LintResult."""
from __future__ import annotations

import json

from .runner import LintResult

JSON_VERSION = 1


def render_text(result: LintResult, *, verbose: bool = False) -> str:
    lines = [f.render() for f in result.findings]
    if verbose and result.allowed:
        lines.append("")
        lines.append("allowlisted (not gating):")
        lines.extend(f"  {f.render()}  <- {f.allowed_by}"
                     for f in result.allowed)
    lines.append(summary_line(result))
    return "\n".join(lines)


def summary_line(result: LintResult) -> str:
    ne, nw = len(result.errors), len(result.warnings)
    extras = []
    if result.allowed:
        extras.append(f"{len(result.allowed)} allowlisted")
    if result.suppressed:
        extras.append(f"{result.suppressed} pragma-suppressed")
    tail = f" ({', '.join(extras)})" if extras else ""
    return (f"jitlint: {ne} error(s), {nw} warning(s){tail} "
            f"across {result.files} file(s)")


def to_json(result: LintResult) -> str:
    return json.dumps({
        "version": JSON_VERSION,
        "files_scanned": result.files,
        "errors": len(result.errors),
        "warnings": len(result.warnings),
        "suppressed": result.suppressed,
        "findings": [f.to_dict() for f in result.findings],
        "allowed": [f.to_dict() for f in result.allowed],
    }, indent=1) + "\n"
