"""Rule registry: rules self-register at import, the runner iterates them.

A rule is a class with ``id`` ("JL001"), ``name`` ("recompile-hazard"),
``severity`` (default for its findings), optional ``paths`` (fnmatch
patterns restricting which relpaths it inspects; overridable per-repo via
``[rules.<name>] paths`` in jitlint.toml), and::

    def check(self, mod: ModuleInfo, options: dict) -> Iterator[Finding]

``options`` is the rule's merged jitlint.toml table.  Rules yield findings
with their own id/name/severity via ``self.finding(...)``.
"""
from __future__ import annotations

from fnmatch import fnmatch

from .findings import Finding, Severity

_RULES: dict = {}               # id -> rule instance


class Rule:
    id: str = ""
    name: str = ""
    severity: Severity = Severity.ERROR
    paths: tuple = ()           # () = every file

    def applies_to(self, relpath: str, options: dict) -> bool:
        patterns = tuple(options.get("paths", self.paths))
        if not patterns:
            return True
        return any(fnmatch(relpath, p) for p in patterns)

    def finding(self, mod, node, message: str, *,
                severity: Severity | None = None) -> Finding:
        return Finding(
            rule_id=self.id, rule_name=self.name,
            severity=severity or self.severity,
            path=mod.relpath,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            end_line=getattr(node, "end_lineno", 0) or 0,
            end_col=getattr(node, "end_col_offset", 0) or 0,
            message=message)

    def check(self, mod, options: dict):  # pragma: no cover - interface
        raise NotImplementedError
        yield


def register(cls):
    """Class decorator: instantiate and index by ID (and reject collisions —
    two rules sharing an ID would make pragmas ambiguous)."""
    rule = cls()
    if not rule.id or not rule.name:
        raise ValueError(f"rule {cls.__name__} must define id and name")
    if rule.id in _RULES or any(r.name == rule.name for r in _RULES.values()):
        raise ValueError(f"duplicate rule id/name: {rule.id} {rule.name}")
    _RULES[rule.id] = rule
    return cls


def all_rules() -> list:
    # ensure the built-in rules have registered themselves
    from . import rules  # noqa: F401
    return [r for _, r in sorted(_RULES.items())]


def get_rule(label: str):
    from . import rules  # noqa: F401
    if label in _RULES:
        return _RULES[label]
    for r in _RULES.values():
        if r.name == label:
            return r
    raise KeyError(label)


def known_labels() -> set:
    from . import rules  # noqa: F401
    out = {"*"}
    for r in _RULES.values():
        out.add(r.id)
        out.add(r.name)
    return out
