"""``# jitlint: ...`` pragma parsing.

Two forms:

  ``# jitlint: ignore[JL001]`` / ``# jitlint: ignore[recompile-hazard]``
      Suppress the named rule(s) (comma-separated; ``*`` for all) on the
      pragma's own line — or, when the pragma is the whole line, on the next
      code line (so long expressions can carry a pragma on the line above).

  ``# jitlint: skip-file``
      Skip the file entirely (must appear in the first 10 lines).

Rules are matched by ID or by name; unknown rule labels are themselves a
finding (a stale pragma silently suppressing nothing is how suppressions
rot), emitted by the runner as JL000.
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field

_PRAGMA_RE = re.compile(r"#\s*jitlint:\s*(skip-file|ignore\[([^\]]*)\])")
_SKIP_FILE_SCAN_LINES = 10


@dataclass
class FilePragmas:
    skip_file: bool = False
    # line (1-based) -> set of rule labels (IDs or names, or "*")
    ignores: dict = field(default_factory=dict)
    # labels seen, with one representative line each (for staleness checks)
    labels: dict = field(default_factory=dict)

    def suppresses(self, line: int, rule_id: str, rule_name: str) -> bool:
        labels = self.ignores.get(line)
        if not labels:
            return False
        return "*" in labels or rule_id in labels or rule_name in labels


def parse_pragmas(source: str) -> FilePragmas:
    """Tokenize-based so pragma text inside string literals (docstrings
    describing the pragma syntax, test fixtures) never counts — only real
    comments carry pragmas."""
    out = FilePragmas()
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError):
        return out                 # unparseable source is the runner's problem
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _PRAGMA_RE.search(tok.string)
        if not m:
            continue
        lineno, col = tok.start
        if m.group(1) == "skip-file":
            if lineno <= _SKIP_FILE_SCAN_LINES:
                out.skip_file = True
            continue
        labels = {s.strip() for s in m.group(2).split(",") if s.strip()}
        if not labels:
            continue
        targets = [lineno]
        if tok.line[:col].strip() == "":
            # comment-only line: the pragma covers the next line too
            targets.append(lineno + 1)
        for t in targets:
            out.ignores.setdefault(t, set()).update(labels)
        for lab in labels:
            out.labels.setdefault(lab, lineno)
    return out
