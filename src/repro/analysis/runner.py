"""File discovery + rule execution + pragma/allowlist resolution."""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

from .astutil import ModuleInfo
from .config import LintConfig
from .findings import Finding, Severity
from .registry import all_rules, known_labels

# runner-level findings (parse errors, stale pragmas) use the reserved JL000
_META_RULE = ("JL000", "jitlint")


@dataclass
class LintResult:
    findings: list = field(default_factory=list)     # active (gate) findings
    allowed: list = field(default_factory=list)      # absorbed by allowlist
    suppressed: int = 0                              # absorbed by pragmas
    files: int = 0

    @property
    def errors(self) -> list:
        return [f for f in self.findings if f.severity is Severity.ERROR]

    @property
    def warnings(self) -> list:
        return [f for f in self.findings if f.severity is Severity.WARNING]

    def exit_code(self, strict: bool = False) -> int:
        if self.errors:
            return 1
        if strict and self.warnings:
            return 1
        return 0


def discover(paths, root: Path, config: LintConfig) -> list:
    """Python files under ``paths``, as (abspath, relpath) pairs, with the
    config's excludes applied."""
    out = []
    seen = set()
    for raw in paths:
        p = Path(raw)
        if not p.is_absolute():
            p = root / p
        files = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for f in files:
            f = f.resolve()
            if f in seen or f.suffix != ".py":
                continue
            seen.add(f)
            try:
                rel = f.relative_to(root.resolve()).as_posix()
            except ValueError:
                rel = f.as_posix()
            if config.excluded(rel):
                continue
            out.append((f, rel))
    return out


def _meta_finding(relpath: str, line: int, message: str) -> Finding:
    return Finding(rule_id=_META_RULE[0], rule_name=_META_RULE[1],
                   severity=Severity.ERROR, path=relpath, line=line, col=0,
                   message=message)


def lint_paths(paths, *, root: str | Path = ".",
               config: LintConfig | None = None,
               rules=None) -> LintResult:
    root = Path(root)
    config = config or LintConfig()
    rules = list(rules) if rules is not None else all_rules()
    labels = known_labels()
    result = LintResult()

    for path, relpath in discover(paths, root, config):
        try:
            mod = ModuleInfo.parse(path, relpath)
        except SyntaxError as e:
            result.findings.append(_meta_finding(
                relpath, e.lineno or 1, f"syntax error: {e.msg}"))
            result.files += 1
            continue
        result.files += 1
        if mod.pragmas.skip_file:
            continue

        for label, line in sorted(mod.pragmas.labels.items()):
            if label not in labels:
                result.findings.append(_meta_finding(
                    relpath, line,
                    f"pragma names unknown rule `{label}` — it suppresses "
                    f"nothing (known: IDs JL001..JL006 or rule names)"))

        raw: list = []
        for rule in rules:
            options = config.options_for(rule.name)
            if not rule.applies_to(relpath, options):
                continue
            raw.extend(rule.check(mod, options))

        for f in raw:
            if mod.pragmas.suppresses(f.line, f.rule_id, f.rule_name):
                result.suppressed += 1
                continue
            entry = config.allowed_by(f)
            if entry is not None:
                result.allowed.append(Finding(
                    **{**f.__dict__, "allowed_by": entry.describe()}))
                continue
            result.findings.append(f)

    result.findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule_id))
    result.allowed.sort(key=lambda f: (f.path, f.line, f.col, f.rule_id))
    return result


def parse_ok(source: str) -> bool:  # pragma: no cover - debugging helper
    try:
        ast.parse(source)
        return True
    except SyntaxError:
        return False
