"""jitlint — JAX/Pallas-aware static analysis for the serving stack.

The repo's efficiency-critical invariants (closed compiled-program
inventory, accelerator constants centralized in ``core/accelerators.py``,
normalized ``cost_analysis()`` access, optional dev deps never hard-imported,
Pallas grid/BlockSpec discipline) are each one careless edit away from a
silent regression that only a slow runtime bench — or a reviewer's memory —
would catch.  This package checks them *before* anything runs, the same way
the paper characterizes layers statically to drive execution: an AST pass
framework (``registry``/``runner``), per-finding rule IDs and severities
(``findings``), ``# jitlint: ignore[rule]`` pragmas (``pragmas``), a
``jitlint.toml`` allowlist (``config``), and a CLI::

    PYTHONPATH=src python -m repro.analysis.jitlint src tests

Pure stdlib on purpose: the CI lint job runs it without installing jax.
"""
from .config import LintConfig, load_config
from .findings import Finding, Severity
from .registry import all_rules, get_rule, register
from .runner import LintResult, lint_paths

__all__ = [
    "Finding", "Severity", "LintConfig", "load_config",
    "register", "get_rule", "all_rules", "lint_paths", "LintResult",
]
