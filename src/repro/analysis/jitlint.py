"""jitlint CLI — ``PYTHONPATH=src python -m repro.analysis.jitlint src tests``.

Exit status: 1 when any error-severity finding survives pragmas and the
allowlist (warnings gate only under ``--strict``), else 0.  ``--json``
writes the machine-readable findings (including allowlisted ones) for the CI
artifact.  Stdlib-only: the lint job runs this without jax installed.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .config import load_config
from .registry import all_rules
from .report import render_text, to_json
from .runner import lint_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="jitlint",
        description="JAX/Pallas-aware static analysis for the serving stack")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files/directories to lint (default: src tests)")
    ap.add_argument("--root", default=".",
                    help="repo root: relpaths, excludes and the default "
                         "config resolve against it")
    ap.add_argument("--config", default=None,
                    help="jitlint.toml (default: <root>/jitlint.toml "
                         "when present)")
    ap.add_argument("--json", default="",
                    help="also write the findings as JSON here")
    ap.add_argument("--strict", action="store_true",
                    help="warnings also fail the run")
    ap.add_argument("--verbose", action="store_true",
                    help="show allowlisted findings in the text report")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            doc = (sys.modules[type(rule).__module__].__doc__ or "")
            headline = doc.strip().splitlines()[0] if doc.strip() else ""
            print(f"{rule.id}  {rule.name:<18} [{rule.severity.value:<7}] "
                  f"{headline}")
        return 0

    config = load_config(args.config, root=args.root)
    result = lint_paths(args.paths, root=args.root, config=config)

    print(render_text(result, verbose=args.verbose))
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(to_json(result))
    return result.exit_code(strict=args.strict)


if __name__ == "__main__":
    sys.exit(main())
