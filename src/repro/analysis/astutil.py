"""Shared AST machinery: module model, jit-entry detection, call graphs.

Analysis is deliberately *per-module*: a function is "jit-reachable" when it
is (a) decorated with ``jax.jit`` / ``jax.pmap`` (bare or under
``functools.partial``), (b) passed by name to a ``jax.jit(...)`` call
anywhere in the module (including ``self.method`` references, the engine's
program-constructor idiom), or (c) transitively called from such a function
through same-module simple calls (``f(...)`` / ``self.f(...)``).  Cross-
module reachability is out of scope on purpose — it would need whole-program
import resolution for marginal extra recall, and every real incident in this
repo's history (ROADMAP "Known bug classes") was local to one module.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

from .pragmas import FilePragmas, parse_pragmas

FunctionNode = (ast.FunctionDef, ast.AsyncFunctionDef)


@dataclass
class ModuleInfo:
    path: Path                  # absolute
    relpath: str                # posix, relative to the lint root
    source: str
    tree: ast.Module
    pragmas: FilePragmas
    _parents: dict = field(default_factory=dict, repr=False)

    @classmethod
    def parse(cls, path: Path, relpath: str) -> "ModuleInfo":
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
        info = cls(path=path, relpath=relpath, source=source, tree=tree,
                   pragmas=parse_pragmas(source))
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                info._parents[child] = parent
        return info

    def parent(self, node: ast.AST) -> ast.AST | None:
        return self._parents.get(node)

    def segment(self, node: ast.AST) -> str:
        return ast.get_source_segment(self.source, node) or ""


def dotted_name(node: ast.AST) -> str:
    """``jax.jit`` -> "jax.jit", ``pl.BlockSpec`` -> "pl.BlockSpec",
    ``self._decode`` -> "self._decode"; "" when not a plain dotted chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def is_jit_callable(node: ast.AST) -> bool:
    """Does this expression name a jit-family transform?"""
    name = dotted_name(node)
    return name in ("jax.jit", "jit", "jax.pmap", "pmap")


def unwrap_partial(call: ast.Call) -> ast.AST | None:
    """``functools.partial(f, ...)`` / ``partial(f, ...)`` -> ``f``."""
    if dotted_name(call.func) in ("functools.partial", "partial") \
            and call.args:
        return call.args[0]
    return None


def _decorator_is_jit(dec: ast.AST) -> bool:
    if is_jit_callable(dec):
        return True
    if isinstance(dec, ast.Call):
        if is_jit_callable(dec.func):
            return True
        inner = unwrap_partial(dec)
        if inner is not None and is_jit_callable(inner):
            return True
    return False


def jit_static_argnames(func: ast.AST) -> frozenset:
    """Static argnames declared by a ``@partial(jax.jit, static_argnames=...)``
    or ``@jax.jit(static_argnames=...)`` decorator, when statically literal."""
    names: set[str] = set()
    for dec in getattr(func, "decorator_list", []):
        if not isinstance(dec, ast.Call) or not _decorator_is_jit(dec):
            continue
        for kw in dec.keywords:
            if kw.arg != "static_argnames":
                continue
            val = kw.value
            elts = val.elts if isinstance(val, (ast.Tuple, ast.List)) \
                else [val]
            for e in elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    names.add(e.value)
    return frozenset(names)


@dataclass
class JitReachability:
    """Per-module jit-entry set and its transitive closure."""
    functions: dict             # bare name -> [FunctionDef]
    entries: set                # bare names that are jit entry points
    reachable: set              # entries + same-module transitive callees
    # every jax.jit(...) Call node in the module, for rule-local inspection
    jit_calls: list

    def is_reachable(self, func: ast.AST) -> bool:
        name = getattr(func, "name", None)
        return name in self.reachable and func in self.functions.get(name, [])


def _callee_names(func: ast.AST) -> set:
    """Bare names of same-module simple calls: ``f(...)``, ``self.f(...)``,
    ``cls.f(...)``.  Nested function defs are part of their parent's body and
    therefore already walked."""
    out = set()
    for node in ast.walk(func):
        if not isinstance(node, ast.Call):
            continue
        target = node.func
        if isinstance(target, ast.Name):
            out.add(target.id)
        elif isinstance(target, ast.Attribute) \
                and isinstance(target.value, ast.Name) \
                and target.value.id in ("self", "cls"):
            out.add(target.attr)
    return out


def jit_reachability(mod: ModuleInfo) -> JitReachability:
    functions: dict = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, FunctionNode):
            functions.setdefault(node.name, []).append(node)

    entries: set = set()
    jit_calls: list = []
    for node in ast.walk(mod.tree):
        if isinstance(node, FunctionNode):
            if any(_decorator_is_jit(d) for d in node.decorator_list):
                entries.add(node.name)
        elif isinstance(node, ast.Call) and is_jit_callable(node.func):
            jit_calls.append(node)
            if node.args:
                ref = node.args[0]
                inner = unwrap_partial(ref) if isinstance(ref, ast.Call) \
                    else None
                for candidate in (ref, inner):
                    name = dotted_name(candidate) if candidate is not None \
                        else ""
                    bare = name.rsplit(".", 1)[-1]
                    if bare in functions:
                        entries.add(bare)

    reachable = set(entries)
    frontier = list(entries)
    while frontier:
        name = frontier.pop()
        for func in functions.get(name, []):
            for callee in _callee_names(func):
                if callee in functions and callee not in reachable:
                    reachable.add(callee)
                    frontier.append(callee)
    return JitReachability(functions=functions, entries=entries,
                           reachable=reachable, jit_calls=jit_calls)


def enclosing_function(mod: ModuleInfo, node: ast.AST) -> ast.AST | None:
    cur = mod.parent(node)
    while cur is not None and not isinstance(cur, FunctionNode):
        cur = mod.parent(cur)
    return cur


def literal_source_is_decimal(mod: ModuleInfo, node: ast.Constant) -> bool:
    """True when a numeric literal is written in decimal (or scientific)
    notation — hex/octal/binary masks and flag words are not config values."""
    text = mod.segment(node).strip().lower()
    return not text.startswith(("0x", "0o", "0b"))
