"""Edge CNN builders — 13 CNNs with the structure the paper characterizes:
separable-convolution edge models (MobileNet-family), fire-module models
(SqueezeNet-family), detection (SSD-style heads), and segmentation variants.

All specs use int8 quantized parameters/activations (bytes_per_param=1), batch=1,
matching the paper's TFLite / quantization-aware-trained deployment (§6).
"""
from __future__ import annotations

from ..core.layerspec import LayerKind, LayerSpec, ModelGraph

B = dict(bytes_per_param=1.0, bytes_per_act=1.0, batch=1)


def _conv(name, hw, cin, cout, k=3, s=1):
    return LayerSpec(name=name, kind=LayerKind.CONV2D, in_hw=hw, in_ch=cin,
                     out_ch=cout, kernel=k, stride=s, **B)


def _dw(name, hw, c, k=3, s=1):
    return LayerSpec(name=name, kind=LayerKind.DWCONV2D, in_hw=hw, in_ch=c,
                     kernel=k, stride=s, **B)


def _pw(name, hw, cin, cout):
    return LayerSpec(name=name, kind=LayerKind.PWCONV2D, in_hw=hw, in_ch=cin,
                     out_ch=cout, kernel=1, stride=1, **B)


def _fc(name, fin, fout):
    return LayerSpec(name=name, kind=LayerKind.FC, in_features=fin,
                     out_features=fout, **B)


def mobilenet_v1_like(name: str, res: int = 224, alpha: float = 1.0,
                      classes: int = 1000) -> ModelGraph:
    """MobileNetV1-style: conv stem + 13 depthwise-separable pairs + classifier."""
    def c(ch):
        return max(8, int(ch * alpha))
    layers = [_conv("stem", res, 3, c(32), k=3, s=2)]
    hw = res // 2
    plan = [  # (stride, out_ch) per dw/pw pair — MobileNetV1 table
        (1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
        (1, 512), (1, 512), (1, 512), (1, 512), (1, 512), (2, 1024), (1, 1024)]
    cin = c(32)
    for i, (s, cout) in enumerate(plan):
        layers.append(_dw(f"dw{i}", hw, cin, k=3, s=s))
        hw = hw // s
        layers.append(_pw(f"pw{i}", hw, cin, c(cout)))
        cin = c(cout)
    layers.append(_fc("classifier", cin, classes))
    return ModelGraph(name, "cnn", layers)


def mobilenet_v2_like(name: str, res: int = 224, alpha: float = 1.0,
                      classes: int = 1000) -> ModelGraph:
    """Inverted residual blocks: pw-expand -> dw -> pw-project."""
    def c(ch):
        return max(8, int(ch * alpha))
    layers = [_conv("stem", res, 3, c(32), k=3, s=2)]
    hw = res // 2
    cin = c(32)
    # (expansion, out_ch, repeats, stride) — MobileNetV2 table
    plan = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
            (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    bi = 0
    for (t, cout, n, s) in plan:
        for r in range(n):
            stride = s if r == 0 else 1
            mid = cin * t
            if t != 1:
                layers.append(_pw(f"b{bi}_expand", hw, cin, mid))
            layers.append(_dw(f"b{bi}_dw", hw, mid, k=3, s=stride))
            hw = hw // stride
            layers.append(_pw(f"b{bi}_project", hw, mid, c(cout)))
            cin = c(cout)
            bi += 1
    layers.append(_pw("head_pw", hw, cin, 1280))
    layers.append(_fc("classifier", 1280, classes))
    return ModelGraph(name, "cnn", layers)


def squeezenet_like(name: str, res: int = 224, classes: int = 1000) -> ModelGraph:
    """Fire modules: squeeze 1x1 -> expand 1x1 + 3x3."""
    layers = [_conv("stem", res, 3, 64, k=3, s=2)]
    hw = res // 4  # stem + pool
    cin = 64
    fires = [(16, 64), (16, 64), (32, 128), (32, 128),
             (48, 192), (48, 192), (64, 256), (64, 256)]
    for i, (sq, ex) in enumerate(fires):
        if i in (2, 4):
            hw //= 2
        layers.append(_pw(f"fire{i}_squeeze", hw, cin, sq))
        layers.append(_pw(f"fire{i}_e1", hw, sq, ex))
        layers.append(_conv(f"fire{i}_e3", hw, sq, ex, k=3))
        cin = 2 * ex
    layers.append(_pw("head", hw, cin, classes))
    return ModelGraph(name, "cnn", layers)


def ssd_mobilenet_like(name: str, res: int = 320, alpha: float = 1.0) -> ModelGraph:
    """Detection: MobileNet backbone + SSD extra layers + box/class heads.

    The extra layers at 5x5/3x3/2x2/1x1 grids with deep channels are the
    paper's Cluster-4 population (large footprint, FLOP/B 25-64, 5-25M MACs).
    """
    g = mobilenet_v1_like("tmp", res=res, alpha=alpha, classes=0)
    layers = [l for l in g.layers if l.kind is not LayerKind.FC]
    hw = 10  # feature map after backbone (res/32)
    cin = max(8, int(1024 * alpha))
    extras = [(512, 5), (512, 5), (384, 3), (384, 3), (256, 2), (256, 1)]
    for i, (cout, out_hw) in enumerate(extras):
        layers.append(_pw(f"extra{i}_pw", hw, cin, cout // 2))
        layers.append(_conv(f"extra{i}_conv", hw, cout // 2, cout, k=3,
                            s=max(1, hw // out_hw)))
        hw = out_hw
        cin = cout
    # prediction heads over the last three scales
    for i, (c_feat, grid) in enumerate([(512, 5), (384, 3), (256, 1)]):
        layers.append(_conv(f"head{i}_box", grid, c_feat, 6 * 4, k=3))
        layers.append(_conv(f"head{i}_cls", grid, c_feat, 6 * 91, k=3))
    return ModelGraph(name, "cnn", layers)


def edge_classifier_like(name: str, res: int = 192, width: int = 64,
                         depth_mult: int = 1, classes: int = 1000) -> ModelGraph:
    """A generic edge classifier with standard convs at moderate resolution —
    populates Cluster 1 (early std conv) and Cluster 4 (deep late conv)."""
    layers = [_conv("stem", res, 3, width, k=3, s=2)]
    hw = res // 2
    cin = width
    stages = [(width, 2), (width * 2, 2), (width * 4, 3 * depth_mult),
              (width * 8, 3 * depth_mult)]
    for si, (cout, n) in enumerate(stages):
        for r in range(n):
            s = 2 if r == 0 and si > 0 else 1
            layers.append(_conv(f"s{si}_conv{r}", hw, cin, cout, k=3, s=s))
            hw //= s
            cin = cout
    layers.append(_conv("late_deep0", hw, cin, cin, k=3))
    layers.append(_conv("late_deep1", hw, cin, cin * 2, k=3, s=2))
    hw //= 2
    layers.append(_fc("classifier", cin * 2, classes))
    return ModelGraph(name, "cnn", layers)


def deeplab_like(name: str, res: int = 257, alpha: float = 1.0) -> ModelGraph:
    """Segmentation: MobileNetV2 backbone + ASPP-ish head at 1/16 resolution."""
    g = mobilenet_v2_like("tmp", res=res - 1, alpha=alpha, classes=0)
    layers = [l for l in g.layers if l.kind is not LayerKind.FC][:-1]
    hw, cin = 16, 320
    for i in range(4):
        layers.append(_conv(f"aspp{i}", hw, cin, 256, k=3))
        cin = 256
    layers.append(_pw("proj", hw, 256, 256))
    layers.append(_pw("logits", hw, 256, 21))
    return ModelGraph(name, "cnn", layers)


def build_cnns() -> list[ModelGraph]:
    """The 13 edge CNNs (CNN1..CNN13)."""
    return [
        mobilenet_v1_like("CNN1_mnv1_224", 224, 1.0),
        mobilenet_v1_like("CNN2_mnv1_192x075", 192, 0.75),
        mobilenet_v2_like("CNN3_mnv2_224", 224, 1.0),
        mobilenet_v2_like("CNN4_mnv2_192x14", 192, 1.4),
        squeezenet_like("CNN5_squeeze_224", 224),
        edge_classifier_like("CNN6_edgeclf_192", 192, width=64),
        edge_classifier_like("CNN7_edgeclf_160w96", 160, width=96),
        ssd_mobilenet_like("CNN8_ssd_mnv1_320", 320, 1.0),
        ssd_mobilenet_like("CNN9_ssd_mnv1_300x075", 300, 0.75),
        deeplab_like("CNN10_deeplab_257", 257, 1.0),
        mobilenet_v2_like("CNN11_mnv2_160x05", 160, 0.5),
        mobilenet_v1_like("CNN12_mnv1_160x05", 160, 0.5),
        deeplab_like("CNN13_deeplab_225x05", 225, 0.5),
    ]
