"""The 24 Google edge NN models the paper characterizes, reconstructed from the
paper's published per-family statistics (see recurrent_models.py / cnn.py)."""
from .zoo import by_family, edge_zoo, get_model

__all__ = ["by_family", "edge_zoo", "get_model"]
