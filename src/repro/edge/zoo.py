"""The 24 Google edge models (13 CNNs + 4 LSTMs + 4 Transducers + 3 RCNNs)."""
from __future__ import annotations

from functools import lru_cache

from ..core.layerspec import ModelGraph
from .cnn import build_cnns
from .recurrent_models import build_lstms, build_rcnns, build_transducers


@lru_cache(maxsize=1)
def _zoo() -> tuple[ModelGraph, ...]:
    models = build_cnns() + build_lstms() + build_transducers() + build_rcnns()
    for m in models:
        m.validate()
    return tuple(models)


def edge_zoo() -> list[ModelGraph]:
    return list(_zoo())


def by_family(family: str) -> list[ModelGraph]:
    return [m for m in _zoo() if m.family == family]


def get_model(name: str) -> ModelGraph:
    for m in _zoo():
        if m.name == name:
            return m
    raise KeyError(name)
