"""Edge LSTM / Transducer / RCNN builders.

Dimensioned from the paper's stated statistics:
  * each LSTM gate averages ~2.1M parameters (W_x + W_h) — §3.2.1,
  * LSTM layer footprints reach 70M parameters,
  * LSTM/Transducer layer footprints average 33.4 MB,
  * Transducers follow the mobile RNN-T structure (He et al. [24]): LSTM encoder
    stack + 2-layer LSTM prediction network + feed-forward joint.
"""
from __future__ import annotations

from ..core.layerspec import LayerKind, LayerSpec, ModelGraph

B = dict(bytes_per_param=1.0, bytes_per_act=1.0, batch=1)


def _lstm(name, fin, hidden, T):
    return LayerSpec(name=name, kind=LayerKind.LSTM, in_features=fin,
                     hidden=hidden, seq_len=T, **B)


def _fc(name, fin, fout):
    return LayerSpec(name=name, kind=LayerKind.FC, in_features=fin,
                     out_features=fout, **B)


def _embed(name, vocab, dim, T):
    return LayerSpec(name=name, kind=LayerKind.EMBEDDING, vocab=vocab,
                     out_features=dim, seq_len=T, **B)


def lstm_speech_like(name: str, hidden: int = 1280, layers_n: int = 5,
                     T: int = 150, feat: int = 240,
                     out_states: int = 8192) -> ModelGraph:
    """LVCSR acoustic model (Sak et al. [44]): stacked LSTMs + output FC."""
    layers = [_lstm("lstm0", feat, hidden, T)]
    for i in range(1, layers_n):
        layers.append(_lstm(f"lstm{i}", hidden, hidden, T))
    layers.append(_fc("output", hidden, out_states))
    return ModelGraph(name, "lstm", layers)


def lstm_translate_like(name: str, hidden: int = 1024, layers_n: int = 4,
                        T: int = 60, vocab: int = 32000) -> ModelGraph:
    """Translation-style seq2seq LSTM stack (GNMT-lite)."""
    layers = [_embed("embed", vocab, hidden, T),
              _lstm("enc0", hidden, hidden, T)]
    for i in range(1, layers_n):
        layers.append(_lstm(f"enc{i}", hidden, hidden, T))
    layers.append(_fc("softmax", hidden, vocab))
    return ModelGraph(name, "lstm", layers)


def transducer_like(name: str, enc_layers: int = 8, enc_hidden: int = 2048,
                    enc_in: int = 512, T: int = 200, U: int = 20,
                    pred_hidden: int = 2048, joint_dim: int = 640,
                    vocab: int = 4096) -> ModelGraph:
    """Mobile RNN-T (He et al. [24]): encoder + prediction + joint."""
    layers = [_lstm("enc0", enc_in, enc_hidden, T)]
    for i in range(1, enc_layers):
        layers.append(_lstm(f"enc{i}", enc_hidden, enc_hidden, T))
    layers.append(_embed("pred_embed", vocab, joint_dim, U))
    layers.append(_lstm("pred0", joint_dim, pred_hidden, U))
    layers.append(_lstm("pred1", pred_hidden, pred_hidden, U))
    layers.append(_fc("joint_enc", enc_hidden, joint_dim))
    layers.append(_fc("joint_pred", pred_hidden, joint_dim))
    layers.append(_fc("joint_out", joint_dim, vocab))
    return ModelGraph(name, "transducer", layers)


def rcnn_like(name: str, res: int = 224, alpha: float = 1.0,
              lstm_hidden: int = 1024, T: int = 16,
              classes: int = 1000) -> ModelGraph:
    """LRCN [11]: CNN feature extractor + LSTM head (image captioning / video)."""
    from .cnn import mobilenet_v1_like
    g = mobilenet_v1_like("tmp", res=res, alpha=alpha, classes=0)
    layers = [l for l in g.layers if l.kind is not LayerKind.FC]
    feat = max(8, int(1024 * alpha))
    layers.append(_fc("feat_proj", feat, lstm_hidden))
    layers.append(_lstm("lstm0", lstm_hidden, lstm_hidden, T))
    layers.append(_lstm("lstm1", lstm_hidden, lstm_hidden, T))
    layers.append(_fc("classifier", lstm_hidden, classes))
    return ModelGraph(name, "rcnn", layers)


def build_lstms() -> list[ModelGraph]:
    return [
        lstm_speech_like("LSTM1_lvcsr_1280x5", hidden=1280, layers_n=5, T=150),
        lstm_speech_like("LSTM2_lvcsr_2048x4", hidden=2048, layers_n=4, T=120,
                         out_states=4096),
        lstm_translate_like("LSTM3_nmt_1024x4", hidden=1024, layers_n=4, T=60),
        # one "large footprint" model: 8*h^2 = 67M params/layer (paper: up to 70M)
        lstm_speech_like("LSTM4_big_2900x2", hidden=2900, layers_n=2, T=80,
                         feat=512, out_states=8192),
    ]


def build_transducers() -> list[ModelGraph]:
    return [
        transducer_like("TR1_rnnt_mobile", enc_layers=8, enc_hidden=2048,
                        enc_in=512, T=200, U=20),
        transducer_like("TR2_rnnt_small", enc_layers=6, enc_hidden=1400,
                        enc_in=400, T=150, U=16, pred_hidden=1400,
                        joint_dim=512, vocab=4096),
        transducer_like("TR3_rnnt_large", enc_layers=8, enc_hidden=2560,
                        enc_in=640, T=240, U=24, pred_hidden=2560,
                        joint_dim=768, vocab=8192),
        transducer_like("TR4_rnnt_med", enc_layers=7, enc_hidden=1792,
                        enc_in=512, T=180, U=20, pred_hidden=1792,
                        joint_dim=640, vocab=4096),
    ]


def build_rcnns() -> list[ModelGraph]:
    return [
        rcnn_like("RCNN1_lrcn_224", 224, 1.0, lstm_hidden=1024, T=16),
        rcnn_like("RCNN2_lrcn_192x075", 192, 0.75, lstm_hidden=768, T=16),
        rcnn_like("RCNN3_captions", 224, 1.0, lstm_hidden=1536, T=24,
                  classes=12000),
    ]
