"""Token sampling for the jitted serving programs.

Per-request temperature / top-k / top-p, applied *inside* the compiled
decode/prefill programs: every parameter is a traced per-row array, so one
program serves any mix of greedy and stochastic requests with zero
recompiles.  Greedy rows (temperature <= 0) take the exact ``argmax`` of the
raw logits — bit-for-bit what the engine produced before sampling existed.

Randomness is stateless: each row's key is ``fold_in(PRNGKey(seed),
position)``, so a request's token stream is a pure function of (seed,
positions) — reproducible across engines, restarts, and slot assignments,
with no carried key state in the slot pool.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: filler for masked-out logits; finite so (masked - max) never yields NaN
_MASKED = -1e30


def _sample_row(logits: jax.Array, temperature: jax.Array, top_k: jax.Array,
                top_p: jax.Array, seed: jax.Array, position: jax.Array
                ) -> jax.Array:
    """One row: logits (V,) fp32, scalar knobs -> sampled token id."""
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1)

    scaled = logits / jnp.maximum(temperature, 1e-6)
    # top-k: drop logits below the k-th largest (k <= 0 keeps everything;
    # ties at the threshold stay in, matching the usual >=-threshold rule)
    sorted_desc = jnp.sort(scaled)[::-1]
    kth = sorted_desc[jnp.clip(top_k, 1, v) - 1]
    keep_k = (top_k <= 0) | (scaled >= kth)
    scaled = jnp.where(keep_k, scaled, _MASKED)
    # top-p (nucleus): keep the smallest set of tokens whose cumulative
    # probability reaches top_p — a token stays while the mass *before* it
    # (exclusive cumsum in descending-probability order) is < top_p, so the
    # top-1 token always survives and p >= 1 keeps everything
    sorted_desc = jnp.sort(scaled)[::-1]
    order = jnp.argsort(-scaled)
    probs = jax.nn.softmax(sorted_desc)
    cum_before = jnp.cumsum(probs) - probs
    keep_sorted = cum_before < jnp.maximum(top_p, 1e-6)
    keep_p = jnp.zeros((v,), bool).at[order].set(keep_sorted)
    scaled = jnp.where(keep_p, scaled, _MASKED)

    key = jax.random.fold_in(jax.random.PRNGKey(seed), position)
    sampled = jax.random.categorical(key, scaled)
    return jnp.where(temperature > 0.0, sampled, greedy).astype(jnp.int32)


def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array, seed: jax.Array,
                  position: jax.Array) -> jax.Array:
    """Batched sampling: logits (B,V) fp32; temperature/top_p (B,) f32;
    top_k/seed/position (B,) int32 -> (B,) int32 token ids.

    Rows with temperature <= 0 are exactly ``argmax(logits, -1)``.  The
    all-greedy case (the default) skips the sort/cumsum machinery entirely at
    runtime via ``lax.cond`` — one compiled program either way, so the
    engine's zero-recompile invariant holds for any greedy/stochastic mix."""
    logits = logits.astype(jnp.float32)

    def stochastic(_):
        return jax.vmap(_sample_row)(logits, temperature, top_k, top_p,
                                     seed, position)

    def greedy(_):
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return jax.lax.cond(jnp.any(temperature > 0.0), stochastic, greedy, None)
