"""Disaggregated serving: prefill and decode as cooperating role engines.

One-size-fits-all execution wastes the hardware — the paper's argument for
per-layer accelerators, applied here to request *phases*: prefill ticks are
compute-centric bursts, decode ticks are memory-centric and latency-bound,
and interleaving them on one mesh lets every prefill burst inflate decode
latency for all running slots (the interference DistServe, OSDI'24,
eliminates).  :class:`DisaggEngine` couples a ``role="prefill"`` and a
``role="decode"`` :class:`~repro.serve.engine.ServeEngine` pinned to
disjoint submeshes (``launch.mesh.make_role_meshes``), so prefill capacity
and decode capacity are provisioned independently.

Per tick the coordinator advances the prefill engine, drains its ``ready``
slots — export the slot into a self-contained *suitcase* (batch-1 state row
+ the slot's KV block contents), stage it onto the decode submesh, release
the prefill slot — then offers pending suitcases to the decode engine's
:meth:`~repro.serve.engine.ServeEngine.adopt` (a block-table remap into the
decode pool's stripes plus one scatter: device-to-device block copy, never a
re-layout), and finally advances the decode engine.  Adoption is FIFO and
backpressured: a suitcase that finds no free slot or no free blocks simply
waits, with the stall counted.

Token identity with the interleaved engine is structural: the same prefill
programs produce the same first token, the suitcase moves KV blocks and
recurrent rows bitwise, and decode math is per-slot independent — the
``--disagg`` bench gate and ``tests/test_distributed.py`` hold the pair to
bitwise-equal generations with zero recompiles after warmup on either
submesh.
"""
from __future__ import annotations

import warnings

from ..obs import Tracer
from .engine import Request, ServeEngine


class DisaggEngine:
    """A prefill engine and a decode engine coupled by KV-suitcase handoff.

    ``prefill_mesh`` / ``decode_mesh`` must be both set (disjoint submeshes
    from ``launch.mesh.make_role_meshes``) or both None (single device —
    still a faithful functional model of the split, used by the identity
    gates).  Both engines share one tracer timeline; the decode engine's
    tracks start after the prefill engine's (``track_base``).

    The decode engine never prefills, so its pool runs with the prefix
    cache off — suitcase contents arrive by block copy, and prefix reuse
    already happened on the prefill side where prompts are admitted.

    ``policy`` (a ``serve.placement.PlacementPlan``) supplies per-role
    bucket/chunk knobs via ``plan.per_role``; explicit constructor
    arguments still win, mirroring ``ServeEngine``'s precedence.
    """

    def __init__(self, model, params, *, prefill_mesh=None, decode_mesh=None,
                 prefill_slots: int = 4, decode_slots: int = 4,
                 max_len: int = 256,
                 buckets: tuple[int, ...] | None = None,
                 min_bucket: int = 16,
                 max_prefill_per_step: int = 1,
                 max_prefill_batch: int = 4,
                 prefill_chunk: int | None = None,
                 kv_block_size: int | None = None,
                 kv_blocks: int | None = None,
                 prefix_cache: bool = True,
                 param_strategy: str = "tp",
                 prefill_model=None, decode_model=None,
                 policy=None,
                 tracer: Tracer | None = None,
                 profile: bool = False,
                 program_memory: bool = False):
        if (prefill_mesh is None) != (decode_mesh is None):
            raise ValueError("prefill_mesh and decode_mesh must be both set "
                             "(disjoint submeshes) or both None")
        self.tracer = tracer if tracer is not None else Tracer()
        per_role = policy.per_role if policy is not None \
            and getattr(policy, "per_role", None) else {}
        pre_kn = per_role.get("prefill", {})
        dec_kn = per_role.get("decode", {})

        def knob(explicit, knobs, key):
            if explicit is not None:
                return explicit
            return knobs.get(key)

        pre_buckets = knob(buckets, pre_kn, "buckets")
        pre_buckets = tuple(pre_buckets) if pre_buckets else None
        common = dict(max_len=max_len, min_bucket=min_bucket,
                      kv_block_size=kv_block_size, kv_blocks=kv_blocks,
                      param_strategy=param_strategy, policy=policy,
                      tracer=self.tracer, profile=profile,
                      program_memory=program_memory)
        self.prefill = ServeEngine(
            model, params, role="prefill", slots=prefill_slots,
            buckets=pre_buckets,
            prefill_chunk=knob(prefill_chunk, pre_kn, "prefill_chunk"),
            max_prefill_per_step=max_prefill_per_step,
            max_prefill_batch=max_prefill_batch,
            prefix_cache=prefix_cache, mesh=prefill_mesh,
            prefill_model=prefill_model, track_base=0, **common)
        dec_buckets = knob(buckets, dec_kn, "buckets")
        self.decode = ServeEngine(
            model, params, role="decode", slots=decode_slots,
            buckets=tuple(dec_buckets) if dec_buckets else None,
            prefill_chunk=knob(prefill_chunk, dec_kn, "prefill_chunk"),
            prefix_cache=False, mesh=decode_mesh, decode_model=decode_model,
            track_base=self.prefill._trk_engine + 1, **common)
        # suitcases exported but not yet adopted (FIFO; self-contained
        # copies, so the prefill slot is already free while these wait)
        self._pending: list = []
        self.wall_time_s = 0.0
        self.ticks = 0

    @property
    def buckets(self):
        """Admission buckets live on the prefill role (where prompts enter)."""
        return self.prefill.buckets

    @property
    def prefill_chunk(self):
        return self.prefill.prefill_chunk

    # ------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        self.prefill.submit(req)

    def warmup(self) -> None:
        """Warm both role inventories (each engine compiles only its own
        closed program set, handoff half included)."""
        self.prefill.warmup()
        self.decode.warmup()

    def step(self) -> None:
        """One coordinator tick: advance prefill, export every ready slot,
        offer pending suitcases to decode (FIFO, backpressured), advance
        decode one lockstep step."""
        t0 = self.tracer.now()
        self.prefill.step()
        self._drain_ready()
        self._adopt_pending()
        self.decode.step()
        self.ticks += 1
        self.wall_time_s += self.tracer.now() - t0

    def _drain_ready(self) -> None:
        pre = self.prefill
        while pre.ready:
            slot = pre.ready.popleft()
            req = pre.requests[slot]
            suitcase = self.decode.stage_in(pre.export_slot(slot))
            pre.release_handoff(slot)
            self._pending.append((req, suitcase, len(req.prompt)))

    def _adopt_pending(self) -> None:
        while self._pending:
            req, suitcase, n = self._pending[0]
            if self.decode.adopt(req, suitcase, n) is None:
                break                    # no slot/blocks free: retry next tick
            self._pending.pop(0)

    def _busy(self) -> bool:
        return bool(self.prefill._queue or self.prefill._prefilling
                    or self._pending
                    or any(r is not None for r in self.prefill.requests)
                    or any(r is not None for r in self.decode.requests))

    def run(self, requests: list[Request], max_steps: int = 10_000,
            on_truncate: str = "warn") -> list[Request]:
        """Serve ``requests`` to completion (or ``max_steps`` coordinator
        ticks); same contract as ``ServeEngine.run``."""
        if on_truncate not in ("warn", "raise", "ignore"):
            raise ValueError(f"on_truncate {on_truncate!r} not in "
                             f"('warn', 'raise', 'ignore')")
        for r in requests:
            self.submit(r)
        steps = 0
        while self._busy() and steps < max_steps:
            self.step()
            steps += 1
        leftovers = ([r for r in self.prefill.requests if r is not None]
                     + [r for r in self.decode.requests if r is not None]
                     + [r for r, _, _ in self._pending]
                     + list(self.prefill._queue))
        if leftovers:
            self.decode.stats.requests_aborted += sum(
                1 for r in leftovers if not r.aborted)
            t_abort = self.tracer.now()
            for r in leftovers:
                if not r.aborted:
                    self.tracer.instant("abort", self.prefill._trk_req,
                                        t_abort, (("rid", r.rid),))
                r.aborted = True
            msg = (f"run() exhausted max_steps={max_steps} with "
                   f"{len(leftovers)} unfinished requests "
                   f"(rids {[r.rid for r in leftovers][:8]}...) — they "
                   f"remain queued/in-slot/pending and are marked aborted")
            if on_truncate == "raise":
                raise RuntimeError(msg)
            if on_truncate == "warn":
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return requests

    # ----------------------------------------------------------------- stats
    def reset_stats(self) -> None:
        self.prefill.reset_stats()
        self.decode.reset_stats()
        self.wall_time_s = 0.0
        self.ticks = 0

    def recompiles_since(self, warm: dict) -> int:
        """Compile-cache growth on either submesh since a ``summary()``
        snapshot taken right after warmup — the zero-recompile gate."""
        cur = self.summary()
        rec = 0
        for role in ("prefill", "decode"):
            w, c = warm["roles"][role], cur["roles"][role]
            rec += (c["prefill_compiles"] - w["prefill_compiles"]) \
                + (c["decode_compiles"] - w["decode_compiles"])
        return rec

    def summary(self) -> dict:
        """Aggregate view: per-role summaries side by side, handoff totals,
        coordinator-wall throughput, per-role tokens/s, and the decode
        time-between-tokens quantiles the ``--disagg`` gate compares."""
        pre = self.prefill.stats.summary()
        dec = self.decode.stats.summary()
        tokens = (self.prefill.stats.tokens_generated
                  + self.decode.stats.tokens_generated)
        wall = self.wall_time_s
        tbt = self.decode.stats.metrics.histogram("decode_tbt_s")
        return {
            "roles": {"prefill": pre, "decode": dec},
            "requests_completed": (pre["requests_completed"]
                                   + dec["requests_completed"]),
            "requests_aborted": dec["requests_aborted"],
            "tokens_generated": tokens,
            "tokens_per_s": tokens / wall if wall else 0.0,
            "per_role_tokens_per_s": {
                # prefill throughput is prompt tokens actually computed;
                # decode throughput is generated tokens — each over the
                # shared coordinator wall, so the pair is comparable
                "prefill": (self.prefill.stats.prefill_tokens_computed
                            / wall if wall else 0.0),
                "decode": (self.decode.stats.tokens_generated
                           / wall if wall else 0.0),
            },
            "handoffs": self.decode.stats.handoffs,
            "handoffs_pending": len(self._pending),
            "handoff_stalls": self.decode.stats.handoff_stalls,
            "handoff_time_s": (self.prefill.stats.handoff_time_s
                               + self.decode.stats.handoff_time_s),
            "decode_tbt_ms": {"p50": 1e3 * tbt.quantile(0.5),
                              "p99": 1e3 * tbt.quantile(0.99)},
            "ticks": self.ticks,
            "wall_time_s": wall,
        }

    def save_trace(self, path) -> None:
        """One Chrome trace for both roles (shared tracer: prefill tracks
        first, then decode's, offset by ``track_base``)."""
        self.tracer.save(path, other_data={"disagg": {
            "handoffs": self.decode.stats.handoffs,
            "handoff_stalls": self.decode.stats.handoff_stalls}})
