"""repro.serve — continuous-batching engine, paged KV pool, sampling,
and the disaggregated prefill/decode pair."""
from .disagg import DisaggEngine
from .engine import EngineStats, Request, ServeEngine
from .kvpool import KVBlockPool, PagedKVManager, RadixPrefixCache

__all__ = ["DisaggEngine", "EngineStats", "Request", "ServeEngine",
           "KVBlockPool", "PagedKVManager", "RadixPrefixCache"]
