"""repro.serve"""
