"""repro.serve — continuous-batching engine, paged KV pool, sampling."""
from .engine import EngineStats, Request, ServeEngine
from .kvpool import KVBlockPool, PagedKVManager, RadixPrefixCache

__all__ = ["EngineStats", "Request", "ServeEngine", "KVBlockPool",
           "PagedKVManager", "RadixPrefixCache"]
