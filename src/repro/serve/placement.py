"""Cost-model-driven serving placement — the online Mensa oracle.

This module closes the loop between the paper's characterization machinery
(`core/characterize`, `core/clustering`, `core/costmodel`, the accelerator
configs in `core/accelerators`) and the live serving engine.  Instead of one
global set of execution knobs, the `ExecutionOracle`:

  1. builds one `LayerSpec` per served layer at the engine's actual geometry
     (prefill chunks at batch 1, lockstep decode at `slots` x 1 token against
     `max_len` of KV) and characterizes each via `characterize_layer`;
  2. clusters the layers with the paper's `rule_cluster` boxes and verifies
     the grouping against a seeded `kmeans_cluster` run (the agreement score
     is recorded on the plan, so a drifting k-means can't silently change
     decisions);
  3. prices every layer on its cluster's designated Mensa accelerator with
     `layer_cost` and emits one `ExecutionPolicy` per cluster — kernel
     variant (Pallas vs the XLA reference path), prefill chunk size, bucket
     ladder, preferred mesh sharding axis — rolled up into a whole-engine
     `PlacementPlan` with predicted per-phase latency.

Policies decide *how* the engine executes, never *what* it computes: a plan
only selects among token-identical implementations, is resolved entirely
before `warmup()`, and is immutable afterwards, so the compiled-program
inventory stays closed (the zero-recompile invariant).  Pallas kernel
variants are only selected when the backend can lower them natively
(`jax.default_backend() == "tpu"`); on CPU CI the oracle resolves to the XLA
path and `--policy auto` is bitwise-identical to the fixed-knob engine.

`benchmarks/calibrate.py` fits the plan's predictions against measured
engine phase times and gates the residual in CI — see docs/placement.md.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

from ..core.accelerators import CLUSTER_TO_ACCELERATOR
from ..core.characterize import LayerCharacteristics, characterize_layer
from ..core.clustering import agreement, kmeans_cluster, rule_cluster
from ..core.costmodel import layer_cost
from ..core.layerspec import LayerKind, LayerSpec
from ..models.model_config import ArchConfig

BYTES = 2.0  # serving runs bf16 activations/params (matches core/strategy.py)

# Block kinds whose execution the policy can switch between a Pallas kernel
# and the XLA reference path, and the ArchConfig knob that carries the choice
# into the model code.  "ssm" is deliberately absent for serving: the fused
# pavlov_ssm kernel returns outputs only (no final state), and serving
# prefill must hand the scan state to decode — the oracle therefore keeps
# SSM blocks on the XLA scan and records the reason on the policy.
KIND_TO_IMPL_KNOB = {
    "attn": "attn_impl",
    "local": "attn_impl",
    "dec": "attn_impl",
    "enc": "attn_impl",
    "rec": "rglru_impl",
}

# Concrete kernels behind each knob, per phase — display metadata for stats,
# --policy-dump, and docs; the model code routes on (knob, mode) itself.
_PALLAS_VARIANTS = {
    "attn_impl": {"prefill": "pallas_flash", "decode": "pallas_paged"},
    "rglru_impl": {"prefill": "pallas_rglru", "decode": "pallas_rglru"},
}


def _bucket_ladder(max_len: int, min_bucket: int = 16,
                   max_bucket: int | None = None) -> tuple[int, ...]:
    top = min(max_bucket, max_len) if max_bucket else max_len
    # engine.prefill_buckets is the single source of truth for the ladder
    # shape; imported lazily because serve/engine.py consumes this module.
    from .engine import prefill_buckets
    return prefill_buckets(top, min_bucket)


# ------------------------------------------------------------------ policies
@dataclass(frozen=True)
class ExecutionPolicy:
    """Per-cluster execution decision: how one group of layers should run."""

    cluster: int                      # Mensa cluster id (1..5)
    kinds: tuple[str, ...]            # block kinds governed by this policy
    accelerator: str                  # designated Mensa accelerator (paper map)
    kernel: str                       # "pallas" | "xla"
    variants: tuple[str, ...]         # concrete kernels, e.g. "pallas_flash"
    prefill_chunk: int                # chunk width this cluster wants per tick
    buckets: tuple[int, ...]          # prompt bucket ladder
    sharding_axis: str | None         # preferred mesh axis ("data"/"model")
    predicted_prefill_s: float        # summed layer_cost, one prefill chunk
    predicted_decode_s: float         # summed layer_cost, one decode step
    note: str = ""                    # why a kernel was (not) selected

    def summary(self) -> dict:
        out = {
            "cluster": self.cluster,
            "kinds": list(self.kinds),
            "accelerator": self.accelerator,
            "kernel": self.kernel,
            "variants": list(self.variants),
            "prefill_chunk": self.prefill_chunk,
            "sharding_axis": self.sharding_axis,
            "predicted_prefill_s": self.predicted_prefill_s,
            "predicted_decode_s": self.predicted_decode_s,
        }
        if self.note:
            out["note"] = self.note
        return out


@dataclass(frozen=True)
class PlacementPlan:
    """Whole-engine resolution of per-cluster policies.

    Frozen and tuple-valued on purpose: a plan is resolved once, before the
    engine compiles anything, and two plans for the same (arch, geometry,
    backend) compare equal — the determinism the tests pin down.
    """

    arch: str
    source: str                       # "auto" (oracle) | "fixed" (constructor knobs)
    backend: str                      # backend kernels were resolved against
    policies: tuple[ExecutionPolicy, ...] = ()
    layer_kinds: tuple[str, ...] = ()
    layer_clusters: tuple[int, ...] = ()   # cluster id per model layer
    buckets: tuple[int, ...] = ()
    prefill_chunk: int = 0
    sharding_axis: str | None = None
    # ArchConfig override items ({knob: impl}), per phase — all RUNTIME_SAFE
    prefill_overrides: tuple[tuple[str, str], ...] = ()
    decode_overrides: tuple[tuple[str, str], ...] = ()
    predicted_prefill_s: float = 0.0  # whole model, one full prefill chunk
    predicted_decode_s: float = 0.0   # whole model, one lockstep decode step
    rule_kmeans_agreement: float = 0.0
    # per-role engine knobs for disaggregated serving (serve/disagg.py):
    # (("prefill", (("buckets", (...)), ("prefill_chunk", n))), ("decode", ()))
    # — a dedicated prefill submesh has no decoders to protect, so its chunk
    # is freed from the decode-latency bound the interleaved chunk obeys
    role_knobs: tuple = ()

    @property
    def per_role(self) -> dict:
        """``{"prefill": {...}, "decode": {...}}`` view of ``role_knobs``."""
        return {role: dict(kv) for role, kv in self.role_knobs}

    @property
    def prefill_cfg_overrides(self) -> dict:
        return dict(self.prefill_overrides)

    @property
    def decode_cfg_overrides(self) -> dict:
        return dict(self.decode_overrides)

    def policy_for(self, kind: str) -> ExecutionPolicy | None:
        for p in self.policies:
            if kind in p.kinds:
                return p
        return None

    def summary(self) -> dict:
        """JSON-able view — EngineStats `placement` section / --policy-dump."""
        return {
            "arch": self.arch,
            "source": self.source,
            "backend": self.backend,
            "buckets": list(self.buckets),
            "prefill_chunk": self.prefill_chunk,
            "sharding_axis": self.sharding_axis,
            "layer_clusters": list(self.layer_clusters),
            "layer_kinds": list(self.layer_kinds),
            "policies": [p.summary() for p in self.policies],
            "prefill_overrides": dict(self.prefill_overrides),
            "decode_overrides": dict(self.decode_overrides),
            "predicted": {
                "prefill_chunk_s": self.predicted_prefill_s,
                "decode_step_s": self.predicted_decode_s,
            },
            "rule_kmeans_agreement": self.rule_kmeans_agreement,
            "role_knobs": {role: dict(kv) for role, kv in self.role_knobs},
        }

    def dumps(self, indent: int = 2) -> str:
        return json.dumps(self.summary(), indent=indent, sort_keys=True)


def fixed_plan(cfg: ArchConfig, *, buckets: tuple[int, ...],
               prefill_chunk: int, backend: str = "") -> PlacementPlan:
    """The no-oracle plan: records the constructor-global knobs so EngineStats
    always has a placement section, but decides nothing."""
    return PlacementPlan(
        arch=cfg.name, source="fixed", backend=backend,
        layer_kinds=tuple(cfg.layer_kinds),
        buckets=tuple(buckets), prefill_chunk=int(prefill_chunk))


# -------------------------------------------------------------------- oracle
@dataclass
class ExecutionOracle:
    """Characterize -> cluster -> cost -> per-cluster `ExecutionPolicy`.

    Pure given its inputs: the same (cfg, geometry, backend, seed) always
    resolves to the same `PlacementPlan` — `resolve()` touches no global
    state and no clocks, so CI decisions are reproducible.
    """

    cfg: ArchConfig
    slots: int = 4
    max_len: int = 512
    min_bucket: int = 16
    max_bucket: int | None = None
    mesh_axes: tuple[str, ...] = ()   # e.g. ("data", "model"); () = no mesh
    backend: str | None = None        # None: ask jax.default_backend()
    seed: int = 0                     # k-means verification seed
    _chars: list = field(default_factory=list, repr=False)

    # ---------------------------------------------------------- layer specs
    def _spec(self, kind: str, *, seq: int, batch: int,
              kv_len: int = 0) -> LayerSpec:
        """One LayerSpec for one block class at an explicit serving geometry
        (mirrors core/strategy._block_specs, but phase-aware: decode runs
        seq=1 against kv_len of context)."""
        cfg = self.cfg
        B = dict(bytes_per_param=BYTES, bytes_per_act=BYTES, batch=batch)
        if kind in ("attn", "local", "dec", "enc"):
            return LayerSpec(
                name=kind, kind=LayerKind.ATTENTION, hidden=cfg.d_model,
                heads=cfg.num_heads, kv_heads=cfg.num_kv_heads,
                head_dim=cfg.head_dim, seq_len=seq, kv_len=kv_len,
                window=cfg.window if kind == "local" else 0,
                in_features=cfg.d_model, **B)
        if kind == "rec":
            return LayerSpec(name="rec", kind=LayerKind.RGLRU,
                             in_features=cfg.d_model, hidden=cfg.d_rnn,
                             seq_len=seq, **B)
        if kind == "ssm":
            return LayerSpec(name="ssm", kind=LayerKind.SSM,
                             in_features=cfg.d_model, hidden=cfg.d_inner,
                             state=cfg.d_state, seq_len=seq, **B)
        if kind == "ffn":
            if cfg.ffn_kind == "moe":
                return LayerSpec(name="moe", kind=LayerKind.MOE,
                                 in_features=cfg.d_model, hidden=cfg.d_ff,
                                 experts=cfg.num_experts, top_k=cfg.top_k,
                                 seq_len=seq, **B)
            width = 3 * cfg.d_ff if cfg.ffn_kind == "glu" else 2 * cfg.d_ff
            return LayerSpec(name="ffn", kind=LayerKind.FC,
                             in_features=cfg.d_model, out_features=width,
                             **{**B, "batch": batch * seq})
        if kind == "embed":
            return LayerSpec(name="embed", kind=LayerKind.EMBEDDING,
                             vocab=cfg.vocab_padded, out_features=cfg.d_model,
                             seq_len=seq, **B)
        raise ValueError(f"unknown block kind {kind!r}")

    def _phase_specs(self, *, seq: int, batch: int,
                     kv_len: int = 0) -> list[tuple[int, str, LayerSpec]]:
        """(layer index, block kind, spec) for every schedulable unit: one
        primary block per layer, the FFN that follows every non-SSM layer,
        plus the embedding table."""
        cfg = self.cfg
        out: list[tuple[int, str, LayerSpec]] = []
        for i, kind in enumerate(cfg.layer_kinds):
            out.append((i, kind, self._spec(kind, seq=seq, batch=batch,
                                            kv_len=kv_len)))
            if kind != "ssm" and cfg.ffn_kind != "none":
                out.append((i, "ffn", self._spec("ffn", seq=seq, batch=batch)))
        out.append((-1, "embed", self._spec("embed", seq=seq, batch=batch)))
        return out

    # ------------------------------------------------------------ resolution
    def _characterize(self) -> list[tuple[int, str, LayerCharacteristics]]:
        """Characterize at full-context geometry (one request, max_len tokens)
        — the per-inference view the paper clusters on."""
        if not self._chars:
            for i, kind, spec in self._phase_specs(seq=self.max_len, batch=1):
                self._chars.append(
                    (i, kind, characterize_layer(self.cfg.name, i, spec)))
        return self._chars

    def _cluster_of(self, kind: str) -> int:
        for _, k, c in self._characterize():
            if k == kind:
                return rule_cluster(c).cluster
        raise KeyError(kind)

    def _kernel_for(self, kinds: tuple[str, ...]) -> tuple[str, list, str]:
        backend = self.backend
        pallas_ok = backend == "tpu"
        knobs = sorted({KIND_TO_IMPL_KNOB[k] for k in kinds
                        if k in KIND_TO_IMPL_KNOB})
        if not knobs:
            reason = ("ssm kernel yields no carry state — serving stays on "
                      "the XLA scan" if "ssm" in kinds else "")
            return "xla", [], reason
        if not pallas_ok:
            return "xla", [], f"backend {backend!r} lowers via XLA reference path"
        variants = sorted({_PALLAS_VARIANTS[k][ph] for k in knobs
                           for ph in ("prefill", "decode")})
        return "pallas", variants, ""

    def _sharding_axis(self, compute_centric: bool) -> str | None:
        if not self.mesh_axes:
            return None
        # compute-centric clusters want their GEMMs split on the model axis;
        # memory-centric clusters scale by replicating over data (slots)
        want = "model" if compute_centric else "data"
        if want in self.mesh_axes:
            return want
        return self.mesh_axes[0]

    def _chunk_for(self, cluster_kinds: tuple[str, ...],
                   ladder_top: int) -> int:
        """Recurrent clusters bound the per-tick scan length (decode latency
        for running slots is gated on one chunk's scan); everything else
        takes the widest chunk (fewest chunk program invocations)."""
        if any(k in ("rec", "ssm") for k in cluster_kinds):
            return max(self.min_bucket, min(ladder_top, self.cfg.scan_chunk))
        return ladder_top

    def resolve(self) -> PlacementPlan:
        cfg = self.cfg
        if self.backend is None:
            import jax
            self.backend = jax.default_backend()
        buckets = _bucket_ladder(self.max_len, self.min_bucket, self.max_bucket)
        chars = self._characterize()

        # rule clustering, verified against the seeded k-means run
        assignments = {}
        for i, kind, c in chars:
            assignments[(i, kind)] = rule_cluster(c).cluster
        km_agreement = agreement([c for _, _, c in chars]) if len(chars) >= 2 \
            else 1.0
        layer_clusters = tuple(assignments[(i, kind)]
                               for i, kind in enumerate(cfg.layer_kinds))

        # group block kinds by cluster id
        by_cluster: dict[int, list[str]] = {}
        for (_, kind), cid in assignments.items():
            by_cluster.setdefault(cid, [])
            if kind not in by_cluster[cid]:
                by_cluster[cid].append(kind)

        # phase geometries: one prefill chunk at batch 1; one lockstep decode
        # step over every slot against the full KV context.  The engine chunk
        # is the tightest recommendation across clusters (recurrent clusters
        # bound the per-tick scan; everything else accepts the widest chunk).
        chunk = self._chunk_for(tuple(set(cfg.layer_kinds)), buckets[-1])
        prefill_specs = self._phase_specs(seq=chunk, batch=1)
        decode_specs = self._phase_specs(seq=1, batch=self.slots,
                                         kv_len=self.max_len)

        def _phase_cost(specs, kinds) -> float:
            total = 0.0
            for i, kind, spec in specs:
                if kind not in kinds:
                    continue
                acc = CLUSTER_TO_ACCELERATOR[assignments[(i, kind)]]
                total += layer_cost(spec, acc).latency_s
            return total

        policies = []
        prefill_over: dict[str, str] = {}
        decode_over: dict[str, str] = {}
        for cid in sorted(by_cluster):
            kinds = tuple(sorted(by_cluster[cid]))
            kernel, variants, note = self._kernel_for(kinds)
            if kernel == "pallas":
                for k in kinds:
                    knob = KIND_TO_IMPL_KNOB.get(k)
                    if knob:
                        prefill_over[knob] = "pallas"
                        decode_over[knob] = "pallas"
            compute_centric = any(c.compute_centric for (_, k, c) in chars
                                  if k in kinds)
            policies.append(ExecutionPolicy(
                cluster=cid, kinds=kinds,
                accelerator=CLUSTER_TO_ACCELERATOR[cid].name,
                kernel=kernel, variants=tuple(variants),
                prefill_chunk=self._chunk_for(kinds, buckets[-1]),
                buckets=buckets,
                sharding_axis=self._sharding_axis(compute_centric),
                predicted_prefill_s=_phase_cost(prefill_specs, set(kinds)),
                predicted_decode_s=_phase_cost(decode_specs, set(kinds)),
                note=note))

        all_kinds = {k for _, k, _ in chars}
        plan_axis = None
        if self.mesh_axes:
            axes = [p.sharding_axis for p in policies if p.sharding_axis]
            plan_axis = ("model" if "model" in axes else
                         (axes[0] if axes else self.mesh_axes[0]))
        # per-role knobs for the disaggregated pair: the interleaved chunk
        # above is bounded by the recurrent scan so a long prompt can't
        # freeze running decoders — a dedicated prefill submesh has none, so
        # its chunk widens to the full ladder top (fewest chunk invocations;
        # token-identical by the chunked==unchunked prefill invariant).  The
        # decode role takes no prefill knobs at all.
        role_knobs = (("prefill", (("buckets", buckets),
                                   ("prefill_chunk", buckets[-1]))),
                      ("decode", ()))
        return PlacementPlan(
            arch=cfg.name, source="auto", backend=self.backend,
            policies=tuple(policies),
            layer_kinds=tuple(cfg.layer_kinds),
            layer_clusters=layer_clusters,
            buckets=buckets, prefill_chunk=chunk,
            sharding_axis=plan_axis,
            prefill_overrides=tuple(sorted(prefill_over.items())),
            decode_overrides=tuple(sorted(decode_over.items())),
            predicted_prefill_s=_phase_cost(prefill_specs, all_kinds),
            predicted_decode_s=_phase_cost(decode_specs, all_kinds),
            rule_kmeans_agreement=km_agreement,
            role_knobs=role_knobs)


def resolve_policy(cfg: ArchConfig, **kw) -> PlacementPlan:
    """Convenience wrapper: one-shot oracle resolution."""
    return ExecutionOracle(cfg, **kw).resolve()


def verify_kmeans_agreement(cfg: ArchConfig, *, max_len: int = 512,
                            seed: int = 0, min_agreement: float = 0.5) -> float:
    """Assert the rule clusters are recoverable by the seeded k-means run for
    a served arch — the reproducibility check the tests pin per arch."""
    oracle = ExecutionOracle(cfg, max_len=max_len, seed=seed, backend="cpu")
    chars = [c for _, _, c in oracle._characterize()]
    labels_a, _ = kmeans_cluster(chars, seed=seed)
    labels_b, _ = kmeans_cluster(chars, seed=seed)
    if list(labels_a) != list(labels_b):
        raise AssertionError("kmeans_cluster is not deterministic under a seed")
    score = agreement(chars)
    if score < min_agreement:
        raise AssertionError(
            f"rule-vs-kmeans agreement {score:.2f} < {min_agreement} "
            f"for {cfg.name}")
    return score
