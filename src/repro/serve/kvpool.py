"""Paged KV-cache bookkeeping: block pool, radix prefix cache, and the
engine-facing manager.

The Mensa reading of the paper's third Edge TPU pitfall is that one-size
memory provisioning wastes capacity because working sets are heterogeneous.
The serving equivalent: a dense ``slots x max_len`` KV allocation charges
every request for the engine's worst case.  This module is the host-side
half of the fix — KV memory becomes a pool of fixed-size blocks:

* ``KVBlockPool``     — refcounted block allocator with a free list and LRU
  eviction of cached-but-unreferenced blocks.  Blocks are *indices*; the
  actual K/V tensors live in the model state tree (one
  ``models.attention.PagedKVCache`` per attention layer, all layers indexed
  by the same block ids).
* ``RadixPrefixCache`` — a radix tree over token-id keys at block
  granularity.  Finished (and freshly prefilled) prompts publish their full
  blocks; an incoming prompt walks the tree and maps every matched block to
  a shared read-only block, skipping prefill for the shared prefix.  A
  partial-block match is served copy-on-write: the block is cloned and only
  the divergent tail is computed.
* ``PagedKVManager``  — the facade ``ServeEngine`` talks to: per-slot block
  tables, admission planning (match + ref + alloc + COW), decode-time
  extension, and same-tick release when a request retires.

Everything here is plain Python over numpy block tables — device work (the
actual scatter/gather through the tables) lives in ``models/attention.py``
and ``kernels/paged_attention``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass


def blocks_for(tokens: int, block_size: int) -> int:
    """Number of blocks needed to hold ``tokens`` tokens."""
    return -(-tokens // block_size)


# ------------------------------------------------------------------ radix tree
class _RadixNode:
    """One cached block: ``key`` is the exact block_size-token tuple, ``block``
    the pool block holding its KV.  Children extend the token path."""
    __slots__ = ("key", "block", "children", "parent", "last_use")

    def __init__(self, key: tuple, block: int, parent: "_RadixNode | None"):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: dict[tuple, _RadixNode] = {}
        self.last_use = 0


@dataclass
class PrefixMatch:
    """Result of a prefix-cache lookup."""
    blocks: list[int]                    # full shared blocks, in prefix order
    partial_block: int | None = None     # block sharing only a head of tokens
    partial_tokens: int = 0              # how many of its tokens match


class RadixPrefixCache:
    """Radix tree over token ids at block granularity.

    Nodes are created when a prompt's full blocks are *published* (after
    prefill, and again — now including generated tokens — when the request
    finishes).  A published block may still be referenced by running slots;
    the pool's refcounts decide when it becomes evictable.  Eviction removes
    leaf nodes only, so every cached block's prefix path stays intact.
    """

    def __init__(self, block_size: int):
        self.block_size = block_size
        self.root = _RadixNode((), -1, None)
        self.by_block: dict[int, _RadixNode] = {}
        self._clock = 0

    def __len__(self) -> int:
        return len(self.by_block)

    def _touch(self, node: _RadixNode) -> None:
        self._clock += 1
        node.last_use = self._clock

    def match(self, tokens: list[int]) -> PrefixMatch:
        """Longest cached prefix of ``tokens``: full blocks, plus at most one
        partially-matching block (the copy-on-write candidate) whose first
        ``partial_tokens`` ids agree with the remaining tokens."""
        bs = self.block_size
        node = self.root
        blocks: list[int] = []
        i = 0
        while i + bs <= len(tokens):
            key = tuple(tokens[i:i + bs])
            child = node.children.get(key)
            if child is None:
                break
            self._touch(child)
            blocks.append(child.block)
            node = child
            i += bs
        # partial tail: the child sharing the longest strict head of the
        # remaining tokens — its block is cloned (COW) by the caller
        rest = tokens[i:]
        best, best_t = None, 0
        if rest:
            for child in node.children.values():
                t = 0
                for a, b in zip(child.key, rest):
                    if a != b:
                        break
                    t += 1
                if t > best_t:
                    best, best_t = child, t
        if best is not None:
            self._touch(best)
            return PrefixMatch(blocks, best.block, best_t)
        return PrefixMatch(blocks)

    def insert(self, tokens: list[int], block_ids: list[int]) -> int:
        """Publish the full blocks of ``tokens`` (backed by ``block_ids``,
        one per block) into the tree.  Where a path node already exists the
        existing block wins (the caller's duplicate stays owned by its slot
        and is freed on release).  Returns how many NEW blocks the tree now
        references."""
        bs = self.block_size
        node = self.root
        added = 0
        for bi in range(len(tokens) // bs):
            key = tuple(tokens[bi * bs:(bi + 1) * bs])
            child = node.children.get(key)
            if child is None:
                block = block_ids[bi]
                if block in self.by_block:       # block already published
                    break                        # (shared path diverged)
                child = _RadixNode(key, block, node)
                node.children[key] = child
                self.by_block[block] = child
                added += 1
            self._touch(child)
            node = child
        return added

    def reclaimable(self, unreferenced) -> int:
        """How many cached blocks cascading leaf-first eviction could
        actually free: a node counts only if its ENTIRE subtree is
        unreferenced — an unreferenced ancestor of a block some slot still
        maps can never become a leaf while that reference lives."""
        def walk(node):
            clean = True
            cnt = 0
            for child in node.children.values():
                c_clean, c_cnt = walk(child)
                cnt += c_cnt
                clean = clean and c_clean
            if node is self.root:
                return clean, cnt
            if clean and unreferenced(node.block):
                return True, cnt + 1
            return False, cnt
        return walk(self.root)[1]

    def evict_lru(self, evictable) -> int | None:
        """Remove and return the least-recently-used *leaf* block for which
        ``evictable(block_id)`` holds (i.e. refcount 0).  None if nothing
        qualifies."""
        best: _RadixNode | None = None
        for node in self.by_block.values():
            if node.children or not evictable(node.block):
                continue
            if best is None or node.last_use < best.last_use:
                best = node
        if best is None:
            return None
        del best.parent.children[best.key]
        del self.by_block[best.block]
        return best.block

    def contains(self, block: int) -> bool:
        return block in self.by_block


# ------------------------------------------------------------------ block pool
class KVBlockPool:
    """Fixed population of KV blocks with refcounts and a free list.

    A block is in exactly one of three states:
      * free      — on the free list, contents meaningless;
      * in use    — refcount > 0 (one ref per slot whose table maps it);
      * cached    — refcount 0 but published in the radix tree (evictable,
                    contents preserved for future prefix hits).
    """

    def __init__(self, num_blocks: int, block_size: int, shards: int = 1):
        if num_blocks < 1 or block_size < 1:
            raise ValueError(f"need >= 1 blocks of >= 1 tokens, got "
                             f"{num_blocks} x {block_size}")
        if shards < 1 or num_blocks % shards:
            raise ValueError(f"num_blocks {num_blocks} must divide into "
                             f"{shards} equal shards (the device pool is "
                             f"sharded in contiguous stripes)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.ref = [0] * num_blocks
        self.free = deque(range(num_blocks))
        self.blocks_evicted = 0
        self.in_use = 0                      # blocks with ref > 0
        self.peak_in_use = 0                 # high-water mark at alloc/retain
                                             # time, before same-tick releases
        # per-shard mirror of the device layout: when the pool's block axis is
        # sharded over a mesh, shard i owns the contiguous stripe
        # [i*N/shards, (i+1)*N/shards) — NamedSharding's split of axis 0.
        # ``peak_by_shard`` is the per-shard distribution AT the global peak,
        # so it always sums exactly to ``peak_in_use``.
        self.shards = shards
        self.in_use_by_shard = [0] * shards
        self.peak_by_shard = [0] * shards

    def shard_of(self, block: int) -> int:
        return block // (self.num_blocks // self.shards)

    def _count(self, block: int, delta: int) -> None:
        self.in_use += delta
        self.in_use_by_shard[self.shard_of(block)] += delta
        if delta > 0 and self.in_use > self.peak_in_use:
            self.peak_in_use = self.in_use
            self.peak_by_shard = list(self.in_use_by_shard)

    def available(self, tree: RadixPrefixCache) -> int:
        """Blocks allocatable right now: free + cached blocks that cascading
        leaf-first eviction can actually reach (an unreferenced block whose
        subtree holds another slot's referenced block is NOT supply)."""
        return len(self.free) + tree.reclaimable(lambda b: self.ref[b] == 0)

    def alloc(self, tree: RadixPrefixCache) -> int | None:
        """Pop a free block, evicting the LRU cached block if none is free.
        Returns None when every block is referenced."""
        if not self.free:
            victim = tree.evict_lru(lambda b: self.ref[b] == 0)
            if victim is None:
                return None
            self.blocks_evicted += 1
            self.free.append(victim)
        block = self.free.popleft()
        assert self.ref[block] == 0
        self.ref[block] = 1
        self._count(block, +1)
        return block

    def retain(self, block: int) -> None:
        if self.ref[block] == 0:             # cached -> referenced again
            self._count(block, +1)
        self.ref[block] += 1

    def release(self, block: int, tree: RadixPrefixCache) -> None:
        """Drop one reference; unpublished blocks go back to the free list
        the moment they hit refcount 0, published ones stay cached."""
        assert self.ref[block] > 0, f"double release of block {block}"
        self.ref[block] -= 1
        if self.ref[block] == 0:
            self._count(block, -1)
            if not tree.contains(block):
                self.free.append(block)


# -------------------------------------------------------------------- manager
@dataclass
class AdmitPlan:
    """What the engine must do to start a prompt on a slot."""
    matched_tokens: int = 0              # prefix tokens served from the cache
    copy: tuple[int, int] | None = None  # (src, dst) block clone (COW), if any


@dataclass
class KVPoolStats:
    prefix_queries: int = 0
    prefix_hits: int = 0
    prefix_tokens_reused: int = 0
    blocks_copied: int = 0


class PagedKVManager:
    """Per-slot block tables + admission/extension/release over the pool.

    The engine asks for an :class:`AdmitPlan` at admission (prefix match,
    refs on shared blocks, fresh blocks covering the prompt, an optional COW
    clone), calls :meth:`extend` before each decode write, and
    :meth:`finish` the same tick a request retires — which both publishes
    the finished sequence's full blocks for future prefix hits and releases
    the slot's references immediately.
    """

    #: table entries >= num_blocks mean "no block": device code drops writes
    #: through them and masks reads (see models/attention.py).
    def __init__(self, *, slots: int, max_len: int, block_size: int,
                 num_blocks: int, prefix_cache: bool = True,
                 shards: int = 1):
        if max_len % block_size:
            raise ValueError(f"max_len {max_len} must be a multiple of "
                             f"kv_block_size {block_size} (the gathered "
                             f"sequence must tile exactly for the paged path "
                             f"to stay bitwise-identical to dense)")
        self.slots = slots
        self.max_len = max_len
        self.block_size = block_size
        self.blocks_per_slot = max_len // block_size
        self.pool = KVBlockPool(num_blocks, block_size, shards)
        self.tree = RadixPrefixCache(block_size)
        self.prefix_enabled = prefix_cache
        self.sentinel = num_blocks
        # host block table; rows are padded with the sentinel
        self.table = [[self.sentinel] * self.blocks_per_slot
                      for _ in range(slots)]
        self.owned = [0] * slots             # blocks mapped per slot
        self.stats = KVPoolStats()
        # bumped on every table mutation so the engine can cache the
        # device-side copy across decode ticks
        self.version = 0
        # device bytes per physical block, set by the engine once the pool's
        # K/V arrays exist (layer count x 2 x heads x head_dim x itemsize is
        # the model's business, not the allocator's) — 0 until then, and the
        # byte telemetry below reads 0 rather than guessing
        self.block_bytes = 0

    def set_block_bytes(self, n: int) -> None:
        self.block_bytes = int(n)

    # ------------------------------------------------------------------ stats
    @property
    def in_use(self) -> int:
        return self.pool.in_use

    @property
    def bytes_in_use(self) -> int:
        """Device bytes referenced by live block mappings."""
        return self.pool.in_use * self.block_bytes

    @property
    def bytes_peak(self) -> int:
        return self.pool.peak_in_use * self.block_bytes

    @property
    def cached(self) -> int:
        return sum(1 for b in self.tree.by_block if self.pool.ref[b] == 0)

    @property
    def blocks_evicted(self) -> int:
        return self.pool.blocks_evicted

    @property
    def shards(self) -> int:
        return self.pool.shards

    @property
    def in_use_by_shard(self) -> list[int]:
        """Referenced blocks per device shard (sums to :attr:`in_use`)."""
        return list(self.pool.in_use_by_shard)

    @property
    def peak_by_shard(self) -> list[int]:
        """Per-shard distribution at the pool's high-water mark (sums to
        ``pool.peak_in_use`` exactly)."""
        return list(self.pool.peak_by_shard)

    def reset_stats(self) -> None:
        self.stats = KVPoolStats()
        self.pool.blocks_evicted = 0
        self.pool.peak_in_use = self.pool.in_use
        self.pool.peak_by_shard = list(self.pool.in_use_by_shard)

    def clear(self) -> None:
        """Forget every block and cached prefix (counters survive): the
        engine calls this when it re-initializes the device pool, whose
        contents the tree's nodes describe."""
        assert all(o == 0 for o in self.owned), \
            "clear() with slots still holding blocks"
        evicted = self.pool.blocks_evicted
        self.pool = KVBlockPool(self.pool.num_blocks, self.block_size,
                                self.pool.shards)
        self.pool.blocks_evicted = evicted
        self.tree = RadixPrefixCache(self.block_size)
        self.table = [[self.sentinel] * self.blocks_per_slot
                      for _ in range(self.slots)]
        self.version += 1

    # -------------------------------------------------------------- admission
    def admit(self, slot: int, prompt: list[int]) -> AdmitPlan | None:
        """Plan serving ``prompt`` on ``slot``: match the prefix cache, take
        references on shared blocks, allocate fresh blocks to cover the rest
        of the prompt, and clone the partially-matched block if any.  Returns
        None — with no side effects — when the pool cannot cover the prompt
        (the engine requeues the request)."""
        assert self.owned[slot] == 0, f"slot {slot} still holds blocks"
        need_total = blocks_for(len(prompt), self.block_size)
        if need_total > self.blocks_per_slot:
            raise ValueError(f"prompt of {len(prompt)} tokens exceeds "
                             f"max_len {self.max_len}")
        st = self.stats
        st.prefix_queries += 1
        # never match the full prompt: at least one token must run through
        # prefill to produce the first sampled token's logits
        match = (self.tree.match(prompt[:len(prompt) - 1])
                 if self.prefix_enabled else PrefixMatch([]))
        n_shared = len(match.blocks)
        n_cow = 1 if match.partial_tokens else 0
        n_fresh = need_total - n_shared      # includes the COW clone
        # blocks the plan is about to pin (cached shared matches + the COW
        # source) stop being evictable the moment we retain them — they must
        # not count toward the supply the fresh allocations draw from
        pinned = [b for b in match.blocks if self.pool.ref[b] == 0]
        if match.partial_tokens and self.pool.ref[match.partial_block] == 0:
            pinned.append(match.partial_block)
        if self.pool.available(self.tree) - len(pinned) < n_fresh:
            return None                      # no side effects: requeue
        row = self.table[slot]
        for i, b in enumerate(match.blocks):
            self.pool.retain(b)
            row[i] = b
        self.owned[slot] = n_shared
        copy = None
        matched = n_shared * self.block_size
        if n_cow:
            # pin the source so allocating the clone can't evict it
            self.pool.retain(match.partial_block)
            dst = self.pool.alloc(self.tree)
            self.pool.release(match.partial_block, self.tree)
            if dst is None:
                self.release(slot)           # roll back: requeue, not crash
                return None
            row[n_shared] = dst
            self.owned[slot] = n_shared + 1
            copy = (match.partial_block, dst)
            matched += match.partial_tokens
        for i in range(n_shared + n_cow, need_total):
            b = self.pool.alloc(self.tree)
            if b is None:
                self.release(slot)           # roll back: requeue, not crash
                return None
            row[i] = b
            self.owned[slot] = i + 1
        if n_cow:
            st.blocks_copied += 1
        if matched:
            st.prefix_hits += 1
            st.prefix_tokens_reused += matched
        self.version += 1
        return AdmitPlan(matched_tokens=matched, copy=copy)

    # -------------------------------------------------------------- handoff
    def adopt(self, slot: int, length: int) -> bool:
        """Map fresh blocks for a sequence of ``length`` tokens arriving from
        another engine's pool (disaggregated handoff).  Pure table remap: the
        block *contents* land via the engine's import program, which scatters
        the visiting suitcase into exactly the rows mapped here.  False — with
        no side effects — when the pool cannot cover the sequence (the
        coordinator retries next tick)."""
        assert self.owned[slot] == 0, f"slot {slot} still holds blocks"
        if not self.extend(slot, length):
            self.release(slot)               # roll back partial allocation
            return False
        return True

    # ------------------------------------------------------------- decode path
    def extend(self, slot: int, length: int) -> bool:
        """Make the slot's table cover ``length`` tokens, allocating blocks
        as decode crosses block boundaries.  False when the pool is out of
        blocks (the engine stalls the slot this tick)."""
        need = blocks_for(length, self.block_size)
        if need > self.blocks_per_slot:
            return False
        row = self.table[slot]
        while self.owned[slot] < need:
            b = self.pool.alloc(self.tree)
            if b is None:
                return False
            row[self.owned[slot]] = b
            self.owned[slot] += 1
            self.version += 1
        return True

    # ---------------------------------------------------------------- publish
    def publish(self, slot: int, tokens: list[int]) -> None:
        """Insert the slot's full blocks for ``tokens`` into the prefix tree
        so concurrent and future same-prefix requests hit them."""
        if not self.prefix_enabled:
            return
        n_full = len(tokens) // self.block_size
        if n_full == 0:
            return
        row = self.table[slot]
        self.tree.insert(tokens[:n_full * self.block_size], row[:n_full])

    def finish(self, slot: int, tokens: list[int]) -> None:
        """Same-tick retirement: publish the finished sequence's full blocks
        (``tokens`` must cover only positions whose KV was actually written —
        future prompts extending it hit them), then release every reference
        the slot holds and clear its table row."""
        self.publish(slot, tokens)
        self.release(slot)

    def release(self, slot: int) -> None:
        """Drop a slot's blocks without publishing (aborted requests, and
        the release half of :meth:`finish`)."""
        row = self.table[slot]
        for i in range(self.owned[slot]):
            self.pool.release(row[i], self.tree)
            row[i] = self.sentinel
        self.owned[slot] = 0
        self.version += 1
