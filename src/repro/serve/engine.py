"""Batched serving engine: continuous batching over a fixed slot pool.

The engine owns per-slot KV/recurrent state; requests are admitted into free
slots, prefilled, then advanced in lockstep decode steps.  Finished slots
(EOS or max_tokens) are evicted and refilled — the standard continuous-
batching pattern (vLLM-style), with a static slot count so every jitted shape
is fixed.

Prefill is *bucketed, batched, and jitted*: prompts are right-padded to a
small set of power-of-two buckets, same-bucket admissions in one tick are
stacked into one ``(N, bucket)`` prefill program (N itself bucketed to powers
of two up to ``max_prefill_batch``), and the padded prefill + splice-into-
slots runs as one compiled call — lengths and target slots are traced, so the
program inventory is exactly |buckets| x |batch buckets|.

Prompts longer than the largest bucket take the *chunked* path: the prompt is
split into ``prefill_chunk``-wide pieces that run one per tick, interleaved
with decode steps, each resuming from the slot's spliced state (cache
continuation for causal/sliding-window attention, conv + RG-LRU/SSM carry for
the recurrent families).  Decode latency for already-running slots therefore
stays bounded by one chunk, not one full long prompt.

KV storage is optionally *paged* (``kv_block_size=...``): full-attention
layers keep their KV in a global pool of fixed-size blocks addressed through
per-slot block tables (serve/kvpool.py), so KV memory scales with actual
sequence lengths instead of ``slots x max_len``, finished requests release
their blocks the same tick they retire, and a radix-tree prefix cache maps
prompts sharing a token prefix onto shared read-only blocks — the shared
portion skips prefill entirely (it resumes through the chunk-continuation
program at ``offset = matched``), with a single block clone (copy-on-write)
when the divergence falls inside a block.  Sliding-window layers keep their
dense ring (already right-sized at ``window``) and recurrent/SSM layers their
fixed-size state — per-layer-class memory organization, the Mensa reading of
the paper's memory-handling pitfall.

Sampling is per-request (temperature / top-k / top-p / seed carried in the
slot pool) and happens inside the jitted programs; greedy requests take the
exact argmax path, bit-for-bit identical to a sampling-free engine.

Serving is optionally *sharded* (``mesh=...``): model weights route through
the Mensa cluster specs in ``launch/shardings.py``, per-slot serving state
shards its slot axis over the mesh's data axes (``serve_state_specs``), and a
paged block pool shards its BLOCK axis the same way — each shard owns a
contiguous stripe of physical blocks, mirrored host-side by the pool's
per-shard accounting.  Every program is jitted with ``NamedSharding``-pinned
state outputs, so the compiled inventory stays closed (zero recompiles) on
1, 2, or 8 devices alike; on a pure data-parallel mesh no per-slot reduction
ever crosses a shard and generated tokens are bitwise identical to the
single-device engine.

``step`` interleaves work per tick — in-flight chunks advance, then at most
``max_prefill_per_step`` admissions, then one lockstep decode step whose
``active`` mask freezes dead and mid-prefill slots bit-for-bit.

Per the Mensa reading: prefill steps are compute-centric (Pascal cluster) and
decode steps memory-centric (Jacquard/Pavlov clusters); the engine keeps them
as separate jitted programs so each lowers with its own strategy — pass
``prefill_model`` / ``decode_model`` built from per-phase
``core.executor.execution_profile`` overrides to specialize each program.

Serving is optionally *disaggregated* (``role=...``): a ``role="prefill"``
engine runs bucketed/chunked prefill only and stages finished slots for
export; a ``role="decode"`` engine owns admission of finished prefills via
:meth:`adopt`, which remaps fresh blocks in its own pool and scatters the
visiting "suitcase" (the slot's state row plus its KV block contents) into
them — a device-to-device block copy, never a re-layout.  The two roles pin
to disjoint submeshes of one device set (``launch.mesh.make_role_meshes``),
so a prefill burst can no longer inflate decode latency — the DistServe
reading of the paper's one-size-fits-none argument, applied to request
phases.  ``serve.disagg.DisaggEngine`` couples the pair.  Each role warms
only its own closed program inventory (prefill + export vs decode + import),
keeping zero-recompile guarantees per submesh.

The engine is *observable by default* (repro/obs): every request's lifecycle
(submit → admit → prefill/chunk → decode → stall → finish/abort) lands in a
ring-buffered :class:`~repro.obs.Tracer` — one track per slot, per-tick
counter tracks, exportable as Chrome trace-event JSON via
:meth:`ServeEngine.save_trace` — and every duration is stamped through
:class:`~repro.obs.Timed`, which blocks on the program outputs first (JAX
dispatch is async; an unsynchronized stamp times the enqueue, not the
compute).  The engine never reads ``time.perf_counter`` directly: all stamps
come from the tracer's clock, so spans, stats, and TTFTs share one timeline
(statically enforced by jitlint JL008).  Aggregates go to the
``EngineStats.metrics`` registry (log2 histograms + counters), serialized as
the versioned ``obs`` section of ``summary()``.
"""
from __future__ import annotations

import warnings
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..models.attention import PagedKVCache
from ..models.transformer import Model
from ..obs import MetricsRegistry, ProgramRegistry, Timed, Tracer
from ..obs.drift import drift_report, plan_predictions
from .kvpool import PagedKVManager
from .sampling import sample_tokens

#: tracer track ids: queue-level request events on 0, slot ``i`` on ``1 + i``,
#: engine-wide spans (decode ticks, warmup) on ``1 + slots``
TRACK_REQUESTS = 0


# ------------------------------------------------------------------- buckets
def prefill_buckets(max_len: int, min_bucket: int = 16) -> tuple[int, ...]:
    """Power-of-two prompt buckets up to max_len: one compile per bucket.
    When max_len is not itself a power of two, a final max_len-sized bucket
    covers the gap so no prompt below the cache size is rejected."""
    out = []
    b = min_bucket
    while b <= max_len:
        out.append(b)
        b *= 2
    if not out:
        raise ValueError(f"max_len {max_len} < min_bucket {min_bucket}")
    if out[-1] < max_len:
        out.append(max_len)
    return tuple(out)


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket that fits an n-token prompt."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"prompt length {n} exceeds largest bucket {buckets[-1]}")


# --------------------------------------------------------------------- stats
@dataclass
class EngineStats:
    """Engine-side serving metrics, accumulated across ticks."""
    requests_completed: int = 0
    requests_aborted: int = 0           # unfinished when run() hit max_steps
    tokens_generated: int = 0
    prefills: int = 0                   # requests prefilled (all paths)
    prefills_chunked: int = 0           # requests prefilled via the chunked path
    prefill_calls: int = 0              # compiled batched-prefill invocations
    prefill_chunks: int = 0             # chunk-continuation invocations
    prefill_prompt_tokens: int = 0
    # prompt tokens actually run through a prefill program — prefix-cache
    # hits skip the shared portion, so computed < prompt when the cache hits
    prefill_tokens_computed: int = 0
    prefill_padded_tokens: int = 0
    prefill_time_s: float = 0.0
    decode_steps: int = 0
    decode_time_s: float = 0.0
    # TTFT: count/sum/max are exact streaming aggregates; percentiles come
    # from the fixed-size log2 histogram in ``metrics`` (O(1) memory on
    # long-lived engines, within one bucket width of exact)
    ttft_count: int = 0
    ttft_sum: float = 0.0
    ttft_max: float = 0.0
    # counters + log2 histograms (TTFT, per-tick decode latency, tokens/tick,
    # prefill padding waste) — the versioned ``obs`` section of summary()
    metrics: MetricsRegistry = field(default_factory=MetricsRegistry)
    occupancy_sum: float = 0.0          # sum over ticks of busy/slots
    ticks: int = 0
    bucket_counts: dict = field(default_factory=dict)
    batch_counts: dict = field(default_factory=dict)   # rows per prefill call
    prefill_compiles: int = 0           # jit cache entries (incl. chunk prog)
    decode_compiles: int = 0
    wall_time_s: float = 0.0
    # ---- paged KV pool (all zero on dense engines) ----
    kv_pool_blocks: int = 0             # physical blocks in the pool
    kv_block_size: int = 0
    kv_blocks_in_use: int = 0           # referenced blocks, end of last tick
    kv_blocks_peak: int = 0
    kv_blocks_cached: int = 0           # evictable prefix-cache blocks
    kv_occupancy_sum: float = 0.0       # sum over ticks of in_use/pool
    prefix_queries: int = 0
    prefix_hits: int = 0
    prefix_tokens_reused: int = 0
    blocks_copied: int = 0              # copy-on-write clones
    blocks_evicted: int = 0             # LRU evictions of cached blocks
    decode_stalls: int = 0              # slot-ticks frozen waiting for blocks
    # ---- sharded pool (mesh engines; kv_shards == 1 otherwise) ----
    kv_shards: int = 1
    kv_in_use_per_shard: list = field(default_factory=list)
    kv_peak_per_shard: list = field(default_factory=list)   # sums to peak
    # ---- disaggregated handoff (role engines; all zero interleaved) ----
    handoffs: int = 0                   # slots exported (prefill role) or
    #                                     adopted (decode role)
    handoff_time_s: float = 0.0         # export/import program time
    handoff_stalls: int = 0             # adoptions deferred: no free slot or
    #                                     no blocks on the decode pool
    # ---- placement (serve/placement.py plan summary; set by the engine) ----
    placement: dict = field(default_factory=dict)
    # ---- program cost registry (obs/programs.py; attached by the engine) ----
    programs: ProgramRegistry | None = None

    def record_ttft(self, v: float) -> None:
        self.ttft_count += 1
        self.ttft_sum += v
        if v > self.ttft_max:
            self.ttft_max = v
        self.metrics.histogram("ttft_s").record(v)

    def summary(self) -> dict:
        dec_ms = 1e3 * self.decode_time_s / max(self.decode_steps, 1)
        out = {
            "requests_completed": self.requests_completed,
            "requests_aborted": self.requests_aborted,
            "tokens_generated": self.tokens_generated,
            "tokens_per_s": self.tokens_generated / self.wall_time_s
            if self.wall_time_s else 0.0,
            "ttft_ms": {
                "mean": 1e3 * self.ttft_sum / self.ttft_count
                if self.ttft_count else 0.0,           # exact
                "p50": 1e3 * self.metrics.histogram("ttft_s").quantile(0.5),
                "max": 1e3 * self.ttft_max,            # exact
            },
            "decode_step_ms": dec_ms,
            "decode_steps": self.decode_steps,
            "prefills": self.prefills,
            "prefills_chunked": self.prefills_chunked,
            "prefill_calls": self.prefill_calls,
            "prefill_chunks": self.prefill_chunks,
            "prefill_prompt_tokens": self.prefill_prompt_tokens,
            "prefill_tokens_computed": self.prefill_tokens_computed,
            "prefill_time_s": self.prefill_time_s,
            "prefill_padding_overhead": (
                self.prefill_padded_tokens / self.prefill_prompt_tokens - 1.0
                if self.prefill_prompt_tokens else 0.0),
            "bucket_counts": dict(self.bucket_counts),
            "prefill_batch_counts": dict(self.batch_counts),
            "slot_occupancy": self.occupancy_sum / max(self.ticks, 1),
            "prefill_compiles": self.prefill_compiles,
            "decode_compiles": self.decode_compiles,
            "wall_time_s": self.wall_time_s,
        }
        if self.handoffs or self.handoff_stalls:
            out["handoff"] = {
                "handoffs": self.handoffs,
                "handoff_time_s": self.handoff_time_s,
                "handoff_stalls": self.handoff_stalls,
            }
        if self.kv_pool_blocks:
            out["kv"] = {
                "pool_blocks": self.kv_pool_blocks,
                "block_size": self.kv_block_size,
                "blocks_in_use": self.kv_blocks_in_use,
                "blocks_peak": self.kv_blocks_peak,
                "blocks_cached": self.kv_blocks_cached,
                "occupancy": self.kv_occupancy_sum / max(self.ticks, 1),
                "prefix_queries": self.prefix_queries,
                "prefix_hits": self.prefix_hits,
                "prefix_hit_rate": self.prefix_hits / self.prefix_queries
                if self.prefix_queries else 0.0,
                "prefix_tokens_reused": self.prefix_tokens_reused,
                "blocks_copied": self.blocks_copied,
                "blocks_evicted": self.blocks_evicted,
                "decode_stalls": self.decode_stalls,
            }
            if self.kv_shards > 1:
                out["kv"]["shards"] = self.kv_shards
                out["kv"]["in_use_per_shard"] = list(self.kv_in_use_per_shard)
                out["kv"]["peak_per_shard"] = list(self.kv_peak_per_shard)
        if self.placement:
            # plan (predicted) + measured + drift, side by side — the triple
            # benchmarks/calibrate.py fits the cost model against (same
            # obs.drift arithmetic, so the numbers agree exactly)
            p = dict(self.placement)
            p["measured"] = {
                "prefill_call_s": self.prefill_time_s
                / max(self.prefill_calls + self.prefill_chunks, 1),
                "prefill_token_s": self.prefill_time_s
                / max(self.prefill_tokens_computed, 1),
                "decode_step_s": self.decode_time_s
                / max(self.decode_steps, 1),
            }
            p["drift"] = drift_report(plan_predictions(p), p["measured"])
            if p["drift"] and self.programs is not None:
                # per-cluster measured-vs-predicted: the program registry's
                # phase totals attributed over the plan's clusters, next to
                # the whole-engine drift the calibration gate consumes
                clusters = self.programs.cluster_rollup()
                if clusters:
                    p["drift"]["clusters"] = clusters
            out["placement"] = p
        if self.programs is not None:
            out["programs"] = self.programs.summary()
        out["obs"] = self.metrics.to_dict()
        return out


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int = -1
    # sampling: temperature <= 0 is exact greedy argmax (the default);
    # seed None derives a per-request stream from rid
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int | None = None
    generated: list[int] = field(default_factory=list)
    done: bool = False
    aborted: bool = False               # unfinished when run() gave up
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 512, greedy: bool = True,
                 buckets: tuple[int, ...] | None = None,
                 min_bucket: int = 16,
                 max_prefill_per_step: int = 1,
                 max_prefill_batch: int = 4,
                 prefill_chunk: int | None = None,
                 kv_block_size: int | None = None,
                 kv_blocks: int | None = None,
                 prefix_cache: bool = True,
                 mesh=None,
                 param_strategy: str = "tp",
                 prefill_model: Model | None = None,
                 decode_model: Model | None = None,
                 policy=None,
                 role: str = "both",
                 track_base: int = 0,
                 tracer: Tracer | None = None,
                 profile: bool = False,
                 program_memory: bool = False):
        """``greedy`` is a legacy knob: sampling is now per-request
        (Request.temperature/top_k/top_p/seed) and greedy stays the exact
        default, so both values are accepted and equivalent.

        ``kv_block_size``: enable the paged KV pool with this many tokens per
        block (must divide max_len).  ``kv_blocks``: physical blocks in the
        pool (default: the dense equivalent, slots * max_len / block_size —
        pass less to actually cap KV memory).  ``prefix_cache``: share
        same-prefix KV blocks across requests via the radix tree; requires
        every layer to be a full-attention layer (block-sharable state) and
        silently disables itself otherwise.

        ``mesh``: optional ``jax.sharding.Mesh`` with (data, model) axes
        (``launch.mesh.make_serve_mesh``).  Weights shard through
        ``launch.shardings.param_specs`` (``param_strategy``: "tp" for the
        Mensa cluster templates, "dp" for replicated blocks), serving state
        through ``serve_state_specs`` (slots and — paged — pool blocks over
        the data axes; heads/recurrence width over ``model`` when they
        divide it).  Axes that don't divide evenly fall back to replicated,
        so any mesh serves any shape.  Program outputs are pinned to the
        canonical state sharding, keeping the compiled inventory closed.

        ``policy``: optional ``serve.placement.PlacementPlan`` from the
        ExecutionOracle.  A plan supplies the bucket ladder and prefill
        chunk (explicit constructor arguments still win) and is recorded in
        ``EngineStats.placement``; its per-phase kernel-variant overrides
        are applied by the caller when building ``prefill_model`` /
        ``decode_model`` (see ``launch.serve.build_engine``).  Plans are
        resolved before any program compiles and never consulted per tick,
        so the zero-recompile invariant is untouched.

        ``param_strategy``: "tp" (Mensa cluster templates), "dp"
        (replicated blocks), or "auto" — route each block family's
        parameters by its cluster's ``ExecutionPolicy.sharding_axis`` from
        the plan (memory-centric clusters replicate, compute-centric ones
        take the TP templates).

        ``role``: "both" (default, interleaved engine), "prefill" (runs
        bucketed/chunked prefill only; finished slots queue on ``ready``
        for :meth:`export_slot` + :meth:`release_handoff`), or "decode"
        (never admits from the queue; sequences arrive via :meth:`adopt`).
        Role engines warm only their own program inventory and carry a
        handoff program each (export/import).  ``track_base`` offsets this
        engine's tracer tracks so two role engines share one timeline
        without colliding; role engines also prefix their track and counter
        names with the role.

        ``tracer``: a :class:`repro.obs.Tracer`; default is a fresh enabled
        one (pass ``Tracer(enabled=False)`` to opt out).  ``profile=True``
        wraps each timed section in a ``jax.profiler.TraceAnnotation`` so
        XLA profiles line up with engine spans.

        ``program_memory=True`` additionally AOT-compiles each program at
        warmup for its ``memory_analysis()`` temp/argument/output watermarks
        in the ``programs`` stats section (roughly doubles warmup compile
        time; the static FLOPs/bytes cost registry is on either way and
        costs one extra lowering per program)."""
        del greedy                      # superseded by per-request sampling
        if role not in ("both", "prefill", "decode"):
            raise ValueError(f"role {role!r} not in "
                             f"('both', 'prefill', 'decode')")
        self.role = role
        self.track_base = track_base
        self.tracer = tracer if tracer is not None else Tracer()
        self.profile = profile
        self.model = model
        self.mesh = mesh
        self.slots = slots
        self.max_len = max_len
        # number of data shards the mesh carries (1 = unsharded)
        if mesh is not None:
            from ..launch.mesh import data_axes
            self._nd = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
            self._data_axes = data_axes(mesh)
        else:
            self._nd = 1
            self._data_axes = ()
        if not buckets and policy is not None and policy.buckets:
            buckets = policy.buckets
        self.buckets = tuple(sorted(buckets)) if buckets \
            else prefill_buckets(max_len, min_bucket)
        if self.buckets[-1] > max_len:
            raise ValueError(f"bucket {self.buckets[-1]} > max_len {max_len}")
        self.max_prefill_per_step = max(1, max_prefill_per_step)
        # batch-bucket the admission group size so the compiled-program
        # inventory stays |buckets| x |batch_buckets|, not one per group size
        self.max_prefill_batch = max(1, min(max_prefill_batch, slots))
        self.batch_buckets = prefill_buckets(self.max_prefill_batch,
                                             min_bucket=1)
        if not prefill_chunk and policy is not None and policy.prefill_chunk:
            prefill_chunk = policy.prefill_chunk
        self.prefill_chunk = int(prefill_chunk) if prefill_chunk \
            else self.buckets[-1]
        if not 1 <= self.prefill_chunk <= max_len:
            raise ValueError(f"prefill_chunk {self.prefill_chunk} outside "
                             f"[1, max_len {max_len}]")
        # every engine carries a plan: either the oracle's resolution or a
        # "fixed" record of the constructor knobs (EngineStats.placement)
        if policy is None:
            from .placement import fixed_plan
            policy = fixed_plan(model.cfg, buckets=self.buckets,
                                prefill_chunk=self.prefill_chunk,
                                backend=jax.default_backend())
        self.policy = policy
        # per-phase programs (Mensa: compute-centric prefill vs memory-centric
        # decode lower as separate jitted functions)
        self.prefill_model = prefill_model or model
        self.decode_model = decode_model or model
        # ------------------------------------------------- paged KV pool
        self.kv: PagedKVManager | None = None
        self._state_kw: dict = {}
        if kv_block_size is not None:
            blocks_per_slot = -(-max_len // kv_block_size)
            if kv_blocks is None:
                kv_blocks = slots * blocks_per_slot
            if kv_blocks < blocks_per_slot:
                # a pool smaller than one request's worst case could never
                # admit a long prompt: admission would requeue it forever on
                # an otherwise idle engine
                raise ValueError(
                    f"kv_blocks {kv_blocks} < max_len/kv_block_size "
                    f"{blocks_per_slot}: the pool must cover at least one "
                    f"request's worst case")
            # prefix reuse needs every layer's per-token state to live in
            # sharable blocks: full-attention stacks only (window rings and
            # recurrent states are not block-addressable)
            kinds = tuple(model.pattern) + tuple(model.tail_kinds)
            prefix_ok = bool(kinds) and all(k == "attn" for k in kinds)
            # the device pool shards its block axis over the data axes only
            # when the stripes come out equal — the host-side accounting
            # mirrors exactly that layout
            shards = self._nd if self._nd > 1 \
                and kv_blocks % self._nd == 0 else 1
            self.kv = PagedKVManager(
                slots=slots, max_len=max_len, block_size=kv_block_size,
                num_blocks=kv_blocks,
                prefix_cache=prefix_cache and prefix_ok,
                shards=shards)
            self._state_kw = dict(kv_block_size=kv_block_size,
                                  kv_blocks=kv_blocks)
        # ------------------------------------------------- mesh placement
        self._state_shardings = None
        self._kv_gather_spec = None
        if mesh is not None:
            from ..launch import shardings as shard_lib
            specs = shard_lib.serve_state_specs(
                model, mesh, slots, max_len, **self._state_kw)
            self._state_shardings = shard_lib.to_named(specs, mesh)
            params = jax.device_put(
                params, shard_lib.to_named(
                    shard_lib.param_specs(model.cfg, params,
                                          strategy=param_strategy,
                                          plan=self.policy), mesh))
            if self.kv is not None:
                self._kv_gather_spec = self._make_gather_spec()
        self.params = params
        self.states = model.init_states(slots, max_len, **self._state_kw,
                                        shardings=self._state_shardings)
        self.memory = None
        self.requests: list[Request | None] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        # per-slot sampling knobs, applied inside the jitted programs
        self.samp_temp = np.zeros(slots, np.float32)
        self.samp_topk = np.zeros(slots, np.int32)
        self.samp_topp = np.ones(slots, np.float32)
        self.samp_seed = np.zeros(slots, np.int32)
        # donate the pool state: every program updates slots in place instead
        # of copying the whole pool each call.  On a mesh, pin the state
        # outputs to the canonical sharding — otherwise XLA's propagated
        # choice could differ from the input placement and the next call
        # would recompile on the changed sharding.
        if mesh is None:
            out_sh = dict(decode=None, prefill=None, chunk=None, copy=None,
                          export=None, imp=None)
        else:
            repl = NamedSharding(mesh, PartitionSpec())
            st = self._state_shardings
            out_sh = dict(decode=(repl, st), prefill=(repl, st),
                          chunk=(repl, st), copy=st, export=repl, imp=st)
        self._decode = jax.jit(self._decode_and_sample, donate_argnums=(2,),
                               out_shardings=out_sh["decode"])
        self._prefill = jax.jit(self._prefill_and_splice,
                                donate_argnums=(4,),
                                out_shardings=out_sh["prefill"])
        self._chunk = jax.jit(self._chunk_and_splice, donate_argnums=(5,),
                              out_shardings=out_sh["chunk"])
        self._copy = jax.jit(self._copy_blocks, donate_argnums=(0,),
                             out_shardings=out_sh["copy"]) \
            if self.kv is not None else None
        # disaggregated handoff pair (role engines only): export packs a slot
        # into a self-contained suitcase replicated on the prefill submesh,
        # import scatters a visiting suitcase into this engine's pool
        self._export = jax.jit(self._export_slot,
                               out_shardings=out_sh["export"]) \
            if role == "prefill" else None
        self._import = jax.jit(self._import_slot, donate_argnums=(0,),
                               out_shardings=out_sh["imp"]) \
            if role == "decode" else None
        self._queue: deque[Request] = deque()
        self._prefilling: dict[int, int] = {}   # slot -> prompt tokens consumed
        # prefill role: slots whose prefill finished, awaiting export by the
        # coordinator (blocks stay pinned until release_handoff)
        self.ready: deque[int] = deque()
        # decode-tick device caches: the full block table and sampling arrays
        # change only on admission/extension/retirement, not every tick
        self._bt_cache = None
        self._bt_version = -1
        self._samp_cache = None
        # trace track layout: queue events, one track per slot, engine-wide —
        # all offset by track_base so cooperating role engines share one
        # tracer timeline; role engines prefix their track + counter names
        pfx = "" if role == "both" else f"{role}/"
        self._ctr_prefix = pfx
        self._trk_req = track_base + TRACK_REQUESTS
        self.tracer.set_track(self._trk_req, f"{pfx}requests")
        for s in range(slots):
            self.tracer.set_track(self._slot_track(s), f"{pfx}slot {s}")
        self._trk_engine = track_base + 1 + slots
        self.tracer.set_track(self._trk_engine, f"{pfx}engine")
        # ------------------------------------------- program cost registry
        self.programs = ProgramRegistry(plan_summary=self.policy.summary())
        self._program_memory = program_memory
        # static device-memory telemetry: the state tree realizes
        # serve_state_specs, so its leaf sizes ARE the per-slot footprint;
        # paged K/V leaves belong to the pool, everything else to the slots
        pool_bytes, state_bytes = self._state_byte_stats()
        self._slot_state_bytes = state_bytes // slots
        if self.kv is not None:
            self.kv.set_block_bytes(pool_bytes // self.kv.pool.num_blocks)
        self.stats = EngineStats()
        self._init_kv_stats()

    def _timed(self, name: str) -> Timed:
        """A Timed section on the tracer's clock (one shared timeline)."""
        return Timed(name, profile=self.profile, clock=self.tracer.clock)

    def _slot_track(self, slot: int) -> int:
        """Tracer track id of ``slot`` (track_base-relative)."""
        return self.track_base + 1 + slot

    def _make_gather_spec(self):
        """``batch -> NamedSharding`` routing the paged ops' gathered K/V
        into the slot layout: batch on the data axes (when the program's
        batch divides them), heads on ``model`` when they split evenly.
        Passed per call to prefill/decode_step — the phase models stay
        stateless and shareable across engines."""
        mesh, nd, d = self.mesh, self._nd, self._data_axes
        kvh = self.model.cfg.num_kv_heads
        mp = int(mesh.shape.get("model", 1))
        hax = "model" if mp > 1 and kvh and kvh % mp == 0 else None

        def spec(batch: int):
            if batch % nd == 0 and batch >= nd:
                return NamedSharding(mesh, PartitionSpec(d, None, hax, None))
            if hax is not None:
                return NamedSharding(
                    mesh, PartitionSpec(None, None, hax, None))
            return None                  # let XLA pick (e.g. batch-1 chunks)

        return spec

    def _state_byte_stats(self) -> tuple[int, int]:
        """(paged pool K/V bytes, per-slot state bytes) of the state tree."""
        pool_b = state_b = 0
        for leaf in jax.tree.leaves(self.states, is_leaf=_is_paged):
            if _is_paged(leaf):
                pool_b += leaf.k.nbytes + leaf.v.nbytes
            elif hasattr(leaf, "nbytes"):
                state_b += leaf.nbytes
        return pool_b, state_b

    def _init_kv_stats(self) -> None:
        if self.kv is not None:
            self.stats.kv_pool_blocks = self.kv.pool.num_blocks
            self.stats.kv_block_size = self.kv.block_size
            self.stats.kv_shards = self.kv.shards
        self.stats.placement = self.policy.summary()
        self.stats.programs = self.programs
        # static memory gauges (the per-tick values update in _tick_counters)
        m = self.stats.metrics
        m.gauge("slot_state_bytes", "bytes").set(self._slot_state_bytes)
        if self.kv is not None:
            m.gauge("kv_pool_capacity_bytes", "bytes").set(
                self.kv.pool.num_blocks * self.kv.block_bytes)
        tmp = self.programs.temp_bytes_peak()
        if tmp:
            m.gauge("program_temp_bytes_peak", "bytes").set(tmp)

    def reset_stats(self) -> None:
        self.stats = EngineStats()
        if self.kv is not None:
            self.kv.reset_stats()
        self.programs.reset_observed()
        self._init_kv_stats()
        self._sync_compile_stats()
        self._sync_kv_stats()

    def _sync_compile_stats(self) -> None:
        # _cache_size is a private jit attribute; degrade stats (not serving)
        # if a JAX upgrade drops it
        def size(fn):
            if fn is None:
                return 0
            return getattr(fn, "_cache_size", lambda: 0)()
        self.stats.prefill_compiles = size(self._prefill) \
            + size(self._chunk) + size(self._copy) + size(self._export)
        self.stats.decode_compiles = size(self._decode) + size(self._import)

    def _sync_kv_stats(self) -> None:
        if self.kv is None:
            return
        st, mgr = self.stats, self.kv
        st.kv_blocks_in_use = mgr.in_use
        st.kv_in_use_per_shard = mgr.in_use_by_shard
        # the pool tracks its high-water mark at alloc/retain time, so the
        # peak sees blocks that were allocated and released within one tick;
        # the per-shard snapshot is the distribution AT that peak, so it sums
        # to kv_blocks_peak exactly
        if mgr.pool.peak_in_use >= st.kv_blocks_peak:
            st.kv_peak_per_shard = mgr.peak_by_shard
        st.kv_blocks_peak = max(st.kv_blocks_peak, mgr.pool.peak_in_use)
        st.kv_blocks_cached = mgr.cached
        st.prefix_queries = mgr.stats.prefix_queries
        st.prefix_hits = mgr.stats.prefix_hits
        st.prefix_tokens_reused = mgr.stats.prefix_tokens_reused
        st.blocks_copied = mgr.stats.blocks_copied
        st.blocks_evicted = mgr.blocks_evicted

    def _tick_counters(self, ts: float, busy: int) -> None:
        """Per-tick counter-track samples (queue depth, slot occupancy,
        paged KV-pool in-use/cached, device-memory bytes) plus the memory
        gauges — gauges update even untraced so ``summary()`` always carries
        the latest occupancy in bytes."""
        m = self.stats.metrics
        state_bytes = busy * self._slot_state_bytes
        m.gauge("active_state_bytes", "bytes").set(state_bytes)
        if self.kv is not None:
            m.gauge("kv_pool_bytes", "bytes").set(self.kv.bytes_in_use)
            m.gauge("kv_pool_bytes_peak", "bytes").set(self.kv.bytes_peak)
        tr, p = self.tracer, self._ctr_prefix
        if not tr.enabled:
            return
        tr.counter(p + "queue_depth", ts, (("queued", len(self._queue)),))
        tr.counter(p + "slots", ts, (("busy", busy),
                                     ("free", self.slots - busy)))
        if self.kv is not None:
            tr.counter(p + "kv_blocks", ts, (("in_use", self.kv.in_use),
                                             ("cached", self.kv.cached)))
            if self.kv.shards > 1:
                tr.counter(p + "kv_in_use_by_shard", ts, tuple(
                    (f"shard{i}", v)
                    for i, v in enumerate(self.kv.in_use_by_shard)))
        series = [("slot_state", state_bytes)]
        if self.kv is not None:
            series.append(("kv_pool", self.kv.bytes_in_use))
        tr.counter(p + "device_memory_bytes", ts, tuple(series))

    def save_trace(self, path) -> None:
        """Write the Chrome trace-event JSON for everything traced so far,
        with the stats summary's placement section (plan + measured + drift)
        and the metrics registry embedded under ``otherData``."""
        summary = self.stats.summary()
        other = {"obs": summary["obs"]}
        if "placement" in summary:
            other["placement"] = summary["placement"]
        if "programs" in summary:
            other["programs"] = summary["programs"]
        self.tracer.save(path, other_data=other)

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError("empty prompt: nothing to condition on")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "samples the first token)")
        if len(req.prompt) > self.max_len - 1:
            # a max_len-token prompt fills the cache completely: the first
            # decode write would land past the last slot and be dropped
            raise ValueError(f"prompt length {len(req.prompt)} leaves no "
                             f"cache room to decode (max_len {self.max_len})")
        if req.temperature < 0:
            raise ValueError("temperature must be >= 0 (0 = greedy)")
        if not 0 < req.top_p <= 1:
            raise ValueError("top_p must be in (0, 1]")
        if req.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 = no top-k filter)")
        req.t_submit = self.tracer.now()
        self.tracer.instant("submit", self._trk_req, req.t_submit,
                            (("rid", req.rid),
                             ("prompt_tokens", len(req.prompt))))
        self._queue.append(req)

    def _set_sampling(self, slot: int, req: Request) -> None:
        self.samp_temp[slot] = req.temperature
        self.samp_topk[slot] = req.top_k
        self.samp_topp[slot] = req.top_p
        self.samp_seed[slot] = req.seed if req.seed is not None \
            else req.rid & 0x7FFFFFFF
        self._samp_cache = None

    def _decode_args(self):
        """Cached device copies of the full block table + per-slot sampling
        arrays — rebuilt only when admission/extension/retirement touched
        them, not on every decode tick."""
        if self.kv is None:
            bt = None
        else:
            if self._bt_cache is None or self._bt_version != self.kv.version:
                self._bt_cache = jnp.asarray(
                    np.asarray(self.kv.table, np.int32))
                self._bt_version = self.kv.version
            bt = self._bt_cache
        if self._samp_cache is None:
            self._samp_cache = (jnp.asarray(self.samp_temp),
                                jnp.asarray(self.samp_topk),
                                jnp.asarray(self.samp_topp),
                                jnp.asarray(self.samp_seed))
        return bt, self._samp_cache

    def _admit(self, budget: int) -> int:
        free = [s for s in range(self.slots) if self.requests[s] is None]
        take = min(budget, len(free), len(self._queue))
        if take <= 0:
            return 0
        groups: dict[int, list[tuple[int, Request]]] = {}
        admitted = 0
        while admitted < take:
            req = self._queue[0]
            slot = free[0]
            matched = 0
            copy = None
            if self.kv is not None:
                plan = self.kv.admit(slot, req.prompt)
                if plan is None:
                    # pool can't cover the prompt yet: keep FIFO order and
                    # retry next tick (decode frees blocks as requests end)
                    break
                matched = plan.matched_tokens
                copy = plan.copy
            self._queue.popleft()
            free.pop(0)
            self.requests[slot] = req
            self._set_sampling(slot, req)
            now = self.tracer.now()
            self.tracer.begin(f"req {req.rid}", self._slot_track(slot), now,
                              (("rid", req.rid),
                               ("prompt_tokens", len(req.prompt)),
                               ("prefix_hit_tokens", matched),
                               ("queue_wait_s", round(now - req.t_submit, 6))))
            if copy is not None:
                self.tracer.instant("cow_copy", self._slot_track(slot), now,
                                    (("rid", req.rid), ("src", copy[0]),
                                     ("dst", copy[1])))
                self._run_copy(*copy)
            admitted += 1
            if matched > 0 or len(req.prompt) > self.buckets[-1]:
                # chunked path: long prompts, and prefix-cache hits of any
                # length — the hit resumes prefill at offset=matched through
                # the same chunk-continuation program
                self._prefilling[slot] = matched
                self._advance_chunk(slot)
            else:
                b = bucket_for(len(req.prompt), self.buckets)
                groups.setdefault(b, []).append((slot, req))
        for b in sorted(groups):
            members = groups[b]
            for i in range(0, len(members), self.max_prefill_batch):
                self._prefill_group(b, members[i:i + self.max_prefill_batch])
        self._sync_kv_stats()
        return admitted

    # ------------------------------------------------------ compiled programs
    def _decode_and_sample(self, params, tokens, pool_states, positions,
                           memory, active, block_table, temp, topk, topp,
                           seed):
        """The decode program: one lockstep step over the slot pool + in-jit
        per-slot sampling of the next token (greedy rows take exact argmax)."""
        logits, states = self.decode_model.decode_step(
            params, tokens, pool_states, positions, memory, active,
            block_table, gather_spec=self._kv_gather_spec)
        nxt = sample_tokens(logits[:, 0], temp, topk, topp, seed,
                            positions + 1)
        return nxt, states

    def _prefill_and_splice(self, params, tokens, lengths, slot_ids,
                            pool_states, block_tables, temp, topk, topp,
                            seed):
        """One compiled program per (batch-bucket, bucket) shape: padded
        (N, bucket) prefill, splice each row into the pool at ``slot_ids[i]``,
        return the N first sampled tokens.  Padding rows (group smaller than
        the batch bucket) carry slot_ids[0]; rows splice in REVERSE order so
        the real row that shares a padding row's target lands last and wins.
        In paged mode the padding rows' block-table entries are the sentinel,
        so their KV writes drop instead."""
        n = tokens.shape[0]
        states_n = self.prefill_model.init_states(n, self.max_len,
                                                  **self._state_kw)
        if self.kv is not None:
            states_n = _adopt_pool_kv(states_n, pool_states)
        logits, states_n, _ = self.prefill_model.prefill(
            params, tokens, states_n, length=lengths,
            block_table=block_tables, gather_spec=self._kv_gather_spec)
        for i in reversed(range(n)):
            row = _state_row(states_n, i)
            pool_states = _splice_states(pool_states, row, slot_ids[i])
        first = sample_tokens(logits[:, 0], temp, topk, topp, seed, lengths)
        return first, pool_states

    def _chunk_and_splice(self, params, tokens, offset, length, slot,
                          pool_states, block_table, temp, topk, topp, seed):
        """One compiled program for every chunk of every long prompt (and for
        every prefix-cache-hit suffix): gather the slot's state, resume
        prefill at ``offset`` with the (1, C) chunk, splice back, return the
        sampled token (meaningful on the final chunk only)."""
        row = _gather_slot(pool_states, slot)
        logits, row, _ = self.prefill_model.prefill(
            params, tokens, row, length=length[None], offset=offset[None],
            block_table=block_table, gather_spec=self._kv_gather_spec)
        pool = _splice_states(pool_states, row, slot)
        tok = sample_tokens(logits[:, -1], temp, topk, topp, seed,
                            (offset + length)[None])
        return tok[0], pool

    def _copy_blocks(self, pool_states, src, dst):
        """Clone physical block ``src`` into ``dst`` across every paged
        layer — the copy-on-write step for a partial-block prefix hit."""
        def tail_copy(x):
            if isinstance(x, PagedKVCache):
                return x._replace(k=x.k.at[dst].set(x.k[src]),
                                  v=x.v.at[dst].set(x.v[src]))
            return x

        def group_copy(x):
            if isinstance(x, PagedKVCache):
                return x._replace(k=x.k.at[:, dst].set(x.k[:, src]),
                                  v=x.v.at[:, dst].set(x.v[:, src]))
            return x

        return {"groups": jax.tree.map(group_copy, pool_states["groups"],
                                       is_leaf=_is_paged),
                "tail": jax.tree.map(tail_copy, pool_states["tail"],
                                     is_leaf=_is_paged)}

    def _run_copy(self, src: int, dst: int) -> None:
        with self._timed("kv_copy") as tm:
            self.states = self._copy(self.states,
                                     jnp.asarray(src, jnp.int32),
                                     jnp.asarray(dst, jnp.int32))
            tm.sync(self.states)
        self.programs.observe("copy", tm.dur, phase="kv", program="_copy")
        self.tracer.span("kv_copy", self._trk_engine, tm.t0, tm.t1,
                         (("src", src), ("dst", dst)))

    def _export_slot(self, pool_states, slot, table_row):
        """Pack slot ``slot`` into a self-contained handoff suitcase: the
        batch-1 state row (dense caches, window rings, RG-LRU/SSM carries —
        everything ``serve_state_specs`` describes) plus, paged, the slot's
        own KV block *contents* gathered through its block-table row.  The
        suitcase shape depends on blocks-per-slot only, never on this pool's
        size, so it travels between pools of different capacities.  Sentinel
        rows (unowned tail of the table) clip to a valid block and gather
        garbage — the import side's sentinel destination rows drop exactly
        those writes."""
        row = _gather_slot(pool_states, slot)
        if self.kv is None:
            return row
        idx = jnp.clip(table_row, 0, self.kv.pool.num_blocks - 1)

        def tail(a):
            return a._replace(k=a.k[idx], v=a.v[idx]) if _is_paged(a) else a

        def grp(a):
            return a._replace(k=a.k[:, idx], v=a.v[:, idx]) \
                if _is_paged(a) else a

        return {"groups": jax.tree.map(grp, row["groups"], is_leaf=_is_paged),
                "tail": jax.tree.map(tail, row["tail"], is_leaf=_is_paged)}

    def _import_slot(self, pool_states, row, slot, table_row):
        """Unpack a visiting suitcase into slot ``slot``: scatter its block
        contents into the pool rows mapped by ``table_row`` (a device-to-
        device block copy between pool stripes — never a re-layout), then
        splice the batch-1 state row.  Sentinel table entries are out of
        bounds by exactly one, so ``mode="drop"`` discards the suitcase's
        garbage tail the same way padded prefill rows drop their writes."""
        if self.kv is not None:
            def tail(pool, new):
                if _is_paged(pool):
                    return new._replace(
                        k=pool.k.at[table_row].set(
                            new.k.astype(pool.k.dtype), mode="drop"),
                        v=pool.v.at[table_row].set(
                            new.v.astype(pool.v.dtype), mode="drop"))
                return new

            def grp(pool, new):
                if _is_paged(pool):
                    return new._replace(
                        k=pool.k.at[:, table_row].set(
                            new.k.astype(pool.k.dtype), mode="drop"),
                        v=pool.v.at[:, table_row].set(
                            new.v.astype(pool.v.dtype), mode="drop"))
                return new

            row = {"groups": jax.tree.map(grp, pool_states["groups"],
                                          row["groups"], is_leaf=_is_paged),
                   "tail": jax.tree.map(tail, pool_states["tail"],
                                        row["tail"], is_leaf=_is_paged)}
        return _splice_states(pool_states, row, slot)

    # -------------------------------------------------------- host-side args
    def _tables_for(self, slot_ids: list[int], rows: int) -> jax.Array | None:
        """(rows, blocks_per_slot) block-table rows for the given slots;
        padding rows (beyond ``slot_ids``) are all-sentinel so the compiled
        program drops their writes."""
        if self.kv is None:
            return None
        bt = np.full((rows, self.kv.blocks_per_slot), self.kv.sentinel,
                     np.int32)
        for i, s in enumerate(slot_ids):
            bt[i] = self.kv.table[s]
        return jnp.asarray(bt)

    def _samp_rows(self, slot_ids: list[int], rows: int):
        t = np.zeros(rows, np.float32)
        k = np.zeros(rows, np.int32)
        p = np.ones(rows, np.float32)
        s = np.zeros(rows, np.int32)
        for i, sl in enumerate(slot_ids):
            t[i] = self.samp_temp[sl]
            k[i] = self.samp_topk[sl]
            p[i] = self.samp_topp[sl]
            s[i] = self.samp_seed[sl]
        return jnp.asarray(t), jnp.asarray(k), jnp.asarray(p), jnp.asarray(s)

    # -------------------------------------------------------------- prefill
    def _prefill_group(self, bucket: int, members: list) -> None:
        n = len(members)
        nb = bucket_for(n, self.batch_buckets)
        toks = np.zeros((nb, bucket), np.int32)
        lens = np.ones((nb,), np.int32)
        slot_ids = np.full((nb,), members[0][0], np.int32)
        for i, (slot, req) in enumerate(members):
            toks[i, :len(req.prompt)] = req.prompt
            lens[i] = len(req.prompt)
            slot_ids[i] = slot
        slots_real = [slot for slot, _ in members]
        bt = self._tables_for(slots_real, nb)
        samp = self._samp_rows(slots_real, nb)
        with self._timed("prefill") as tm:
            first, self.states = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(lens),
                jnp.asarray(slot_ids), self.states, bt, *samp)
            first = tm.sync(first)           # device sync BEFORE the stamp
        first = np.asarray(first)
        now = tm.t1
        st = self.stats
        st.prefill_calls += 1
        st.prefill_time_s += tm.dur
        self.programs.observe(f"prefill[{nb}x{bucket}]", tm.dur,
                              phase="prefill", program="_prefill")
        st.batch_counts[n] = st.batch_counts.get(n, 0) + 1
        waste = st.metrics.counter("prefill_waste_tokens", "tokens")
        for i, (slot, req) in enumerate(members):
            tok = int(first[i])
            self.positions[slot] = len(req.prompt)
            req.generated.append(tok)
            req.t_first_token = now
            st.prefills += 1
            st.prefill_prompt_tokens += len(req.prompt)
            st.prefill_tokens_computed += len(req.prompt)
            st.prefill_padded_tokens += bucket
            waste.inc(bucket - len(req.prompt))
            self.tracer.span("prefill", self._slot_track(slot), tm.t0, tm.t1,
                             (("rid", req.rid), ("bucket", bucket),
                              ("rows", n)))
            st.record_ttft(now - req.t_submit)
            st.bucket_counts[bucket] = st.bucket_counts.get(bucket, 0) + 1
            if self.kv is not None:
                self.kv.publish(slot, req.prompt)
            if len(req.generated) >= req.max_new_tokens or tok == req.eos_id:
                self._finish(slot, now)
            elif self.role == "prefill":
                self._stage_ready(slot, now)

    def _advance_chunk(self, slot: int) -> None:
        req = self.requests[slot]
        off = self._prefilling[slot]
        c = self.prefill_chunk
        piece = req.prompt[off:off + c]
        n = len(piece)
        toks = np.zeros((1, c), np.int32)
        toks[0, :n] = piece
        bt = self._tables_for([slot], 1)
        samp = self._samp_rows([slot], 1)
        # every chunk syncs on its sampled token before the stamp: the old
        # no-sync fast path on intermediate chunks recorded dispatch time as
        # prefill time (the async-dispatch under-reporting bug) and hid the
        # chunk's real cost from the per-tick timeline
        with self._timed("prefill_chunk") as tm:
            tok, self.states = self._chunk(
                self.params, jnp.asarray(toks), jnp.asarray(off, jnp.int32),
                jnp.asarray(n, jnp.int32), jnp.asarray(slot, jnp.int32),
                self.states, bt, *samp)
            tok = tm.sync(tok)
        st = self.stats
        st.prefill_chunks += 1
        st.prefill_padded_tokens += c
        st.prefill_tokens_computed += n
        st.prefill_time_s += tm.dur
        self.programs.observe("chunk", tm.dur, phase="prefill",
                              program="_chunk")
        st.metrics.counter("prefill_waste_tokens", "tokens").inc(c - n)
        self.tracer.span("prefill_chunk", self._slot_track(slot),
                         tm.t0, tm.t1,
                         (("rid", req.rid), ("offset", off), ("n", n)))
        if off + n < len(req.prompt):
            self._prefilling[slot] = off + n
            return
        tok = int(tok)                       # final chunk: sample first token
        now = tm.t1
        del self._prefilling[slot]
        self.positions[slot] = len(req.prompt)
        req.generated.append(tok)
        req.t_first_token = now
        st.prefills += 1
        st.prefills_chunked += 1
        st.prefill_prompt_tokens += len(req.prompt)
        st.record_ttft(now - req.t_submit)
        if self.kv is not None:
            self.kv.publish(slot, req.prompt)
        if len(req.generated) >= req.max_new_tokens or tok == req.eos_id:
            self._finish(slot, now)
        elif self.role == "prefill":
            self._stage_ready(slot, now)

    def _finish(self, slot: int, now: float) -> None:
        req = self.requests[slot]
        req.done = True
        req.aborted = False
        req.t_done = now
        self.tracer.end(f"req {req.rid}", self._slot_track(slot), now,
                        (("rid", req.rid),
                         ("tokens", len(req.generated))))
        self.requests[slot] = None
        if self.kv is not None:
            # same-tick reclamation: publish the finished sequence for future
            # prefix hits, then release every block the slot held.  The LAST
            # generated token was sampled but never fed back through decode,
            # so its KV was never written — publish only the written prefix
            # or a block-aligned sequence would share a garbage position.
            self.kv.finish(slot, req.prompt + req.generated[:-1])
        self.stats.requests_completed += 1
        self.stats.tokens_generated += len(req.generated)

    # --------------------------------------------------------------- handoff
    def _stage_ready(self, slot: int, now: float) -> None:
        """Prefill role: the slot's prompt is fully prefilled and its first
        token sampled — park it on ``ready`` for the coordinator.  The slot
        keeps its blocks pinned until :meth:`release_handoff`; the sequence
        was already published, so future same-prefix admissions hit it."""
        self.ready.append(slot)
        self.tracer.instant("prefill_done", self._slot_track(slot), now,
                            (("rid", self.requests[slot].rid),))

    def export_slot(self, slot: int):
        """Prefill role: run the export program for a ready slot, returning
        the suitcase (still on this engine's devices — the decode engine's
        :meth:`stage_in` moves it)."""
        req = self.requests[slot]
        trow = jnp.asarray(np.asarray(self.kv.table[slot], np.int32)) \
            if self.kv is not None else None
        with self._timed("handoff_export") as tm:
            out = self._export(self.states, jnp.asarray(slot, jnp.int32),
                               trow)
            tm.sync(out)
        st = self.stats
        st.handoffs += 1
        st.handoff_time_s += tm.dur
        self.programs.observe("export", tm.dur, phase="handoff",
                              program="_export")
        self.tracer.span("handoff_export", self._slot_track(slot),
                         tm.t0, tm.t1, (("rid", req.rid),))
        return out

    def release_handoff(self, slot: int) -> None:
        """Prefill role: the suitcase left — free the slot and its block
        references (the prefix tree keeps the published blocks cached)."""
        req = self.requests[slot]
        now = self.tracer.now()
        self.tracer.end(f"req {req.rid}", self._slot_track(slot), now,
                        (("rid", req.rid), ("handoff", 1)))
        self.requests[slot] = None
        if self.kv is not None:
            self.kv.release(slot)
        self._sync_kv_stats()

    def stage_in(self, suitcase):
        """Decode role: land a visiting suitcase on this engine's submesh,
        replicated — one fixed committed sharding, because the import
        program's jit cache keys on it, and this is the single transfer
        point warm and runtime suitcases share.  Meshless engines pass
        through untouched: a device_put would *commit* the arrays and split
        the cache key from the uncommitted warm path."""
        if self.mesh is None:
            return suitcase
        return jax.device_put(suitcase,
                              NamedSharding(self.mesh, PartitionSpec()))

    def adopt(self, req: Request, suitcase, n_tokens: int) -> int | None:
        """Decode role: admit a finished prefill from a peer engine — map a
        free slot, remap fresh blocks covering its ``n_tokens`` written
        positions (:meth:`PagedKVManager.adopt`), scatter the suitcase into
        them, and start decoding from ``req.generated[-1]``.  Returns the
        slot, or None — with no side effects beyond a stall counter — when
        no slot or no blocks are free (the coordinator retries next tick)."""
        free = [s for s in range(self.slots) if self.requests[s] is None]
        if not free:
            self.stats.handoff_stalls += 1
            return None
        slot = free[0]
        if self.kv is not None and not self.kv.adopt(slot, n_tokens):
            self.stats.handoff_stalls += 1
            return None
        trow = jnp.asarray(np.asarray(self.kv.table[slot], np.int32)) \
            if self.kv is not None else None
        with self._timed("handoff_import") as tm:
            self.states = self._import(self.states, suitcase,
                                       jnp.asarray(slot, jnp.int32), trow)
            tm.sync(self.states)
        st = self.stats
        st.handoffs += 1
        st.handoff_time_s += tm.dur
        self.programs.observe("import", tm.dur, phase="handoff",
                              program="_import")
        self.requests[slot] = req
        self.positions[slot] = n_tokens
        self._set_sampling(slot, req)
        now = tm.t1
        self.tracer.begin(f"req {req.rid}", self._slot_track(slot), now,
                          (("rid", req.rid),
                           ("prompt_tokens", len(req.prompt))))
        self.tracer.instant(
            "handoff", self._slot_track(slot), now,
            (("rid", req.rid), ("tokens", n_tokens),
             ("blocks", self.kv.owned[slot] if self.kv is not None else 0)))
        self._sync_kv_stats()
        return slot

    # ---------------------------------------------------------------- warmup
    def warmup(self) -> None:
        """Pre-compile every program the engine can ever run — all
        (batch-bucket, bucket) prefill shapes, the chunk-continuation program
        (when any admissible prompt is longer than the largest bucket, or a
        prefix cache can shortcut into it), the block-clone program (paged),
        and the decode program — then reset the pool.  After this, any trace
        triggers zero recompiles regardless of arrival pattern."""
        if self._queue or self._prefilling \
                or any(r is not None for r in self.requests):
            raise RuntimeError("warmup() requires an idle engine")
        zs = lambda n: (jnp.zeros((n,), jnp.float32),
                        jnp.zeros((n,), jnp.int32),
                        jnp.ones((n,), jnp.float32),
                        jnp.zeros((n,), jnp.int32))
        # every program registers its static cost (lowered-HLO FLOPs/bytes,
        # optionally compiled memory watermarks) immediately before its
        # warmup call — same args, so the registered shape IS the warmed one
        reg, mem = self.programs, self._program_memory
        with self._timed("warmup") as tm:
            if self.role != "decode":
                for b in self.buckets:
                    for nb in self.batch_buckets:
                        args = (self.params, jnp.zeros((nb, b), jnp.int32),
                                jnp.ones((nb,), jnp.int32),
                                jnp.asarray(np.arange(nb) % self.slots,
                                            np.int32),
                                self.states, self._warm_table(nb), *zs(nb))
                        reg.register(f"prefill[{nb}x{b}]", self._prefill,
                                     args, phase="prefill",
                                     program="_prefill", memory=mem)
                        _, self.states = self._prefill(*args)
                # chunk continuation: reachable for prompts beyond the
                # largest bucket, and (paged) for any prefix-cache hit
                if self.max_len - 1 > self.buckets[-1] \
                        or (self.kv is not None and self.kv.prefix_enabled):
                    args = (self.params,
                            jnp.zeros((1, self.prefill_chunk), jnp.int32),
                            jnp.asarray(0, jnp.int32),
                            jnp.asarray(1, jnp.int32),
                            jnp.asarray(0, jnp.int32), self.states,
                            self._warm_table(1), *zs(1))
                    reg.register("chunk", self._chunk, args, phase="prefill",
                                 program="_chunk", memory=mem)
                    _, self.states = self._chunk(*args)
                if self._copy is not None:
                    args = (self.states, jnp.asarray(0, jnp.int32),
                            jnp.asarray(0, jnp.int32))
                    reg.register("copy", self._copy, args, phase="kv",
                                 program="_copy", memory=mem)
                    self.states = self._copy(*args)
            if self.role != "prefill":
                args = (self.params, jnp.zeros((self.slots, 1), jnp.int32),
                        self.states, jnp.asarray(self.positions),
                        self.memory, jnp.zeros((self.slots,), bool),
                        self._warm_table(self.slots), *zs(self.slots))
                reg.register("decode", self._decode, args, phase="decode",
                             program="_decode", memory=mem)
                _, self.states = self._decode(*args)
            self._warm_handoff(reg, mem)
            self.states = self.model.init_states(
                self.slots, self.max_len, **self._state_kw,
                shardings=self._state_shardings)
            tm.sync(self.states)
        self.tracer.span("warmup", self._trk_engine, tm.t0, tm.t1)
        if self.kv is not None:
            # the device pool was just re-zeroed: drop every cached prefix
            # that described its old contents
            self.kv.clear()
        self.positions[:] = 0
        self._sync_compile_stats()
        tmp = self.programs.temp_bytes_peak()
        if tmp:
            self.stats.metrics.gauge("program_temp_bytes_peak",
                                     "bytes").set(tmp)
            if self.tracer.enabled:
                self.tracer.counter(self._ctr_prefix + "program_temp_bytes",
                                    tm.t1, (("peak", tmp),))

    def _warm_table(self, rows: int) -> jax.Array | None:
        """All-sentinel block tables: warmup calls drop every KV write."""
        if self.kv is None:
            return None
        return jnp.full((rows, self.kv.blocks_per_slot), self.kv.sentinel,
                        jnp.int32)

    def _warm_handoff(self, reg, mem) -> None:
        """Compile this role's half of the handoff pair.  The prefill side
        exports an idle slot through an all-sentinel table row.  The decode
        side builds a warm suitcase eagerly from its *own* idle states (the
        wire format's pytree structure depends on the model and blocks-per-
        slot only, both shared with the peer), stages it through the same
        :meth:`stage_in` path as runtime — the committed input sharding is
        part of the jit cache key — and imports against an all-sentinel
        destination row, so every paged write drops; the spliced garbage
        lands in slot 0 of states that warmup re-initializes right after."""
        wt = self._warm_table(1)
        trow = wt[0] if wt is not None else None
        if self._export is not None:
            args = (self.states, jnp.asarray(0, jnp.int32), trow)
            reg.register("export", self._export, args, phase="handoff",
                         program="_export", memory=mem)
            self._export(*args)
        if self._import is not None:
            suitcase = self.stage_in(self._export_slot(
                self.states, jnp.asarray(0, jnp.int32), trow))
            args = (self.states, suitcase, jnp.asarray(0, jnp.int32), trow)
            reg.register("import", self._import, args, phase="handoff",
                         program="_import", memory=mem)
            self.states = self._import(*args)

    # ---------------------------------------------------------------- decode
    def step(self) -> None:
        """One engine tick: advance each in-flight chunked prefill by one
        chunk, admit up to ``max_prefill_per_step`` queued requests, then
        advance every decoding slot by one lockstep decode step (dead and
        mid-prefill slots are frozen by the ``active`` mask).  Paged engines
        extend each slot's block table before the write and stall (freeze) a
        slot for the tick when the pool has no block for it."""
        t_tick = self.tracer.now()
        if self.role != "decode":
            for slot in list(self._prefilling):
                self._advance_chunk(slot)
            self._admit(self.max_prefill_per_step)
        busy = [i for i, r in enumerate(self.requests) if r is not None]
        # a prefill-role engine never decodes: ready slots wait for export
        active = [] if self.role == "prefill" \
            else [i for i in busy if i not in self._prefilling]
        if self.kv is not None and active:
            ok = []
            for i in active:
                # the write this tick lands at position[i]: the table must
                # cover position[i] + 1 tokens
                if self.kv.extend(i, int(self.positions[i]) + 1):
                    ok.append(i)
                else:
                    self.stats.decode_stalls += 1
                    self.tracer.instant(
                        "stall", self._slot_track(i), self.tracer.now(),
                        (("rid", self.requests[i].rid),))
            if not ok and not self._prefilling:
                # nothing can decode and nothing mid-prefill will retire:
                # no block can ever free — fail loudly instead of spinning
                raise RuntimeError(
                    f"KV pool exhausted: {self.kv.in_use} of "
                    f"{self.kv.pool.num_blocks} blocks referenced, every "
                    f"active slot stalled and nothing can retire — size the "
                    f"pool for at least one request's worst case "
                    f"(kv_blocks >= max_len / kv_block_size)")
            active = ok
        self.stats.ticks += 1
        self.stats.occupancy_sum += len(busy) / self.slots
        if not active:
            self._sync_compile_stats()
            self._sync_kv_stats()
            self.stats.kv_occupancy_sum += (
                self.kv.in_use / self.kv.pool.num_blocks
                if self.kv is not None else 0.0)
            now = self.tracer.now()
            self._tick_counters(now, len(busy))
            self.stats.wall_time_s += now - t_tick
            return
        toks = np.zeros((self.slots, 1), np.int32)
        mask = np.zeros((self.slots,), bool)
        for i in active:
            mask[i] = True
            toks[i, 0] = self.requests[i].generated[-1] \
                if self.requests[i].generated else self.requests[i].prompt[-1]
        bt, samp = self._decode_args()
        with self._timed("decode") as tm:
            nxt, self.states = self._decode(
                self.params, jnp.asarray(toks), self.states,
                jnp.asarray(self.positions), self.memory, jnp.asarray(mask),
                bt, *samp)
            nxt = tm.sync(nxt)               # device sync BEFORE the stamp
        nxt = np.asarray(nxt, np.int32)
        now = tm.t1
        self.stats.decode_steps += 1
        self.stats.decode_time_s += tm.dur
        self.programs.observe("decode", tm.dur, phase="decode",
                              program="_decode")
        self.stats.metrics.histogram("decode_tick_s").record(tm.dur)
        self.stats.metrics.histogram(
            "tokens_per_tick", base=1.0, unit="tokens").record(len(active))
        self.tracer.span("decode", self._trk_engine, tm.t0, tm.t1,
                         (("active", len(active)),))
        for i in active:
            req = self.requests[i]
            self.positions[i] += 1
            req.generated.append(int(nxt[i]))
            if (len(req.generated) >= req.max_new_tokens
                    or int(nxt[i]) == req.eos_id
                    or self.positions[i] >= self.max_len - 1):
                self._finish(i, now)
        self._sync_compile_stats()
        self._sync_kv_stats()
        self.stats.kv_occupancy_sum += (
            self.kv.in_use / self.kv.pool.num_blocks
            if self.kv is not None else 0.0)
        end = self.tracer.now()
        # time-between-tokens as a running slot experiences it: the whole
        # tick's wall, chunk-prefill and admission interference included —
        # on a dedicated decode submesh the tick carries only the decode
        # program, which is exactly the latency win the --disagg gate
        # measures (interleaved p99 carries chunk ticks; disagg p99 doesn't)
        self.stats.metrics.histogram("decode_tbt_s").record(end - t_tick)
        self._tick_counters(end, len([r for r in self.requests
                                      if r is not None]))
        # wall time accumulates per tick so tokens_per_s stays meaningful for
        # callers driving submit()+step() directly instead of run()
        self.stats.wall_time_s += end - t_tick

    def run(self, requests: list[Request], max_steps: int = 10_000,
            on_truncate: str = "warn") -> list[Request]:
        """Serve ``requests`` to completion (or ``max_steps`` ticks).

        ``on_truncate``: what to do when max_steps is exhausted with work
        still in flight — "warn" (default), "raise", or "ignore".  Survivors
        are always marked ``req.aborted`` and counted in
        ``stats.requests_aborted`` (a later run() that finishes them clears
        the flag)."""
        if on_truncate not in ("warn", "raise", "ignore"):
            raise ValueError(f"on_truncate {on_truncate!r} not in "
                             f"('warn', 'raise', 'ignore')")
        for r in requests:
            self.submit(r)
        steps = 0
        while (self._queue or any(r is not None for r in self.requests)) \
                and steps < max_steps:
            self.step()
            steps += 1
        leftovers = [r for r in self.requests if r is not None] \
            + list(self._queue)
        if leftovers:
            # count each distinct request once, even across repeated
            # truncated run() calls over the same survivors
            self.stats.requests_aborted += sum(
                1 for r in leftovers if not r.aborted)
            t_abort = self.tracer.now()
            for r in leftovers:
                if not r.aborted:
                    self.tracer.instant("abort", self._trk_req, t_abort,
                                        (("rid", r.rid),))
                r.aborted = True
            msg = (f"run() exhausted max_steps={max_steps} with "
                   f"{len(leftovers)} unfinished requests "
                   f"(rids {[r.rid for r in leftovers][:8]}...) — they remain "
                   f"queued/in-slot and are marked aborted")
            if on_truncate == "raise":
                raise RuntimeError(msg)
            if on_truncate == "warn":
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return requests


# --------------------------------------------------------- state pool surgery
def _is_paged(x) -> bool:
    return isinstance(x, PagedKVCache)


def _adopt_pool_kv(fresh, pool):
    """Swap the paged-KV leaves of a freshly initialized batch-N state tree
    for the live pool's (the block arrays are global — the fresh zeros are
    dead code the compiler drops); everything else keeps its fresh batch-N
    leaves.  ``fresh.length`` (zeros) is kept: prefill rows start empty."""
    def pick(f, p):
        if _is_paged(f):
            return PagedKVCache(p.k, p.v, f.length)
        return f
    return jax.tree.map(pick, fresh, pool, is_leaf=_is_paged)


def _state_row(states, i: int):
    """Batch-1 view of row ``i`` (a static index) of a batch-N state tree.
    Batch is the first axis for tail states, the second for stacked
    (scan-group) states.  Paged KV leaves have no batch axis on k/v (they're
    the global pool) — only their per-slot ``length`` is sliced."""
    def grp(a):
        return a._replace(length=a.length[:, i:i + 1]) if _is_paged(a) \
            else a[:, i:i + 1]

    def tail(a):
        return a._replace(length=a.length[i:i + 1]) if _is_paged(a) \
            else a[i:i + 1]

    return {"groups": jax.tree.map(grp, states["groups"], is_leaf=_is_paged),
            "tail": jax.tree.map(tail, states["tail"], is_leaf=_is_paged)}


def _gather_slot(pool_states, slot):
    """Batch-1 copy of slot ``slot`` (may be a traced scalar) of the pool."""

    def tail(a):
        if _is_paged(a):
            return a._replace(
                length=jax.lax.dynamic_slice(a.length, (slot,), (1,)))
        return jax.lax.dynamic_slice(
            a, (slot,) + (0,) * (a.ndim - 1), (1,) + a.shape[1:])

    def grp(a):
        if _is_paged(a):
            return a._replace(length=jax.lax.dynamic_slice(
                a.length, (0, slot), (a.length.shape[0], 1)))
        return jax.lax.dynamic_slice(
            a, (0, slot) + (0,) * (a.ndim - 2),
            (a.shape[0], 1) + a.shape[2:])

    return {"groups": jax.tree.map(grp, pool_states["groups"],
                                   is_leaf=_is_paged),
            "tail": jax.tree.map(tail, pool_states["tail"],
                                 is_leaf=_is_paged)}


def _splice_states(pool_states, one_states, slot):
    """Write batch-1 `one_states` into slot `slot` of the pooled states.
    Batch is the first axis for tail states and the second for stacked
    (scan-group) states.  ``slot`` may be a traced scalar.  Paged KV leaves
    carry the updated global pool in k/v (taken wholesale) and a per-slot
    length (spliced)."""

    def splice(pool, new):
        if _is_paged(pool):
            return PagedKVCache(new.k, new.v, jax.lax.dynamic_update_slice(
                pool.length, new.length.astype(pool.length.dtype), (slot,)))
        if pool.ndim == new.ndim:          # tail state: batch axis 0
            return jax.lax.dynamic_update_slice(
                pool, new.astype(pool.dtype),
                (slot,) + (0,) * (pool.ndim - 1))
        raise ValueError((pool.shape, new.shape))

    def splice_stacked(pool, new):
        if _is_paged(pool):
            return PagedKVCache(new.k, new.v, jax.lax.dynamic_update_slice(
                pool.length, new.length.astype(pool.length.dtype), (0, slot)))
        # pool: (G, B, ...), new: (G, 1, ...)
        return jax.lax.dynamic_update_slice(
            pool, new.astype(pool.dtype),
            (0, slot) + (0,) * (pool.ndim - 2))

    out_groups = jax.tree.map(splice_stacked, pool_states["groups"],
                              one_states["groups"], is_leaf=_is_paged)
    out_tail = jax.tree.map(splice, pool_states["tail"], one_states["tail"],
                            is_leaf=_is_paged)
    return {"groups": out_groups, "tail": out_tail}
