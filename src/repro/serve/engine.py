"""Batched serving engine: continuous batching over a fixed slot pool.

The engine owns per-slot KV/recurrent state; requests are admitted into free
slots, prefilled, then advanced in lockstep decode steps.  Finished slots
(EOS or max_tokens) are evicted and refilled — the standard continuous-
batching pattern (vLLM-style), with a static slot count so every jitted shape
is fixed.

Prefill is *bucketed, batched, and jitted*: prompts are right-padded to a
small set of power-of-two buckets, same-bucket admissions in one tick are
stacked into one ``(N, bucket)`` prefill program (N itself bucketed to powers
of two up to ``max_prefill_batch``), and the padded prefill + splice-into-
slots runs as one compiled call — lengths and target slots are traced, so the
program inventory is exactly |buckets| x |batch buckets|.

Prompts longer than the largest bucket take the *chunked* path: the prompt is
split into ``prefill_chunk``-wide pieces that run one per tick, interleaved
with decode steps, each resuming from the slot's spliced state (cache
continuation for causal/sliding-window attention, conv + RG-LRU/SSM carry for
the recurrent families).  Decode latency for already-running slots therefore
stays bounded by one chunk, not one full long prompt.

``step`` interleaves work per tick — in-flight chunks advance, then at most
``max_prefill_per_step`` admissions, then one lockstep decode step whose
``active`` mask freezes dead and mid-prefill slots bit-for-bit.

Per the Mensa reading: prefill steps are compute-centric (Pascal cluster) and
decode steps memory-centric (Jacquard/Pavlov clusters); the engine keeps them
as separate jitted programs so each lowers with its own strategy — pass
``prefill_model`` / ``decode_model`` built from per-phase
``core.executor.execution_profile`` overrides to specialize each program.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Model

# TTFT samples kept for windowed percentiles (mean/max stay exact streaming)
TTFT_WINDOW = 8192


# ------------------------------------------------------------------- buckets
def prefill_buckets(max_len: int, min_bucket: int = 16) -> tuple[int, ...]:
    """Power-of-two prompt buckets up to max_len: one compile per bucket.
    When max_len is not itself a power of two, a final max_len-sized bucket
    covers the gap so no prompt below the cache size is rejected."""
    out = []
    b = min_bucket
    while b <= max_len:
        out.append(b)
        b *= 2
    if not out:
        raise ValueError(f"max_len {max_len} < min_bucket {min_bucket}")
    if out[-1] < max_len:
        out.append(max_len)
    return tuple(out)


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket that fits an n-token prompt."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"prompt length {n} exceeds largest bucket {buckets[-1]}")


# --------------------------------------------------------------------- stats
@dataclass
class EngineStats:
    """Engine-side serving metrics, accumulated across ticks."""
    requests_completed: int = 0
    requests_aborted: int = 0           # unfinished when run() hit max_steps
    tokens_generated: int = 0
    prefills: int = 0                   # requests prefilled (all paths)
    prefills_chunked: int = 0           # requests prefilled via the chunked path
    prefill_calls: int = 0              # compiled batched-prefill invocations
    prefill_chunks: int = 0             # chunk-continuation invocations
    prefill_prompt_tokens: int = 0
    prefill_padded_tokens: int = 0
    prefill_time_s: float = 0.0
    decode_steps: int = 0
    decode_time_s: float = 0.0
    # TTFT: count/sum/max are exact streaming aggregates; ttft_s keeps only
    # the most recent TTFT_WINDOW..2*TTFT_WINDOW samples so percentiles are
    # *windowed* (recent-traffic) on long-lived engines, never silently biased
    ttft_s: list = field(default_factory=list)
    ttft_count: int = 0
    ttft_sum: float = 0.0
    ttft_max: float = 0.0
    occupancy_sum: float = 0.0          # sum over ticks of busy/slots
    ticks: int = 0
    bucket_counts: dict = field(default_factory=dict)
    batch_counts: dict = field(default_factory=dict)   # rows per prefill call
    prefill_compiles: int = 0           # jit cache entries (incl. chunk prog)
    decode_compiles: int = 0
    wall_time_s: float = 0.0

    def record_ttft(self, v: float) -> None:
        self.ttft_count += 1
        self.ttft_sum += v
        if v > self.ttft_max:
            self.ttft_max = v
        self.ttft_s.append(v)
        if len(self.ttft_s) >= 2 * TTFT_WINDOW:        # amortized O(1) trim
            del self.ttft_s[:len(self.ttft_s) - TTFT_WINDOW]

    def summary(self) -> dict:
        dec_ms = 1e3 * self.decode_time_s / max(self.decode_steps, 1)
        return {
            "requests_completed": self.requests_completed,
            "requests_aborted": self.requests_aborted,
            "tokens_generated": self.tokens_generated,
            "tokens_per_s": self.tokens_generated / self.wall_time_s
            if self.wall_time_s else 0.0,
            "ttft_ms": {
                "mean": 1e3 * self.ttft_sum / self.ttft_count
                if self.ttft_count else 0.0,           # exact
                "p50": 1e3 * float(np.median(self.ttft_s))
                if self.ttft_s else 0.0,               # windowed
                "max": 1e3 * self.ttft_max,            # exact
            },
            "decode_step_ms": dec_ms,
            "decode_steps": self.decode_steps,
            "prefills": self.prefills,
            "prefills_chunked": self.prefills_chunked,
            "prefill_calls": self.prefill_calls,
            "prefill_chunks": self.prefill_chunks,
            "prefill_time_s": self.prefill_time_s,
            "prefill_padding_overhead": (
                self.prefill_padded_tokens / self.prefill_prompt_tokens - 1.0
                if self.prefill_prompt_tokens else 0.0),
            "bucket_counts": dict(self.bucket_counts),
            "prefill_batch_counts": dict(self.batch_counts),
            "slot_occupancy": self.occupancy_sum / max(self.ticks, 1),
            "prefill_compiles": self.prefill_compiles,
            "decode_compiles": self.decode_compiles,
            "wall_time_s": self.wall_time_s,
        }


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int = -1
    generated: list[int] = field(default_factory=list)
    done: bool = False
    aborted: bool = False               # unfinished when run() gave up
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 512, greedy: bool = True,
                 buckets: tuple[int, ...] | None = None,
                 min_bucket: int = 16,
                 max_prefill_per_step: int = 1,
                 max_prefill_batch: int = 4,
                 prefill_chunk: int | None = None,
                 prefill_model: Model | None = None,
                 decode_model: Model | None = None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        if not greedy:
            raise NotImplementedError(
                "non-greedy sampling is not implemented yet (ROADMAP item); "
                "both compiled paths take argmax")
        self.greedy = greedy
        self.buckets = tuple(sorted(buckets)) if buckets \
            else prefill_buckets(max_len, min_bucket)
        if self.buckets[-1] > max_len:
            raise ValueError(f"bucket {self.buckets[-1]} > max_len {max_len}")
        self.max_prefill_per_step = max(1, max_prefill_per_step)
        # batch-bucket the admission group size so the compiled-program
        # inventory stays |buckets| x |batch_buckets|, not one per group size
        self.max_prefill_batch = max(1, min(max_prefill_batch, slots))
        self.batch_buckets = prefill_buckets(self.max_prefill_batch,
                                             min_bucket=1)
        self.prefill_chunk = int(prefill_chunk) if prefill_chunk \
            else self.buckets[-1]
        if not 1 <= self.prefill_chunk <= max_len:
            raise ValueError(f"prefill_chunk {self.prefill_chunk} outside "
                             f"[1, max_len {max_len}]")
        # per-phase programs (Mensa: compute-centric prefill vs memory-centric
        # decode lower as separate jitted functions)
        self.prefill_model = prefill_model or model
        self.decode_model = decode_model or model
        self.states = model.init_states(slots, max_len)
        self.memory = None
        self.requests: list[Request | None] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        # donate the pool state: every program updates slots in place instead
        # of copying the whole pool each call
        self._decode = jax.jit(self.decode_model.decode_step,
                               donate_argnums=(2,))
        self._prefill = jax.jit(self._prefill_and_splice,
                                donate_argnums=(4,))
        self._chunk = jax.jit(self._chunk_and_splice, donate_argnums=(5,))
        self._queue: deque[Request] = deque()
        self._prefilling: dict[int, int] = {}   # slot -> prompt tokens consumed
        self.stats = EngineStats()

    def reset_stats(self) -> None:
        self.stats = EngineStats()
        self._sync_compile_stats()

    def _sync_compile_stats(self) -> None:
        # _cache_size is a private jit attribute; degrade stats (not serving)
        # if a JAX upgrade drops it
        def size(fn):
            return getattr(fn, "_cache_size", lambda: 0)()
        self.stats.prefill_compiles = size(self._prefill) + size(self._chunk)
        self.stats.decode_compiles = size(self._decode)

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError("empty prompt: nothing to condition on")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "samples the first token)")
        if len(req.prompt) > self.max_len - 1:
            # a max_len-token prompt fills the cache completely: the first
            # decode write would land past the last slot and be dropped
            raise ValueError(f"prompt length {len(req.prompt)} leaves no "
                             f"cache room to decode (max_len {self.max_len})")
        req.t_submit = time.perf_counter()
        self._queue.append(req)

    def _admit(self, budget: int) -> int:
        free = [s for s in range(self.slots) if self.requests[s] is None]
        take = min(budget, len(free), len(self._queue))
        if take <= 0:
            return 0
        groups: dict[int, list[tuple[int, Request]]] = {}
        for _ in range(take):
            req = self._queue.popleft()
            slot = free.pop(0)
            self.requests[slot] = req
            if len(req.prompt) > self.buckets[-1]:
                # long prompt: chunked path — first chunk runs this tick,
                # the rest advance one per tick interleaved with decode
                self._prefilling[slot] = 0
                self._advance_chunk(slot)
            else:
                b = bucket_for(len(req.prompt), self.buckets)
                groups.setdefault(b, []).append((slot, req))
        for b in sorted(groups):
            members = groups[b]
            for i in range(0, len(members), self.max_prefill_batch):
                self._prefill_group(b, members[i:i + self.max_prefill_batch])
        return take

    def _prefill_and_splice(self, params, tokens, lengths, slot_ids,
                            pool_states):
        """One compiled program per (batch-bucket, bucket) shape: padded
        (N, bucket) prefill, splice each row into the pool at ``slot_ids[i]``,
        return the N first sampled tokens.  Padding rows (group smaller than
        the batch bucket) carry slot_ids[0]; rows splice in REVERSE order so
        the real row that shares a padding row's target lands last and wins."""
        n = tokens.shape[0]
        states_n = self.prefill_model.init_states(n, self.max_len)
        logits, states_n, _ = self.prefill_model.prefill(
            params, tokens, states_n, length=lengths)
        for i in reversed(range(n)):
            row = _state_row(states_n, i)
            pool_states = _splice_states(pool_states, row, slot_ids[i])
        return jnp.argmax(logits[:, 0], axis=-1), pool_states

    def _chunk_and_splice(self, params, tokens, offset, length, slot,
                          pool_states):
        """One compiled program for every chunk of every long prompt: gather
        the slot's state, resume prefill at ``offset`` with the (1, C) chunk,
        splice back, return the sampled token (meaningful on the final chunk
        only)."""
        row = _gather_slot(pool_states, slot)
        logits, row, _ = self.prefill_model.prefill(
            params, tokens, row, length=length[None], offset=offset[None])
        pool = _splice_states(pool_states, row, slot)
        return jnp.argmax(logits[0, -1]), pool

    def _prefill_group(self, bucket: int, members: list) -> None:
        n = len(members)
        nb = bucket_for(n, self.batch_buckets)
        toks = np.zeros((nb, bucket), np.int32)
        lens = np.ones((nb,), np.int32)
        slot_ids = np.full((nb,), members[0][0], np.int32)
        for i, (slot, req) in enumerate(members):
            toks[i, :len(req.prompt)] = req.prompt
            lens[i] = len(req.prompt)
            slot_ids[i] = slot
        t0 = time.perf_counter()
        first, self.states = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(lens),
            jnp.asarray(slot_ids), self.states)
        first = np.asarray(first)            # blocks until the result is ready
        now = time.perf_counter()
        st = self.stats
        st.prefill_calls += 1
        st.prefill_time_s += now - t0
        st.batch_counts[n] = st.batch_counts.get(n, 0) + 1
        for i, (slot, req) in enumerate(members):
            tok = int(first[i])
            self.positions[slot] = len(req.prompt)
            req.generated.append(tok)
            req.t_first_token = now
            st.prefills += 1
            st.prefill_prompt_tokens += len(req.prompt)
            st.prefill_padded_tokens += bucket
            st.record_ttft(now - req.t_submit)
            st.bucket_counts[bucket] = st.bucket_counts.get(bucket, 0) + 1
            if len(req.generated) >= req.max_new_tokens or tok == req.eos_id:
                self._finish(slot, now)

    def _advance_chunk(self, slot: int) -> None:
        req = self.requests[slot]
        off = self._prefilling[slot]
        c = self.prefill_chunk
        piece = req.prompt[off:off + c]
        n = len(piece)
        toks = np.zeros((1, c), np.int32)
        toks[0, :n] = piece
        t0 = time.perf_counter()
        tok, self.states = self._chunk(
            self.params, jnp.asarray(toks), jnp.asarray(off, jnp.int32),
            jnp.asarray(n, jnp.int32), jnp.asarray(slot, jnp.int32),
            self.states)
        st = self.stats
        st.prefill_chunks += 1
        st.prefill_padded_tokens += c
        if off + n < len(req.prompt):
            # intermediate chunk: don't block on the (unused) token — let the
            # dispatch overlap with this tick's decode step
            self._prefilling[slot] = off + n
            st.prefill_time_s += time.perf_counter() - t0
            return
        tok = int(tok)                       # final chunk: sample first token
        now = time.perf_counter()
        st.prefill_time_s += now - t0
        del self._prefilling[slot]
        self.positions[slot] = len(req.prompt)
        req.generated.append(tok)
        req.t_first_token = now
        st.prefills += 1
        st.prefills_chunked += 1
        st.prefill_prompt_tokens += len(req.prompt)
        st.record_ttft(now - req.t_submit)
        if len(req.generated) >= req.max_new_tokens or tok == req.eos_id:
            self._finish(slot, now)

    def _finish(self, slot: int, now: float) -> None:
        req = self.requests[slot]
        req.done = True
        req.aborted = False
        req.t_done = now
        self.requests[slot] = None
        self.stats.requests_completed += 1
        self.stats.tokens_generated += len(req.generated)

    # ---------------------------------------------------------------- warmup
    def warmup(self) -> None:
        """Pre-compile every program the engine can ever run — all
        (batch-bucket, bucket) prefill shapes, the chunk-continuation program
        (when any admissible prompt is longer than the largest bucket), and
        the decode program — then reset the pool.  After this, any trace
        triggers zero recompiles regardless of arrival pattern."""
        if self._queue or self._prefilling \
                or any(r is not None for r in self.requests):
            raise RuntimeError("warmup() requires an idle engine")
        for b in self.buckets:
            for nb in self.batch_buckets:
                _, self.states = self._prefill(
                    self.params, jnp.zeros((nb, b), jnp.int32),
                    jnp.ones((nb,), jnp.int32),
                    jnp.asarray(np.arange(nb) % self.slots, np.int32),
                    self.states)
        if self.max_len - 1 > self.buckets[-1]:
            _, self.states = self._chunk(
                self.params, jnp.zeros((1, self.prefill_chunk), jnp.int32),
                jnp.asarray(0, jnp.int32), jnp.asarray(1, jnp.int32),
                jnp.asarray(0, jnp.int32), self.states)
        _, self.states = self._decode(
            self.params, jnp.zeros((self.slots, 1), jnp.int32), self.states,
            jnp.asarray(self.positions), self.memory,
            jnp.zeros((self.slots,), bool))
        self.states = self.model.init_states(self.slots, self.max_len)
        self.positions[:] = 0
        self._sync_compile_stats()

    # ---------------------------------------------------------------- decode
    def step(self) -> None:
        """One engine tick: advance each in-flight chunked prefill by one
        chunk, admit up to ``max_prefill_per_step`` queued requests, then
        advance every decoding slot by one lockstep decode step (dead and
        mid-prefill slots are frozen by the ``active`` mask)."""
        t_tick = time.perf_counter()
        for slot in list(self._prefilling):
            self._advance_chunk(slot)
        self._admit(self.max_prefill_per_step)
        busy = [i for i, r in enumerate(self.requests) if r is not None]
        active = [i for i in busy if i not in self._prefilling]
        self.stats.ticks += 1
        self.stats.occupancy_sum += len(busy) / self.slots
        if not active:
            self._sync_compile_stats()
            self.stats.wall_time_s += time.perf_counter() - t_tick
            return
        toks = np.zeros((self.slots, 1), np.int32)
        mask = np.zeros((self.slots,), bool)
        for i in active:
            mask[i] = True
            toks[i, 0] = self.requests[i].generated[-1] \
                if self.requests[i].generated else self.requests[i].prompt[-1]
        t0 = time.perf_counter()
        logits, self.states = self._decode(
            self.params, jnp.asarray(toks), self.states,
            jnp.asarray(self.positions), self.memory, jnp.asarray(mask))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        now = time.perf_counter()
        self.stats.decode_steps += 1
        self.stats.decode_time_s += now - t0
        for i in active:
            req = self.requests[i]
            self.positions[i] += 1
            req.generated.append(int(nxt[i]))
            if (len(req.generated) >= req.max_new_tokens
                    or int(nxt[i]) == req.eos_id
                    or self.positions[i] >= self.max_len - 1):
                self._finish(i, now)
        self._sync_compile_stats()
        # wall time accumulates per tick so tokens_per_s stays meaningful for
        # callers driving submit()+step() directly instead of run()
        self.stats.wall_time_s += time.perf_counter() - t_tick

    def run(self, requests: list[Request], max_steps: int = 10_000,
            on_truncate: str = "warn") -> list[Request]:
        """Serve ``requests`` to completion (or ``max_steps`` ticks).

        ``on_truncate``: what to do when max_steps is exhausted with work
        still in flight — "warn" (default), "raise", or "ignore".  Survivors
        are always marked ``req.aborted`` and counted in
        ``stats.requests_aborted`` (a later run() that finishes them clears
        the flag)."""
        if on_truncate not in ("warn", "raise", "ignore"):
            raise ValueError(f"on_truncate {on_truncate!r} not in "
                             f"('warn', 'raise', 'ignore')")
        for r in requests:
            self.submit(r)
        steps = 0
        while (self._queue or any(r is not None for r in self.requests)) \
                and steps < max_steps:
            self.step()
            steps += 1
        leftovers = [r for r in self.requests if r is not None] \
            + list(self._queue)
        if leftovers:
            # count each distinct request once, even across repeated
            # truncated run() calls over the same survivors
            self.stats.requests_aborted += sum(
                1 for r in leftovers if not r.aborted)
            for r in leftovers:
                r.aborted = True
            msg = (f"run() exhausted max_steps={max_steps} with "
                   f"{len(leftovers)} unfinished requests "
                   f"(rids {[r.rid for r in leftovers][:8]}...) — they remain "
                   f"queued/in-slot and are marked aborted")
            if on_truncate == "raise":
                raise RuntimeError(msg)
            if on_truncate == "warn":
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return requests


# --------------------------------------------------------- state pool surgery
def _state_row(states, i: int):
    """Batch-1 view of row ``i`` (a static index) of a batch-N state tree.
    Batch is the first axis for tail states, the second for stacked
    (scan-group) states."""
    return {"groups": jax.tree.map(lambda a: a[:, i:i + 1], states["groups"]),
            "tail": jax.tree.map(lambda a: a[i:i + 1], states["tail"])}


def _gather_slot(pool_states, slot):
    """Batch-1 copy of slot ``slot`` (may be a traced scalar) of the pool."""

    def tail(a):
        return jax.lax.dynamic_slice(
            a, (slot,) + (0,) * (a.ndim - 1), (1,) + a.shape[1:])

    def grp(a):
        return jax.lax.dynamic_slice(
            a, (0, slot) + (0,) * (a.ndim - 2), (a.shape[0], 1) + a.shape[2:])

    return {"groups": jax.tree.map(grp, pool_states["groups"]),
            "tail": jax.tree.map(tail, pool_states["tail"])}


def _splice_states(pool_states, one_states, slot):
    """Write batch-1 `one_states` into slot `slot` of the pooled states.
    Batch is the first axis for tail states and the second for stacked
    (scan-group) states.  ``slot`` may be a traced scalar."""

    def splice(pool, new):
        if pool.ndim == new.ndim:          # tail state: batch axis 0
            return jax.lax.dynamic_update_slice(
                pool, new.astype(pool.dtype),
                (slot,) + (0,) * (pool.ndim - 1))
        raise ValueError((pool.shape, new.shape))

    def splice_stacked(pool, new):
        # pool: (G, B, ...), new: (G, 1, ...)
        return jax.lax.dynamic_update_slice(
            pool, new.astype(pool.dtype),
            (0, slot) + (0,) * (pool.ndim - 2))

    out_groups = jax.tree.map(splice_stacked, pool_states["groups"],
                              one_states["groups"])
    out_tail = jax.tree.map(splice, pool_states["tail"], one_states["tail"])
    return {"groups": out_groups, "tail": out_tail}
