"""Batched serving engine: continuous batching over a fixed slot pool.

The engine owns per-slot KV/recurrent state; requests are admitted into free
slots, prefilled, then advanced in lockstep decode steps.  Finished slots
(EOS or max_tokens) are evicted and refilled — the standard continuous-
batching pattern (vLLM-style), with a static slot count so every jitted shape
is fixed.

Prefill is *bucketed and jitted*: prompts are right-padded to a small set of
power-of-two buckets so each bucket compiles exactly once, and the padded
prefill + splice-into-slot runs as one compiled program (prompt length and
target slot are traced scalars, so neither triggers recompilation).  ``step``
interleaves work per tick — at most ``max_prefill_per_step`` admissions
before each lockstep decode step — so a burst of arrivals no longer stalls
every decoding slot behind a wall of prefills.

Per the Mensa reading: prefill steps are compute-centric (Pascal cluster) and
decode steps memory-centric (Jacquard/Pavlov clusters); the engine keeps them
as separate jitted programs so each lowers with its own strategy — pass
``prefill_model`` / ``decode_model`` built from per-phase
``core.executor.execution_profile`` overrides to specialize each program.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Model


# ------------------------------------------------------------------- buckets
def prefill_buckets(max_len: int, min_bucket: int = 16) -> tuple[int, ...]:
    """Power-of-two prompt buckets up to max_len: one compile per bucket.
    When max_len is not itself a power of two, a final max_len-sized bucket
    covers the gap so no prompt below the cache size is rejected."""
    out = []
    b = min_bucket
    while b <= max_len:
        out.append(b)
        b *= 2
    if not out:
        raise ValueError(f"max_len {max_len} < min_bucket {min_bucket}")
    if out[-1] < max_len:
        out.append(max_len)
    return tuple(out)


def bucket_for(n: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket that fits an n-token prompt."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"prompt length {n} exceeds largest bucket {buckets[-1]}")


# --------------------------------------------------------------------- stats
@dataclass
class EngineStats:
    """Engine-side serving metrics, accumulated across ticks."""
    requests_completed: int = 0
    tokens_generated: int = 0
    prefills: int = 0
    prefill_prompt_tokens: int = 0
    prefill_padded_tokens: int = 0
    prefill_time_s: float = 0.0
    decode_steps: int = 0
    decode_time_s: float = 0.0
    ttft_s: list = field(default_factory=list)
    occupancy_sum: float = 0.0          # sum over ticks of active/slots
    ticks: int = 0
    bucket_counts: dict = field(default_factory=dict)
    prefill_compiles: int = 0           # jit cache entries (== buckets seen)
    decode_compiles: int = 0
    wall_time_s: float = 0.0

    def summary(self) -> dict:
        ttft = sorted(self.ttft_s)
        dec_ms = 1e3 * self.decode_time_s / max(self.decode_steps, 1)
        return {
            "requests_completed": self.requests_completed,
            "tokens_generated": self.tokens_generated,
            "tokens_per_s": self.tokens_generated / self.wall_time_s
            if self.wall_time_s else 0.0,
            "ttft_ms": {
                "mean": 1e3 * float(np.mean(ttft)) if ttft else 0.0,
                "p50": 1e3 * ttft[len(ttft) // 2] if ttft else 0.0,
                "max": 1e3 * ttft[-1] if ttft else 0.0,
            },
            "decode_step_ms": dec_ms,
            "decode_steps": self.decode_steps,
            "prefills": self.prefills,
            "prefill_time_s": self.prefill_time_s,
            "prefill_padding_overhead": (
                self.prefill_padded_tokens / self.prefill_prompt_tokens - 1.0
                if self.prefill_prompt_tokens else 0.0),
            "bucket_counts": dict(self.bucket_counts),
            "slot_occupancy": self.occupancy_sum / max(self.ticks, 1),
            "prefill_compiles": self.prefill_compiles,
            "decode_compiles": self.decode_compiles,
            "wall_time_s": self.wall_time_s,
        }


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int = -1
    generated: list[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 512, greedy: bool = True,
                 buckets: tuple[int, ...] | None = None,
                 min_bucket: int = 16,
                 max_prefill_per_step: int = 1,
                 prefill_model: Model | None = None,
                 decode_model: Model | None = None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        if not greedy:
            raise NotImplementedError(
                "non-greedy sampling is not implemented yet (ROADMAP item); "
                "both compiled paths take argmax")
        self.greedy = greedy
        self.buckets = tuple(sorted(buckets)) if buckets \
            else prefill_buckets(max_len, min_bucket)
        if self.buckets[-1] > max_len:
            raise ValueError(f"bucket {self.buckets[-1]} > max_len {max_len}")
        self.max_prefill_per_step = max(1, max_prefill_per_step)
        # per-phase programs (Mensa: compute-centric prefill vs memory-centric
        # decode lower as separate jitted functions)
        self.prefill_model = prefill_model or model
        self.decode_model = decode_model or model
        self.states = model.init_states(slots, max_len)
        self.memory = None
        self.requests: list[Request | None] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        # donate the pool state: both programs update one slot (prefill) or
        # append one token per slot (decode) — in-place instead of copying
        # the whole pool each call
        self._decode = jax.jit(self.decode_model.decode_step,
                               donate_argnums=(2,))
        self._prefill = jax.jit(self._prefill_and_splice,
                                donate_argnums=(4,))
        self._queue: list[Request] = []
        self.stats = EngineStats()

    def reset_stats(self) -> None:
        self.stats = EngineStats()
        self._sync_compile_stats()

    def _sync_compile_stats(self) -> None:
        # _cache_size is a private jit attribute; degrade stats (not serving)
        # if a JAX upgrade drops it
        self.stats.prefill_compiles = getattr(
            self._prefill, "_cache_size", lambda: 0)()
        self.stats.decode_compiles = getattr(
            self._decode, "_cache_size", lambda: 0)()

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError("empty prompt: nothing to condition on")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (prefill always "
                             "samples the first token)")
        if len(req.prompt) > self.max_len - 1:
            # a max_len-token prompt fills the cache completely: the first
            # decode write would land past the last slot and be dropped
            raise ValueError(f"prompt length {len(req.prompt)} leaves no "
                             f"cache room to decode (max_len {self.max_len})")
        bucket_for(len(req.prompt), self.buckets)   # validate it fits
        req.t_submit = time.perf_counter()
        self._queue.append(req)

    def _admit(self, budget: int) -> int:
        admitted = 0
        for slot in range(self.slots):
            if admitted >= budget or not self._queue:
                break
            if self.requests[slot] is None:
                req = self._queue.pop(0)
                self.requests[slot] = req
                self._prefill_slot(slot, req)
                admitted += 1
        return admitted

    def _prefill_and_splice(self, params, tokens, length, slot, pool_states):
        """One compiled program per bucket shape: padded batch-1 prefill,
        splice into the pool at ``slot``, return the first sampled token."""
        states1 = self.prefill_model.init_states(1, self.max_len)
        logits, states1, _ = self.prefill_model.prefill(
            params, tokens, states1, length=length[None])
        pool = _splice_states(pool_states, states1, slot)
        return jnp.argmax(logits[0, -1]), pool

    def _prefill_slot(self, slot: int, req: Request) -> None:
        n = len(req.prompt)
        bucket = bucket_for(n, self.buckets)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.prompt
        t0 = time.perf_counter()
        tok, self.states = self._prefill(
            self.params, jnp.asarray(toks),
            jnp.asarray(n, jnp.int32), jnp.asarray(slot, jnp.int32),
            self.states)
        tok = int(tok)                       # blocks until the result is ready
        now = time.perf_counter()
        self.positions[slot] = n
        req.generated.append(tok)
        req.t_first_token = now
        st = self.stats
        st.prefills += 1
        st.prefill_prompt_tokens += n
        st.prefill_padded_tokens += bucket
        st.prefill_time_s += now - t0
        st.ttft_s.append(now - req.t_submit)
        if len(st.ttft_s) > 20_000:           # bound memory on long-lived engines
            del st.ttft_s[:10_000]
        st.bucket_counts[bucket] = st.bucket_counts.get(bucket, 0) + 1
        if len(req.generated) >= req.max_new_tokens or tok == req.eos_id:
            self._finish(slot, now)

    def _finish(self, slot: int, now: float) -> None:
        req = self.requests[slot]
        req.done = True
        req.t_done = now
        self.requests[slot] = None
        self.stats.requests_completed += 1
        self.stats.tokens_generated += len(req.generated)

    # ---------------------------------------------------------------- decode
    def step(self) -> None:
        """One engine tick: admit up to ``max_prefill_per_step`` queued
        requests, then advance every active slot by one decode step."""
        t_tick = time.perf_counter()
        self._admit(self.max_prefill_per_step)
        active = [i for i, r in enumerate(self.requests) if r is not None]
        self.stats.ticks += 1
        self.stats.occupancy_sum += len(active) / self.slots
        if not active:
            self._sync_compile_stats()
            self.stats.wall_time_s += time.perf_counter() - t_tick
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for i in active:
            toks[i, 0] = self.requests[i].generated[-1] \
                if self.requests[i].generated else self.requests[i].prompt[-1]
        t0 = time.perf_counter()
        logits, self.states = self._decode(
            self.params, jnp.asarray(toks), self.states,
            jnp.asarray(self.positions), self.memory)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        now = time.perf_counter()
        self.stats.decode_steps += 1
        self.stats.decode_time_s += now - t0
        for i in active:
            req = self.requests[i]
            self.positions[i] += 1
            req.generated.append(int(nxt[i]))
            if (len(req.generated) >= req.max_new_tokens
                    or int(nxt[i]) == req.eos_id
                    or self.positions[i] >= self.max_len - 1):
                self._finish(i, now)
        self._sync_compile_stats()
        # wall time accumulates per tick so tokens_per_s stays meaningful for
        # callers driving submit()+step() directly instead of run()
        self.stats.wall_time_s += time.perf_counter() - t_tick

    def run(self, requests: list[Request], max_steps: int = 10_000
            ) -> list[Request]:
        for r in requests:
            self.submit(r)
        steps = 0
        while (self._queue or any(r is not None for r in self.requests)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return requests


def _splice_states(pool_states, one_states, slot):
    """Write batch-1 `one_states` into slot `slot` of the pooled states.
    Batch is the first axis for tail states and the second for stacked
    (scan-group) states.  ``slot`` may be a traced scalar."""

    def splice(pool, new):
        if pool.ndim == new.ndim:          # tail state: batch axis 0
            return jax.lax.dynamic_update_slice(
                pool, new.astype(pool.dtype),
                (slot,) + (0,) * (pool.ndim - 1))
        raise ValueError((pool.shape, new.shape))

    def splice_stacked(pool, new):
        # pool: (G, B, ...), new: (G, 1, ...)
        return jax.lax.dynamic_update_slice(
            pool, new.astype(pool.dtype),
            (0, slot) + (0,) * (pool.ndim - 2))

    out_groups = jax.tree.map(splice_stacked, pool_states["groups"],
                              one_states["groups"])
    out_tail = jax.tree.map(splice, pool_states["tail"], one_states["tail"])
    return {"groups": out_groups, "tail": out_tail}
