"""Batched serving engine: continuous batching over a fixed slot pool.

The engine owns per-slot KV/recurrent state; requests are admitted into free
slots, prefilled (left-padded into the shared cache), then advanced in lockstep
decode steps.  Finished slots (EOS or max_tokens) are evicted and refilled —
the standard continuous-batching pattern (vLLM-style), with a static slot
count so every jitted shape is fixed.

Per the Mensa reading: prefill steps are compute-centric (Pascal cluster) and
decode steps memory-centric (Jacquard/Pavlov clusters); the engine keeps them
as separate jitted programs so each lowers with its own strategy.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Model


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int = -1
    generated: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 512, greedy: bool = True):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.states = model.init_states(slots, max_len)
        self.memory = None
        self.requests: list[Request | None] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        self._decode = jax.jit(model.decode_step)
        self._queue: list[Request] = []

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.requests[slot] is None and self._queue:
                req = self._queue.pop(0)
                self.requests[slot] = req
                self._prefill_slot(slot, req)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Single-slot prefill: runs the prompt through a batch-1 cache and
        splices the result into the shared slot states."""
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        states1 = self.model.init_states(1, self.max_len)
        logits, states1, _ = self.model.prefill(self.params, toks, states1)
        self.states = _splice_states(self.states, states1, slot)
        self.positions[slot] = len(req.prompt)
        tok = int(jnp.argmax(logits[0, -1]))
        req.generated.append(tok)

    # ---------------------------------------------------------------- decode
    def step(self) -> None:
        self._admit()
        active = [i for i, r in enumerate(self.requests) if r is not None]
        if not active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for i in active:
            toks[i, 0] = self.requests[i].generated[-1] \
                if self.requests[i].generated else self.requests[i].prompt[-1]
        logits, self.states = self._decode(
            self.params, jnp.asarray(toks), self.states,
            jnp.asarray(self.positions), self.memory)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for i in active:
            req = self.requests[i]
            self.positions[i] += 1
            req.generated.append(int(nxt[i]))
            if (len(req.generated) >= req.max_new_tokens
                    or int(nxt[i]) == req.eos_id
                    or self.positions[i] >= self.max_len - 1):
                req.done = True
                self.requests[i] = None

    def run(self, requests: list[Request], max_steps: int = 10_000
            ) -> list[Request]:
        for r in requests:
            self.submit(r)
        steps = 0
        while (self._queue or any(r is not None for r in self.requests)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return requests


def _splice_states(pool_states, one_states, slot: int):
    """Write batch-1 `one_states` into slot `slot` of the pooled states.
    Batch is the first axis for tail states and the second for stacked
    (scan-group) states."""

    def splice(pool, new):
        if pool.ndim == new.ndim:          # tail state: batch axis 0
            return jax.lax.dynamic_update_slice(
                pool, new.astype(pool.dtype),
                (slot,) + (0,) * (pool.ndim - 1))
        raise ValueError((pool.shape, new.shape))

    def splice_stacked(pool, new):
        # pool: (G, B, ...), new: (G, 1, ...)
        return jax.lax.dynamic_update_slice(
            pool, new.astype(pool.dtype),
            (0, slot) + (0,) * (pool.ndim - 2))

    out_groups = jax.tree.map(splice_stacked, pool_states["groups"],
                              one_states["groups"])
    out_tail = jax.tree.map(splice, pool_states["tail"], one_states["tail"])
    return {"groups": out_groups, "tail": out_tail}
