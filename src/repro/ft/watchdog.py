"""Fault tolerance & straggler mitigation.

Components:
* ``StepWatchdog`` — per-step wall-time tracker with robust outlier detection
  (median + k*MAD).  On a real pod each host reports step times through the
  coordination service; a host flagged as a persistent straggler triggers the
  mitigation policy below.  On one host it still guards against livelock
  (e.g. a wedged data loader) via the hard timeout.
* ``FailureInjector`` — deterministic fault injection for tests/examples:
  raises ``InjectedFailure`` at a configured step so the restart path
  (checkpoint -> auto-resume -> identical loss curve) is exercised end-to-end.
* ``run_with_restarts`` — supervisor loop: run the train function, on failure
  restore from the latest checkpoint and continue, up to ``max_restarts``.

Straggler policy at pod scale (documented contract, enforced by the watchdog
callbacks): (1) flag a host when its step time exceeds median + 6*MAD for 3
consecutive steps; (2) first mitigation is data-reshard-away (skip its input
shard for the next window, covered by the deterministic pipeline); (3) second
is hot-spare swap: the job restarts from the last checkpoint on the standby
slice — identical semantics to the failure path below, which is why the two
share an implementation.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Callable


class InjectedFailure(RuntimeError):
    pass


@dataclass
class StepWatchdog:
    mad_k: float = 6.0
    window: int = 50
    consecutive: int = 3
    hard_timeout_s: float = 3600.0
    _times: list = field(default_factory=list)
    _flags: int = 0
    stragglers_detected: int = 0

    def observe(self, step_time_s: float) -> bool:
        """Record one step; returns True if this step is a straggler event."""
        self._times.append(step_time_s)
        if len(self._times) > self.window:
            self._times.pop(0)
        if step_time_s > self.hard_timeout_s:
            self.stragglers_detected += 1
            return True
        if len(self._times) < 10:
            return False
        med = statistics.median(self._times)
        mad = statistics.median(abs(t - med) for t in self._times) or 1e-9
        if step_time_s > med + self.mad_k * mad and step_time_s > 1.5 * med:
            self._flags += 1
        else:
            self._flags = 0
        if self._flags >= self.consecutive:
            self._flags = 0
            self.stragglers_detected += 1
            return True
        return False


@dataclass
class FailureInjector:
    fail_at_step: int = -1
    fail_once: bool = True
    _fired: bool = False

    def maybe_fail(self, step: int) -> None:
        if step == self.fail_at_step and not (self.fail_once and self._fired):
            self._fired = True
            raise InjectedFailure(f"injected failure at step {step}")


def run_with_restarts(train_once: Callable[[], None], *,
                      max_restarts: int = 3,
                      on_restart: Callable[[int, Exception], None] | None = None
                      ) -> int:
    """Supervisor: call `train_once` (which auto-resumes from the latest
    checkpoint internally); restart on failure. Returns #restarts used."""
    restarts = 0
    while True:
        try:
            train_once()
            return restarts
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any failure triggers restart
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart:
                on_restart(restarts, e)
