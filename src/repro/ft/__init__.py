"""repro.ft"""
