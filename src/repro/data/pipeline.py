"""Deterministic, exactly-resumable synthetic token pipeline.

``batch(step)`` is a pure function of (seed, step, topology), so a restarted
job consumes exactly the same sample stream with no replay and no skips —
the data-side half of fault tolerance.  Each host materializes only its own
shard (host_id/num_hosts split along the batch axis), and an async prefetch
thread keeps `prefetch` batches ahead of the training loop.

The token distribution is a Zipf-like categorical with a deterministic
per-(step, position) hash — cheap, seed-stable across processes, and enough
structure (skewed unigram + local repetition) for the loss to fall visibly
during the example runs.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    modality_tokens: int = 0
    modality_dim: int = 0
    encdec: bool = False
    d_model: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _philox(seed: int, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Cheap counter-based hash -> uint64 (deterministic across platforms)."""
    x = (a.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
         ^ b.astype(np.uint64) * np.uint64(0xBF58476D1CE4E5B9)
         ^ np.uint64(seed) * np.uint64(0x94D049BB133111EB))
    x ^= x >> np.uint64(31)
    x *= np.uint64(0x7FB5D329728EA185)
    x ^= x >> np.uint64(27)
    return x


class SyntheticTokens:
    """tokens[b, t] = Zipf(hash(seed, global_sample_index, t))."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf CDF over the vocab (s = 1.1), precomputed once
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        w = 1.0 / ranks ** 1.1
        self._cdf = np.cumsum(w) / np.sum(w)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        b, s = cfg.host_batch, cfg.seq_len
        base = step * cfg.global_batch + cfg.host_id * b
        sample_idx = (base + np.arange(b, dtype=np.int64))[:, None]
        pos = np.arange(s + 1, dtype=np.int64)[None, :]
        u = _philox(cfg.seed, sample_idx * (s + 1) + pos, pos + 1)
        uf = (u >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        toks = np.searchsorted(self._cdf, uf).astype(np.int32)
        # local repetition: every 7th position repeats 3 back (learnable)
        rep = (pos % 7 == 0) & (pos >= 3)
        toks = np.where(rep, np.roll(toks, 3, axis=1), toks)
        out = {"tokens": toks[:, :s], "labels": toks[:, 1:s + 1]}
        if cfg.modality_tokens:
            m = _philox(cfg.seed + 1, sample_idx + pos[:, :1], sample_idx)
            rng = np.random.RandomState((int(m[0, 0]) & 0x7FFFFFFF))
            out["modality"] = rng.randn(
                b, cfg.modality_tokens, cfg.modality_dim).astype(np.float32)
        if cfg.encdec:
            rng = np.random.RandomState((step * 1000003 + cfg.host_id)
                                        & 0x7FFFFFFF)
            out["src_embeds"] = rng.randn(b, s, cfg.d_model).astype(np.float32)
        return out


class PrefetchingLoader:
    """Async prefetch wrapper with exact-step resume."""

    def __init__(self, source: SyntheticTokens, start_step: int = 0,
                 prefetch: int = 2):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            try:
                self._q.put((step, self.source.batch(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
