"""repro.data"""
