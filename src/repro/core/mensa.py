"""Mensa system wrapper — evaluate any model zoo under the four §7 configurations
(Baseline, Base+HB, EyerissV2, Mensa) and produce the paper's comparison metrics.
"""
from __future__ import annotations

from dataclasses import dataclass

from .accelerators import (BASE_HB, EDGE_TPU, EYERISS_V2, MENSA_ACCELERATORS)
from .costmodel import ScheduleCost, monolithic_cost
from .energy import DEFAULT_ENERGY, EnergyParams
from .layerspec import ModelGraph
from .scheduler import MensaScheduler


@dataclass(frozen=True)
class ModelResult:
    model: str
    family: str
    baseline: ScheduleCost
    base_hb: ScheduleCost
    eyeriss: ScheduleCost
    mensa: ScheduleCost


def evaluate_model(graph: ModelGraph,
                   ep: EnergyParams = DEFAULT_ENERGY,
                   policy: str = "cluster") -> ModelResult:
    sched = MensaScheduler(MENSA_ACCELERATORS, energy=ep, policy=policy)
    return ModelResult(
        model=graph.name,
        family=graph.family,
        baseline=monolithic_cost(graph, EDGE_TPU, ep),
        base_hb=monolithic_cost(graph, BASE_HB, ep),
        eyeriss=monolithic_cost(graph, EYERISS_V2, ep),
        mensa=sched.evaluate(graph),
    )


def evaluate_zoo(graphs: list[ModelGraph],
                 ep: EnergyParams = DEFAULT_ENERGY,
                 policy: str = "cluster") -> list[ModelResult]:
    return [evaluate_model(g, ep, policy) for g in graphs]


def geomean(xs: list[float]) -> float:
    import math
    xs = [max(x, 1e-30) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


@dataclass(frozen=True)
class ZooSummary:
    """The paper's headline aggregate claims, computed over our zoo."""
    energy_reduction_vs_baseline: float        # paper: 66.0%
    energy_eff_x_vs_baseline: float            # paper: 3.0x
    energy_eff_x_vs_eyeriss: float             # paper: 2.4x
    throughput_x_vs_baseline: float            # paper: 3.1x
    throughput_x_vs_base_hb: float             # paper: 1.3x
    throughput_x_vs_eyeriss: float             # paper: 4.3x
    latency_x_vs_baseline: float               # paper: 1.96x
    latency_x_vs_base_hb: float                # paper: 1.17x
    base_hb_energy_reduction: float            # paper: 7.5%
    base_hb_throughput_x: float                # paper: 2.5x
    baseline_mean_utilization: float           # paper: 27.3%
    lstm_transducer_throughput_x: float        # paper: 5.7x
    lstm_transducer_baseline_util: float       # paper: <1%


def summarize(results: list[ModelResult]) -> ZooSummary:
    import numpy as np

    def ratios(num, den):
        return [num(r) / max(den(r), 1e-30) for r in results]

    lstm_tr = [r for r in results if r.family in ("lstm", "transducer")]
    peak = EDGE_TPU.peak_flops
    base_util = [r.baseline.throughput_flops / peak for r in results]
    return ZooSummary(
        energy_reduction_vs_baseline=1 - geomean(
            ratios(lambda r: r.mensa.energy.total, lambda r: r.baseline.energy.total)),
        energy_eff_x_vs_baseline=geomean(
            ratios(lambda r: r.mensa.efficiency_flops_per_j,
                   lambda r: r.baseline.efficiency_flops_per_j)),
        energy_eff_x_vs_eyeriss=geomean(
            ratios(lambda r: r.mensa.efficiency_flops_per_j,
                   lambda r: r.eyeriss.efficiency_flops_per_j)),
        throughput_x_vs_baseline=geomean(
            ratios(lambda r: r.mensa.throughput_flops,
                   lambda r: r.baseline.throughput_flops)),
        throughput_x_vs_base_hb=geomean(
            ratios(lambda r: r.mensa.throughput_flops,
                   lambda r: r.base_hb.throughput_flops)),
        throughput_x_vs_eyeriss=geomean(
            ratios(lambda r: r.mensa.throughput_flops,
                   lambda r: r.eyeriss.throughput_flops)),
        latency_x_vs_baseline=geomean(
            ratios(lambda r: r.baseline.latency_s, lambda r: r.mensa.latency_s)),
        latency_x_vs_base_hb=geomean(
            ratios(lambda r: r.base_hb.latency_s, lambda r: r.mensa.latency_s)),
        base_hb_energy_reduction=1 - geomean(
            ratios(lambda r: r.base_hb.energy.total,
                   lambda r: r.baseline.energy.total)),
        base_hb_throughput_x=geomean(
            ratios(lambda r: r.base_hb.throughput_flops,
                   lambda r: r.baseline.throughput_flops)),
        baseline_mean_utilization=float(np.mean(base_util)),
        lstm_transducer_throughput_x=geomean(
            [r.mensa.throughput_flops / max(r.baseline.throughput_flops, 1e-30)
             for r in lstm_tr]) if lstm_tr else 0.0,
        lstm_transducer_baseline_util=float(np.mean(
            [r.baseline.throughput_flops / peak
             for r in lstm_tr])) if lstm_tr else 0.0,
    )
