"""Dataflow performance model — maps (layer, accelerator) to an execution profile.

This is the analytic model the paper builds for §6 ("we develop an analytical cost
model to determine the performance of each of our proposed dataflows").  For every
layer we abstract the compute as a (possibly per-timestep) GEMM of logical dims
  M (independent output positions) x K (reduction depth) x N (output channels)
and derive, per dataflow:

  * eff_map   — spatial mapping efficiency of the PE array (quantization losses,
                M=1 MVM degeneracy, depthwise's missing reduction dim, ...)
  * eff_sched — scheduling efficiency (baseline's sequential LSTM-gate scheduling
                vs. Pavlov's decoupled/parallel schedule — §3.2.1)
  * offchip_param_bytes / offchip_act_bytes — DRAM traffic after buffer filtering
  * buf_param_reads / buf_act_accesses      — on-chip buffer traffic (bytes)
  * noc_bytes — on-chip distribution traffic after multicast filtering
  * exposed_latency_s — per-dependent-fetch DRAM latency that cannot overlap
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .accelerators import AcceleratorConfig
from .layerspec import LayerKind, LayerSpec


@dataclass(frozen=True)
class GemmShape:
    m: int          # independent output positions
    k: int          # reduction depth
    n: int          # output channels
    steps: int = 1  # sequential repetitions (recurrent timesteps)
    parallel_mvms: int = 1  # independent MVMs per step (e.g. 4 LSTM gates x 2)


def gemm_shape(spec: LayerSpec) -> GemmShape:
    k = spec.kind
    if k is LayerKind.CONV2D:
        return GemmShape(m=spec.batch * spec.out_hw * spec.out_hw,
                         k=spec.kernel * spec.kernel * spec.in_ch, n=spec.out_ch)
    if k is LayerKind.PWCONV2D:
        return GemmShape(m=spec.batch * spec.out_hw * spec.out_hw,
                         k=spec.in_ch, n=spec.out_ch)
    if k is LayerKind.DWCONV2D:
        # no cross-channel reduction: N=channels but K only kernel^2
        return GemmShape(m=spec.batch * spec.out_hw * spec.out_hw,
                         k=spec.kernel * spec.kernel, n=spec.in_ch)
    if k is LayerKind.FC:
        return GemmShape(m=spec.batch, k=spec.in_features, n=spec.out_features)
    if k is LayerKind.LSTM:
        # per timestep: 4 gates x (input MVM + hidden MVM)
        return GemmShape(m=spec.batch, k=(spec.in_features + spec.hidden) // 2,
                         n=spec.hidden, steps=spec.seq_len, parallel_mvms=8)
    if k is LayerKind.RGLRU:
        return GemmShape(m=spec.batch, k=spec.in_features, n=spec.hidden,
                         steps=spec.seq_len, parallel_mvms=2)
    if k is LayerKind.SSM:
        return GemmShape(m=spec.batch, k=spec.in_features, n=spec.hidden,
                         steps=spec.seq_len, parallel_mvms=2)
    if k is LayerKind.ATTENTION:
        d = max(spec.hidden, 1)
        return GemmShape(m=spec.batch * spec.seq_len, k=d,
                         n=spec.heads * spec.head_dim or d)
    if k is LayerKind.MOE:
        return GemmShape(m=spec.batch * spec.seq_len, k=spec.in_features,
                         n=spec.hidden, parallel_mvms=spec.top_k)
    if k is LayerKind.EMBEDDING:
        return GemmShape(m=spec.batch * spec.seq_len, k=1, n=spec.out_features)
    # pool/norm/elementwise glue
    return GemmShape(m=max(spec.out_act_elems, 1), k=1, n=1)


def _quant_eff(dim: int, size: int) -> float:
    """Utilization of a hardware dimension of `size` by a logical dim `dim`."""
    if dim <= 0:
        return 1.0
    return dim / (math.ceil(dim / size) * size)


@dataclass(frozen=True)
class ExecutionProfile:
    eff_map: float
    eff_sched: float
    offchip_param_bytes: float
    offchip_act_bytes: float
    buf_param_reads: float
    buf_act_accesses: float
    noc_bytes: float
    exposed_latency_s: float
    bw_efficiency: float = 1.0   # attained fraction of DRAM peak (§5.4: access
                                 # pattern determines usable bandwidth)
    buf_param_stream: float = 0.0  # bytes staged at bank granularity (streaming)

    @property
    def offchip_bytes(self) -> float:
        return self.offchip_param_bytes + self.offchip_act_bytes


# Fraction of peak DRAM bandwidth each dataflow's access pattern attains (§5.4:
# "we cannot [use the bandwidth] simply by issuing many outstanding requests...
# if we can design our dataflow to issue *sequential* accesses, we can exploit
# this pattern to use the bandwidth... at much lower cost").  Monolithic
# buffer-tile fetch patterns are scattered; Pavlov/Jacquard stream sequentially.
BW_EFFICIENCY = {
    "output_stationary": 0.30,
    "pascal": 0.60,
    "row_stationary": 0.45,   # flexible NoC feeds the array well
    "pavlov": 0.95,
    "jacquard": 0.90,
}

# Per-scheduled-unit dispatch overhead: the baseline graph scheduler issues each
# LSTM gate MVM as a standalone FC layer (§3.2.1), paying DMA/descriptor setup
# per unit.  Mensa's dataflow-sequenced accelerators do not.
DISPATCH_OVERHEAD_S = {
    "output_stationary": 25e-6,
    "pascal": 25e-6,
    "row_stationary": 30e-6,  # incl. online NoC reconfiguration (§8 critique)
    "pavlov": 0.0,
    "jacquard": 0.0,
}


def _recurrent_param_traffic(spec: LayerSpec, acc: AcceleratorConfig,
                             decouple_input: bool) -> float:
    """Off-chip parameter traffic of a recurrent layer.

    Weights are consumed once per timestep.  Whatever fraction fits on-chip is
    fetched once; the remainder streams from DRAM every step.  Pavlov's decoupled
    schedule (§5.4) batches all input MVMs so W_x is fetched exactly once; the
    hidden-MVM weights W_h still stream per step (sequentially, which is what the
    near-data placement makes cheap).
    """
    pb = spec.param_bytes
    if spec.kind is LayerKind.LSTM:
        wx = 4 * spec.in_features * spec.hidden * spec.bytes_per_param
        wh = 4 * spec.hidden * spec.hidden * spec.bytes_per_param
    elif spec.kind in (LayerKind.RGLRU, LayerKind.SSM):
        wx, wh = pb, 0.0  # recurrence is diagonal/elementwise: no big W_h
    else:
        wx, wh = pb, 0.0
    steps = max(spec.seq_len, 1)
    if decouple_input:
        # W_x once; W_h per step unless it fits on-chip
        wh_fit = min(wh, acc.param_buf_bytes)
        return wx + wh_fit + (wh - wh_fit) * steps
    fit = min(pb, acc.param_buf_bytes)
    return fit + (pb - fit) * steps


def profile(spec: LayerSpec, acc: AcceleratorConfig) -> ExecutionProfile:
    g = gemm_shape(spec)
    rows, cols = acc.pe_rows, acc.pe_cols
    pb, df = spec.param_bytes, acc.dataflow
    in_b, out_b = spec.in_act_bytes, spec.out_act_bytes
    recurrent = spec.kind in (LayerKind.LSTM, LayerKind.RGLRU, LayerKind.SSM)
    eff_sched = 1.0
    exposed = 0.0
    noc_mult = 1.0          # on-chip distribution amplification (1 = perfect multicast)
    buf_read_mult = 1.0     # param-buffer read amplification

    # Systolic pipeline-fill efficiency: short reduction dims cannot keep a
    # dot-product spine busy (K-deep accumulation amortizes the fill bubbles).
    fill = g.k / (g.k + rows / 4)

    def _os_mapping_eff() -> float:
        """Monolithic systolic array mapping efficiency: the compiler picks the
        better of (a) output-stationary M x N spatial tiling and (b) a
        weight-streaming mapping (K on rows, N on cols, M temporal) that keeps
        the array full for skinny GEMMs but is only legal when the weights
        stream once (m small — MVM-like)."""
        eff_os = _quant_eff(g.m, rows) * _quant_eff(g.n, cols) * fill
        if g.m <= rows:
            eff_ws = _quant_eff(g.k, rows) * _quant_eff(g.n, cols)
            return max(eff_os, eff_ws)
        return eff_os

    if df in ("output_stationary",):
        eff_map = _os_mapping_eff()
        if spec.kind is LayerKind.DWCONV2D:
            # depthwise has no cross-channel reduction to fill the spine
            eff_map *= 0.5
        if recurrent:
            # gates scheduled sequentially as independent FC layers (§3.2.1)
            eff_sched = 0.5
            exposed = g.steps * g.parallel_mvms * DISPATCH_OVERHEAD_S[df]
        m_tiles = math.ceil(g.m / rows)
        buf_read_mult = float(m_tiles) if pb <= acc.param_buf_bytes else 1.0
        noc_mult = 2.0   # no multicast-optimized distribution
        if recurrent:
            off_p = _recurrent_param_traffic(spec, acc, decouple_input=False)
        else:
            off_p = pb
    elif df == "pascal":
        eff_map = _os_mapping_eff()
        if spec.kind is LayerKind.DWCONV2D:
            eff_map *= 0.7
        if recurrent:
            eff_sched = 0.6
            exposed = g.steps * g.parallel_mvms * DISPATCH_OVERHEAD_S[df]
            off_p = _recurrent_param_traffic(spec, acc, decouple_input=False)
        else:
            off_p = pb
        m_tiles = math.ceil(g.m / rows)
        # spatial multicast: one buffer read feeds all PEs in a column
        buf_read_mult = float(m_tiles) / cols if pb <= acc.param_buf_bytes else 1.0
        buf_read_mult = max(buf_read_mult, 1.0 / cols)
        noc_mult = 1.0   # multicast, no partial-sum traffic (temporal reduction)
    elif df == "pavlov":
        # each PE owns output elements; N across all PEs
        n_pes = rows * cols
        eff_map = _quant_eff(g.n, n_pes)
        eff_sched = 1.0  # decoupled input/hidden MVMs + K concurrent cell psums
        if recurrent:
            off_p = _recurrent_param_traffic(spec, acc, decouple_input=True)
            exposed = 0.0  # sequential streaming hides DRAM latency
        else:
            off_p = pb
        buf_read_mult = 0.0   # params stream DRAM->PE RF directly (512 B/PE)
        noc_mult = 1.0
    elif df == "jacquard":
        # params spatially distributed + pinned in PE RFs; reuse factor WxH
        n_pes = rows * cols
        eff_map = _quant_eff(g.k, n_pes) if g.k >= n_pes else \
            _quant_eff(g.k * min(g.n, max(1, n_pes // max(g.k, 1))), n_pes)
        if spec.kind is LayerKind.DWCONV2D:
            # §7.2: depthwise runs "less optimally" on Jacquard — its dataflow
            # targets parameter reuse, but depthwise activations have none
            eff_map = min(eff_map, 0.45)
        if recurrent:
            off_p = _recurrent_param_traffic(spec, acc, decouple_input=True)
        else:
            off_p = pb
        buf_read_mult = 1.0   # each param passes the buffer once on its way to RF
        noc_mult = 1.0
        eff_sched = 1.0
    elif df == "row_stationary":
        # Eyeriss v2: flexible mapping, good spatial efficiency even for
        # depthwise/MVM, but small array and tiny buffers
        n_pes = rows * cols
        eff_map = min(1.0, (g.m * min(g.n, 32)) / n_pes) if g.m * g.n < n_pes \
            else 0.9
        if recurrent:
            eff_sched = 0.7
            exposed = g.steps * g.parallel_mvms * DISPATCH_OVERHEAD_S[df]
            off_p = _recurrent_param_traffic(spec, acc, decouple_input=False)
        else:
            off_p = pb
        buf_read_mult = 1.0
        noc_mult = 2.0   # flexible (reconfigurable) NoC costs energy per byte
    else:
        raise ValueError(f"unknown dataflow {df}")

    # activation traffic: spill to DRAM only what the act buffer cannot hold
    act_ws = in_b + out_b
    if act_ws <= acc.act_buf_bytes:
        off_a = 0.0
    else:
        off_a = act_ws - acc.act_buf_bytes
    # paper: Mensa synchronizes cross-accelerator activations via DRAM; the
    # scheduler adds that transfer separately (phase 2), so `off_a` here is
    # intra-layer spill only.

    # Resident parameters are re-read from the (full, expensive) buffer per
    # M-tile per the dataflow's read amplification; streamed parameters are
    # staged at bank granularity on their way to the array (cheap sequential
    # bursts).  Pavlov streams DRAM->PE-RF directly and bypasses the buffer.
    if buf_read_mult <= 0.0:
        buf_param_reads, buf_stream = 0.0, 0.0
    elif pb <= acc.param_buf_bytes:
        buf_param_reads, buf_stream = pb * buf_read_mult, max(off_p - pb, 0.0)
    else:
        buf_param_reads, buf_stream = 0.0, off_p
    # OS-style dataflows re-read the input activations once per output-channel
    # tile (each N-tile sweeps the full input); Pavlov/Jacquard stream acts once.
    if df in ("output_stationary", "pascal", "row_stationary"):
        n_tiles = math.ceil(g.n / cols) if g.n else 1
        buf_act = in_b * n_tiles + out_b
    else:
        buf_act = act_ws
    noc = (buf_param_reads + buf_stream + buf_act) * noc_mult

    return ExecutionProfile(
        eff_map=max(min(eff_map, 1.0), 1e-4),
        eff_sched=eff_sched,
        offchip_param_bytes=off_p,
        offchip_act_bytes=off_a,
        buf_param_reads=buf_param_reads,
        buf_act_accesses=buf_act,
        noc_bytes=noc,
        exposed_latency_s=exposed,
        bw_efficiency=BW_EFFICIENCY[df],
        buf_param_stream=buf_stream,
    )
