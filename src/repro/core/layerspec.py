"""Typed per-layer DAG used by the Mensa characterization/scheduling pipeline.

A ``LayerSpec`` describes one schedulable unit of work (one NN layer) exactly the
way the paper characterizes it: its kind, its tensor shapes, and enough structure
to derive MACs, parameter/activation footprints, and reuse.  A ``ModelGraph`` is a
DAG of layers (edges carry the activation bytes that flow between layers — the
quantity the phase-2 scheduler prices).

All byte quantities honor ``bytes_per_param`` / ``bytes_per_act`` so the same specs
serve the paper's int8 edge models (1 B) and the TPU-level bf16 models (2 B).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class LayerKind(enum.Enum):
    CONV2D = "conv2d"            # standard convolution
    DWCONV2D = "dwconv2d"        # depthwise convolution
    PWCONV2D = "pwconv2d"        # pointwise (1x1) convolution
    FC = "fc"                    # fully connected / dense
    LSTM = "lstm"                # full LSTM layer (4 gates, T steps)
    EMBEDDING = "embedding"      # table lookup
    POOL = "pool"                # pooling (negligible params)
    ATTENTION = "attention"      # (self/cross) attention core
    RGLRU = "rglru"              # gated linear recurrence (Griffin/RecurrentGemma)
    SSM = "ssm"                  # Mamba-style selective scan
    MOE = "moe"                  # mixture-of-experts FFN
    NORM = "norm"                # layernorm/rmsnorm
    ELEMENTWISE = "elementwise"  # residual add / activation glue


@dataclass(frozen=True)
class LayerSpec:
    """One layer, with everything the characterizer needs.

    Shapes use the conventions:
      CONV2D/DWCONV2D/PWCONV2D: in_hw, in_ch, out_ch, kernel, stride
      FC: in_features, out_features
      LSTM: in_features (x_t dim), hidden (h dim), seq_len
      EMBEDDING: vocab (rows), out_features (dim), seq_len tokens looked up
      ATTENTION: hidden=d_model, heads, kv_heads, head_dim, seq_len, kv_len, window
      RGLRU/SSM: in_features=d_model, hidden=d_inner, seq_len, state (SSM state dim)
      MOE: in_features=d_model, hidden=d_ff, experts, top_k
    """

    name: str
    kind: LayerKind
    # generic dims (0 when unused)
    in_hw: int = 0
    in_ch: int = 0
    out_ch: int = 0
    kernel: int = 1
    stride: int = 1
    in_features: int = 0
    out_features: int = 0
    hidden: int = 0
    seq_len: int = 1
    kv_len: int = 0
    heads: int = 0
    kv_heads: int = 0
    head_dim: int = 0
    window: int = 0
    vocab: int = 0
    experts: int = 0
    top_k: int = 0
    state: int = 0
    batch: int = 1
    bytes_per_param: float = 1.0   # int8 edge models: 1 byte
    bytes_per_act: float = 1.0

    # ------------------------------------------------------------------ shapes
    @property
    def out_hw(self) -> int:
        if self.kind in (LayerKind.CONV2D, LayerKind.DWCONV2D, LayerKind.PWCONV2D,
                         LayerKind.POOL):
            return max(1, self.in_hw // self.stride)
        return 0

    # ------------------------------------------------------------------ params
    @property
    def param_count(self) -> int:
        k = self.kind
        if k is LayerKind.CONV2D:
            return self.kernel * self.kernel * self.in_ch * self.out_ch
        if k is LayerKind.DWCONV2D:
            return self.kernel * self.kernel * self.in_ch
        if k is LayerKind.PWCONV2D:
            return self.in_ch * self.out_ch
        if k is LayerKind.FC:
            return self.in_features * self.out_features
        if k is LayerKind.LSTM:
            # 4 gates x (W_x: in->hidden, W_h: hidden->hidden)
            return 4 * (self.in_features * self.hidden + self.hidden * self.hidden)
        if k is LayerKind.EMBEDDING:
            return self.vocab * self.out_features
        if k is LayerKind.ATTENTION:
            d = self.hidden
            q = self.heads * self.head_dim
            kv = self.kv_heads * self.head_dim
            return d * q + 2 * d * kv + q * d  # Wq, Wk, Wv, Wo
        if k is LayerKind.RGLRU:
            # input/gate projections + recurrent gates (diagonal recurrence)
            return 2 * self.in_features * self.hidden + 3 * self.hidden
        if k is LayerKind.SSM:
            d_in, d_state = self.hidden, self.state
            # in_proj (x2 branches) + dt/B/C proj + out_proj + conv
            return (2 * self.in_features * d_in + d_in * (2 * d_state + 1)
                    + d_in * self.in_features + 4 * d_in)
        if k is LayerKind.MOE:
            return self.experts * 3 * self.in_features * self.hidden \
                + self.in_features * self.experts  # router
        if k is LayerKind.NORM:
            return self.in_features
        return 0

    @property
    def param_bytes(self) -> float:
        return self.param_count * self.bytes_per_param

    # -------------------------------------------------------------------- MACs
    @property
    def macs(self) -> int:
        """Multiply-accumulate count for one inference pass (batch included)."""
        b, k = self.batch, self.kind
        if k is LayerKind.CONV2D:
            return b * self.out_hw * self.out_hw * self.out_ch \
                * self.kernel * self.kernel * self.in_ch
        if k is LayerKind.DWCONV2D:
            return b * self.out_hw * self.out_hw * self.in_ch * self.kernel * self.kernel
        if k is LayerKind.PWCONV2D:
            return b * self.out_hw * self.out_hw * self.in_ch * self.out_ch
        if k is LayerKind.FC:
            return b * self.in_features * self.out_features
        if k is LayerKind.LSTM:
            return b * self.seq_len * 4 * (self.in_features * self.hidden
                                           + self.hidden * self.hidden)
        if k is LayerKind.EMBEDDING:
            return 0
        if k is LayerKind.ATTENTION:
            d = self.hidden
            q = self.heads * self.head_dim
            kv = self.kv_heads * self.head_dim
            proj = b * self.seq_len * (d * q + 2 * d * kv + q * d)
            ctx = self.kv_len if self.kv_len else self.seq_len
            if self.window:
                ctx = min(ctx, self.window)
            score = b * self.heads * self.seq_len * ctx * self.head_dim * 2
            return proj + score
        if k is LayerKind.RGLRU:
            return b * self.seq_len * (2 * self.in_features * self.hidden
                                       + 4 * self.hidden)
        if k is LayerKind.SSM:
            d_in, d_state = self.hidden, self.state
            per_tok = (2 * self.in_features * d_in + d_in * (2 * d_state + 1)
                       + d_in * self.in_features + 2 * d_in * d_state + 4 * d_in)
            return b * self.seq_len * per_tok
        if k is LayerKind.MOE:
            return b * self.seq_len * (self.top_k * 3 * self.in_features * self.hidden
                                       + self.in_features * self.experts)
        if k is LayerKind.POOL:
            return b * self.out_hw * self.out_hw * self.in_ch * self.kernel * self.kernel
        if k is LayerKind.NORM:
            return b * self.seq_len * self.in_features * 2
        return 0

    @property
    def flops(self) -> int:
        return 2 * self.macs

    # -------------------------------------------------------------- activations
    @property
    def in_act_elems(self) -> int:
        b, k = self.batch, self.kind
        if k in (LayerKind.CONV2D, LayerKind.PWCONV2D):
            return b * self.in_hw * self.in_hw * self.in_ch
        if k in (LayerKind.DWCONV2D, LayerKind.POOL):
            return b * self.in_hw * self.in_hw * self.in_ch
        if k is LayerKind.FC:
            return b * self.in_features
        if k is LayerKind.LSTM:
            return b * self.seq_len * self.in_features
        if k is LayerKind.EMBEDDING:
            return b * self.seq_len
        if k in (LayerKind.ATTENTION, LayerKind.RGLRU, LayerKind.SSM, LayerKind.MOE,
                 LayerKind.NORM, LayerKind.ELEMENTWISE):
            return b * self.seq_len * self.in_features if self.in_features else 0
        return 0

    @property
    def out_act_elems(self) -> int:
        b, k = self.batch, self.kind
        if k in (LayerKind.CONV2D, LayerKind.PWCONV2D):
            return b * self.out_hw * self.out_hw * self.out_ch
        if k in (LayerKind.DWCONV2D, LayerKind.POOL):
            return b * self.out_hw * self.out_hw * self.in_ch
        if k is LayerKind.FC:
            return b * self.out_features
        if k is LayerKind.LSTM:
            return b * self.seq_len * self.hidden
        if k is LayerKind.EMBEDDING:
            return b * self.seq_len * self.out_features
        if k in (LayerKind.ATTENTION, LayerKind.MOE, LayerKind.NORM,
                 LayerKind.ELEMENTWISE):
            return b * self.seq_len * (self.in_features or self.hidden)
        if k in (LayerKind.RGLRU, LayerKind.SSM):
            return b * self.seq_len * self.in_features
        return 0

    @property
    def in_act_bytes(self) -> float:
        return self.in_act_elems * self.bytes_per_act

    @property
    def out_act_bytes(self) -> float:
        return self.out_act_elems * self.bytes_per_act


@dataclass
class ModelGraph:
    """A model = named DAG of LayerSpecs. ``edges`` are (src_idx, dst_idx)."""

    name: str
    family: str                      # "cnn" | "lstm" | "transducer" | "rcnn" | ...
    layers: list[LayerSpec]
    edges: list[tuple[int, int]] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.edges and len(self.layers) > 1:
            # default: simple chain
            self.edges = [(i, i + 1) for i in range(len(self.layers) - 1)]

    # convenience aggregates ---------------------------------------------------
    @property
    def total_params(self) -> int:
        return sum(l.param_count for l in self.layers)

    @property
    def total_param_bytes(self) -> float:
        return sum(l.param_bytes for l in self.layers)

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def total_flops(self) -> int:
        return 2 * self.total_macs

    def successors(self, idx: int) -> list[int]:
        return [d for (s, d) in self.edges if s == idx]

    def predecessors(self, idx: int) -> list[int]:
        return [s for (s, d) in self.edges if d == idx]

    def validate(self) -> None:
        n = len(self.layers)
        for s, d in self.edges:
            if not (0 <= s < n and 0 <= d < n):
                raise ValueError(f"{self.name}: edge ({s},{d}) out of range 0..{n-1}")
            if s >= d:
                raise ValueError(f"{self.name}: edge ({s},{d}) not topologically ordered")
