"""Energy model — §6 methodology.

Mirrors the paper's model: total = MAC energy + on-chip buffer energy (CACTI-style,
capacity-dependent pJ/B) + DRAM energy (per-byte, LPDDR4 vs. HBM-internal) + NoC
energy + static leakage x latency.

Constants are physically grounded:
  * 8-bit MAC = 0.2 pJ/bit (paper) -> 1.6 pJ/MAC -> 0.8 pJ/FLOP.
  * LPDDR4 ~ 4 pJ/bit = 32 pJ/B (paper's refs [3,15]); HBM-internal access from the
    logic layer ~ 1.25 pJ/bit = 10 pJ/B (TETRIS/Mondrian-class numbers).
  * SRAM access energy scales ~ sqrt(capacity) (CACTI): e(B) = e0 * sqrt(cap/32KB),
    with e0 = 0.4 pJ/B at 32 KB (22 nm).
  * Leakage: 30 mW/MB SRAM + 25 uW/PE.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .accelerators import AcceleratorConfig

MB = 1024.0 * 1024.0


@dataclass(frozen=True)
class EnergyParams:
    e_flop: float = 0.8e-12              # J per FLOP (8-bit MAC = 1.6 pJ)
    e_dram_lpddr4: float = 32e-12        # J per byte
    e_dram_hbm_internal: float = 4.5e-12 # J per byte (logic-layer access: no
                                         # SoC interconnect / PHY crossing)
    e_sram_base: float = 1.6e-12        # J per byte at 32 KB (CACTI-P 22 nm,
                                         # incl. bank selection + output drive)
    sram_ref_bytes: float = 32 * 1024.0
    e_noc: float = 0.25e-12              # J per byte-hop (on-chip distribution)
    p_leak_sram_per_mb: float = 0.008    # W per MB
    p_leak_pe: float = 12e-6             # W per PE (incl. its register file)

    def e_sram(self, capacity_bytes: float) -> float:
        cap = max(capacity_bytes, 1024.0)
        return self.e_sram_base * math.sqrt(cap / self.sram_ref_bytes)

    def e_dram(self, kind: str) -> float:
        return self.e_dram_hbm_internal if kind == "hbm_internal" \
            else self.e_dram_lpddr4

    def static_power(self, acc: AcceleratorConfig) -> float:
        sram_mb = (acc.param_buf_bytes + acc.act_buf_bytes) / MB
        return self.p_leak_sram_per_mb * sram_mb + self.p_leak_pe * acc.n_pes


DEFAULT_ENERGY = EnergyParams()


@dataclass(frozen=True)
class EnergyBreakdown:
    pe: float
    buf_param_dynamic: float
    buf_act_dynamic: float
    noc: float
    dram: float
    static: float

    @property
    def total(self) -> float:
        return (self.pe + self.buf_param_dynamic + self.buf_act_dynamic
                + self.noc + self.dram + self.static)

    def __add__(self, o: "EnergyBreakdown") -> "EnergyBreakdown":
        return EnergyBreakdown(
            self.pe + o.pe,
            self.buf_param_dynamic + o.buf_param_dynamic,
            self.buf_act_dynamic + o.buf_act_dynamic,
            self.noc + o.noc,
            self.dram + o.dram,
            self.static + o.static)


ZERO_ENERGY = EnergyBreakdown(0, 0, 0, 0, 0, 0)
