"""Layer clustering — the paper's §5.1 discovery that 97% of layers fall into
five clusters in (param footprint, param FLOP/B, MACs, activation reuse) space.

Two implementations:
  * ``rule_cluster``      — the paper's published cluster boundary rules (Table in §5.1).
  * ``kmeans_cluster``    — plain k-means (k=5) on log-features, implemented from
                            scratch in numpy; used to *verify* that the rule clusters
                            are natural (high agreement ⇒ the structure is in the data,
                            not in the rules).

Clusters (paper §5.1):
  1: footprint 1–100 kB,    FLOP/B 780–20k,  MACs 30M–200M   (early std conv)
  2: footprint 100–500 kB,  FLOP/B 81–400,   MACs 20M–100M   (pointwise / mid conv)
  3: footprint 0.9–18 MB,   FLOP/B ~1,       MACs 0.1M–10M   (LSTM gates, FC)
  4: footprint 0.5–2.5 MB,  FLOP/B 25–64,    MACs 5M–25M     (late deep conv)
  5: footprint 1–100 kB,    FLOP/B 49–600,   MACs 0.5M–5M    (depthwise)
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .characterize import LayerCharacteristics
from .layerspec import LayerKind

KB = 1024.0
MB = 1024.0 * 1024.0

# (footprint lo/hi bytes, flop/B lo/hi, MACs lo/hi) per cluster id
RULE_BOUNDS: dict[int, tuple[float, float, float, float, float, float]] = {
    1: (1 * KB, 100 * KB, 780.0, 20_000.0, 30e6, 200e6),
    2: (100 * KB, 500 * KB, 81.0, 400.0, 20e6, 100e6),
    3: (0.9 * MB, 18 * MB, 0.0, 8.0, 0.1e6, 10e6),
    4: (0.5 * MB, 2.5 * MB, 25.0, 64.0, 5e6, 25e6),
    5: (1 * KB, 100 * KB, 49.0, 600.0, 0.5e6, 5e6),
}

# Log-space centroids of the rule boxes, used for nearest-centroid fallback.
_CENTROIDS = {
    cid: np.array([
        (math.log10(lo_f) + math.log10(hi_f)) / 2,
        (math.log10(max(lo_r, 0.5)) + math.log10(max(hi_r, 0.5))) / 2,
        (math.log10(lo_m) + math.log10(hi_m)) / 2,
    ])
    for cid, (lo_f, hi_f, lo_r, hi_r, lo_m, hi_m) in RULE_BOUNDS.items()
}


def _features(c: LayerCharacteristics) -> np.ndarray:
    return np.array([
        math.log10(max(c.sched_param_bytes, 1.0)),
        math.log10(max(c.sched_flop_per_byte, 0.5)),
        math.log10(max(c.sched_macs, 1.0)),
    ])


@dataclass(frozen=True)
class ClusterAssignment:
    cluster: int          # 1..5
    strict: bool          # True if the layer satisfied the published rule box exactly


def _in_box(c: LayerCharacteristics, cid: int, pad: float = 1.0) -> bool:
    lo_f, hi_f, lo_r, hi_r, lo_m, hi_m = RULE_BOUNDS[cid]
    return (lo_f / pad <= c.sched_param_bytes <= hi_f * pad
            and lo_r / pad <= c.sched_flop_per_byte <= hi_r * pad
            and lo_m / pad <= c.sched_macs <= hi_m * pad)


def rule_cluster(c: LayerCharacteristics) -> ClusterAssignment:
    """Assign the paper's cluster id. Strict box match first; else structural
    priors (recurrent/FC-with-big-footprint → 3, depthwise → 5), else nearest
    rule-box centroid in log space."""
    for cid in (1, 2, 3, 4, 5):
        if _in_box(c, cid):
            return ClusterAssignment(cid, True)
    # structural priors mirror the paper's cluster descriptions
    if c.recurrent or (c.kind is LayerKind.FC and c.sched_param_bytes > 0.5 * MB) \
            or c.kind is LayerKind.EMBEDDING:
        return ClusterAssignment(3, False)
    if c.kind is LayerKind.DWCONV2D:
        return ClusterAssignment(5, False)
    f = _features(c)
    cid = min(_CENTROIDS, key=lambda k: float(np.sum((f - _CENTROIDS[k]) ** 2)))
    return ClusterAssignment(cid, False)


def cluster_all(chars: list[LayerCharacteristics]) -> list[ClusterAssignment]:
    return [rule_cluster(c) for c in chars]


def strict_fraction(chars: list[LayerCharacteristics], pad: float = 1.0) -> float:
    """Fraction of (weight-bearing) layers inside one of the 5 rule boxes — the
    paper's "97% of layers group into 5 clusters" claim.  ``pad`` loosens the
    published (rounded, descriptive) bounds multiplicatively; benchmarks report
    pad=1 (literal boxes) and pad=2.5 (boxes as cluster descriptors)."""
    weighty = [c for c in chars if c.param_bytes > 256 and c.macs > 0]
    if not weighty:
        return 0.0
    hits = sum(1 for c in weighty
               if any(_in_box(c, cid, pad) for cid in RULE_BOUNDS))
    return hits / len(weighty)


# -------------------------------------------------------------------- k-means
def kmeans_cluster(chars: list[LayerCharacteristics], k: int = 5, seed: int = 0,
                   iters: int = 100) -> tuple[np.ndarray, np.ndarray]:
    """From-scratch k-means on log features. Returns (labels, centroids)."""
    x = np.stack([_features(c) for c in chars])
    # explicit seeded generator: every draw below goes through rng, so the
    # same (chars, k, seed) always yields the same labels — the oracle's
    # reproducibility contract (and CI's)
    rng = np.random.RandomState(seed)
    # k-means++ init; degenerate inputs (all points coincident — common for a
    # transformer whose layers are identical specs) make every d2 zero, where
    # the weighted draw is undefined: fall back to a uniform seeded draw
    # instead of crashing np.random.choice with probs that don't sum to 1
    cent = [x[rng.randint(len(x))]]
    for _ in range(k - 1):
        d2 = np.min(np.stack([np.sum((x - c) ** 2, axis=1) for c in cent]), axis=0)
        total = float(d2.sum())
        if total <= 0.0:
            cent.append(x[rng.randint(len(x))])
            continue
        probs = d2 / total
        probs = probs / probs.sum()     # renormalize away fp round-off
        cent.append(x[rng.choice(len(x), p=probs)])
    cent_arr = np.stack(cent)
    labels = np.zeros(len(x), dtype=int)
    for it in range(iters):
        d = np.sum((x[:, None, :] - cent_arr[None, :, :]) ** 2, axis=2)
        new_labels = np.argmin(d, axis=1)
        if np.array_equal(new_labels, labels) and it > 0:
            break
        labels = new_labels
        for j in range(k):
            pts = x[labels == j]
            if len(pts):
                cent_arr[j] = pts.mean(axis=0)
    return labels, cent_arr


def agreement(chars: list[LayerCharacteristics]) -> float:
    """Best-permutation agreement between rule clusters and k-means clusters."""
    import itertools
    rules = np.array([rule_cluster(c).cluster - 1 for c in chars])
    km, _ = kmeans_cluster(chars)
    best = 0.0
    for perm in itertools.permutations(range(5)):
        mapped = np.array([perm[v] for v in km])
        best = max(best, float(np.mean(mapped == rules)))
    return best
