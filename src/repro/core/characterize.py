"""Per-layer characterization — the paper's §3.2 analysis machinery.

For every layer we derive the characteristics the paper clusters on:
  * parameter footprint (bytes)
  * parameter FLOP/B (arithmetic intensity w.r.t. parameters — "parameter reuse")
  * MAC count
  * activation footprint (bytes, in+out)
  * activation FLOP/B ("activation reuse")
plus bookkeeping (kind, model, index) used by the scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass

from .layerspec import LayerKind, LayerSpec, ModelGraph


@dataclass(frozen=True)
class LayerCharacteristics:
    model: str
    index: int
    name: str
    kind: LayerKind
    macs: int
    flops: int
    param_bytes: float
    act_bytes: float                # in + out activations
    out_act_bytes: float
    param_flop_per_byte: float      # parameter reuse
    act_flop_per_byte: float        # activation reuse
    recurrent: bool                 # sequential inter-step dependency (LSTM/RGLRU/SSM)
    # Scheduling-unit granularity (paper §3.2.1: the accelerator schedules each
    # LSTM *gate MVM* as an FC layer; cluster boxes in §5.1 are stated at that
    # granularity — e.g. "each gate has an average of 2.1M parameters").
    sched_macs: float = 0.0
    sched_param_bytes: float = 0.0
    sched_flop_per_byte: float = 0.0

    @property
    def compute_centric(self) -> bool:
        return self.sched_flop_per_byte >= 81.0 and self.sched_macs >= 20e6


def characterize_layer(model: str, index: int, spec: LayerSpec) -> LayerCharacteristics:
    param_b = max(spec.param_bytes, 1e-9)
    act_b = max(spec.in_act_bytes + spec.out_act_bytes, 1e-9)
    flops = spec.flops
    recurrent = spec.kind in (LayerKind.LSTM, LayerKind.RGLRU, LayerKind.SSM)
    # scheduling-unit: one gate (LSTM) / one step (other recurrences) / the
    # whole layer (feed-forward kinds)
    if spec.kind is LayerKind.LSTM:
        units_space = 4.0                      # 4 gates share the footprint
        units_time = 4.0 * max(spec.seq_len, 1)
    elif recurrent:
        units_space = 1.0
        units_time = float(max(spec.seq_len, 1))
    else:
        units_space = units_time = 1.0
    s_macs = spec.macs / units_time
    s_pb = max(spec.param_bytes / units_space, 1e-9)
    return LayerCharacteristics(
        model=model,
        index=index,
        name=spec.name,
        kind=spec.kind,
        macs=spec.macs,
        flops=flops,
        param_bytes=spec.param_bytes,
        act_bytes=spec.in_act_bytes + spec.out_act_bytes,
        out_act_bytes=spec.out_act_bytes,
        param_flop_per_byte=flops / param_b,
        act_flop_per_byte=flops / act_b,
        recurrent=recurrent,
        sched_macs=s_macs,
        sched_param_bytes=spec.param_bytes / units_space,
        sched_flop_per_byte=2.0 * s_macs / s_pb,
    )


def characterize_model(graph: ModelGraph) -> list[LayerCharacteristics]:
    return [characterize_layer(graph.name, i, l) for i, l in enumerate(graph.layers)]


def characterize_zoo(graphs: list[ModelGraph]) -> list[LayerCharacteristics]:
    out: list[LayerCharacteristics] = []
    for g in graphs:
        out.extend(characterize_model(g))
    return out


# ---------------------------------------------------------------- summaries
def variation_report(chars: list[LayerCharacteristics]) -> dict:
    """Quantify intra-model variation (paper: up to 200x MACs, 244x FLOP/B)."""
    import collections
    by_model: dict[str, list[LayerCharacteristics]] = collections.defaultdict(list)
    for c in chars:
        if c.macs > 0 and c.param_bytes > 1:     # skip norm/pool glue
            by_model[c.model].append(c)
    rep = {}
    for m, cs in by_model.items():
        macs = [c.macs for c in cs]
        fpb = [c.param_flop_per_byte for c in cs]
        foot = [c.param_bytes for c in cs]
        rep[m] = {
            "n_layers": len(cs),
            "mac_variation_x": max(macs) / max(min(macs), 1),
            "flopb_variation_x": max(fpb) / max(min(fpb), 1e-9),
            "footprint_variation_x": max(foot) / max(min(foot), 1e-9),
        }
    return rep
