"""Accelerator configurations — §3 baseline + §5 Mensa designs + §7 comparison points.

All design points come straight from the paper:
  * Baseline Edge TPU: 64x64 PEs, 2 TFLOP/s peak, 4 MB param + 2 MB act buffers,
    LPDDR4 (32 GB/s).
  * Base+HB: Baseline with 8x bandwidth (256 GB/s).
  * Eyeriss v2: 384 PEs, 192 kB buffers, row-stationary flexible NoC, LPDDR4.
  * Pascal:   32x32 PEs @ 2 TFLOP/s, 128 kB param + 256 kB act, on-chip, LPDDR4.
  * Pavlov:   8x8 PEs @ 128 GFLOP/s, 512 B/PE param RF + 128 kB act, near-data (256 GB/s).
  * Jacquard: 16x16 PEs @ 512 GFLOP/s, 128 kB param + 128 kB act, near-data (256 GB/s).
"""
from __future__ import annotations

from dataclasses import dataclass

KB = 1024
MB = 1024 * 1024
GB = 1024 ** 3


@dataclass(frozen=True)
class AcceleratorConfig:
    name: str
    pe_rows: int
    pe_cols: int
    peak_flops: float              # FLOP/s
    param_buf_bytes: float
    act_buf_bytes: float
    dram_bw: float                 # bytes/s available to this accelerator
    dram_kind: str                 # "lpddr4" | "hbm_internal"
    dataflow: str                  # "output_stationary" | "pascal" | "pavlov"
                                   # | "jacquard" | "row_stationary"
    near_data: bool = False
    dram_latency_s: float = 100e-9  # exposed per dependent fetch

    @property
    def n_pes(self) -> int:
        return self.pe_rows * self.pe_cols

    @property
    def freq_hz(self) -> float:
        # peak = n_pes * 2 FLOP/cycle * freq
        return self.peak_flops / (2 * self.n_pes)


EDGE_TPU = AcceleratorConfig(
    name="baseline", pe_rows=64, pe_cols=64, peak_flops=2e12,
    param_buf_bytes=4 * MB, act_buf_bytes=2 * MB,
    dram_bw=32e9, dram_kind="lpddr4", dataflow="output_stationary")

BASE_HB = AcceleratorConfig(
    name="base_hb", pe_rows=64, pe_cols=64, peak_flops=2e12,
    param_buf_bytes=4 * MB, act_buf_bytes=2 * MB,
    dram_bw=256e9, dram_kind="lpddr4", dataflow="output_stationary")

EYERISS_V2 = AcceleratorConfig(
    name="eyeriss_v2", pe_rows=16, pe_cols=24, peak_flops=307.2e9,
    param_buf_bytes=96 * KB, act_buf_bytes=96 * KB,
    dram_bw=32e9, dram_kind="lpddr4", dataflow="row_stationary")

PASCAL = AcceleratorConfig(
    name="pascal", pe_rows=32, pe_cols=32, peak_flops=2e12,
    param_buf_bytes=128 * KB, act_buf_bytes=256 * KB,
    dram_bw=32e9, dram_kind="lpddr4", dataflow="pascal")

PAVLOV = AcceleratorConfig(
    name="pavlov", pe_rows=8, pe_cols=8, peak_flops=128e9,
    param_buf_bytes=64 * 512, act_buf_bytes=128 * KB,   # 512 B private RF per PE
    dram_bw=256e9, dram_kind="hbm_internal", dataflow="pavlov",
    near_data=True, dram_latency_s=40e-9)

JACQUARD = AcceleratorConfig(
    name="jacquard", pe_rows=16, pe_cols=16, peak_flops=512e9,
    param_buf_bytes=128 * KB, act_buf_bytes=128 * KB,
    dram_bw=256e9, dram_kind="hbm_internal", dataflow="jacquard",
    near_data=True, dram_latency_s=40e-9)

MENSA_ACCELERATORS = (PASCAL, PAVLOV, JACQUARD)


# ----------------------------------------------------------------- host chips
# Level-B Mensa maps execution strategies onto a TPU pod instead of the
# paper's edge ASICs; these are the datacenter-chip magnitudes its analytic
# cost models (core/strategy.py) and the roofline bench divide by.  Every
# peak-FLOPS / bandwidth / byte-budget constant in the repo lives either
# here or in configs/ — jitlint's config-literal rule (JL002) enforces it.
@dataclass(frozen=True)
class HostChipConfig:
    """A datacenter accelerator chip as the analytic cost models see it."""
    name: str
    peak_flops: float          # bf16 FLOP/s per chip
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per ICI link
    hbm_budget: float          # usable bytes/chip for params + optimizer


TPU_V5E = HostChipConfig(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                         ici_bw=50e9, hbm_budget=12e9)

# cluster -> designated Mensa accelerator (paper §5.2)
CLUSTER_TO_ACCELERATOR = {1: PASCAL, 2: PASCAL, 3: PAVLOV, 4: JACQUARD, 5: JACQUARD}


def by_name(name: str) -> AcceleratorConfig:
    for a in (EDGE_TPU, BASE_HB, EYERISS_V2, PASCAL, PAVLOV, JACQUARD):
        if a.name == name:
            return a
    raise KeyError(name)
