"""The Mensa two-phase runtime scheduler (§4.2).

Phase 1 — isolation mapping: each layer goes to the accelerator designated for
its cluster (driver configuration knowledge: cluster characteristics + which
accelerator serves which cluster).  A cost-based mode (`policy="cost"`) instead
argmins an energy-delay product per layer, which is useful for ablations.

Phase 2 — communication-aware remap: walking the DAG in topological order,
each node is priced once against the full set of its in-edges.  For every
candidate accelerator (the node's current one plus each distinct predecessor
accelerator) the cost is the node's layer cost on that candidate plus the
transfer cost (DRAM round-trip of the edge activation) of every in-edge whose
predecessor sits elsewhere; the node lands on the cheapest candidate.  Cost =
energy-delay product, the same heuristic currency as phase 1.  (Aggregating
all in-edges per node — rather than greedily per edge — keeps multi-
predecessor nodes from flipping accelerators repeatedly while ignoring the
transfer cost of their other in-edges.)
"""
from __future__ import annotations

from dataclasses import dataclass

from .accelerators import (AcceleratorConfig, CLUSTER_TO_ACCELERATOR,
                           MENSA_ACCELERATORS)
from .characterize import characterize_model
from .clustering import rule_cluster
from .costmodel import layer_cost, schedule_cost, ScheduleCost
from .energy import DEFAULT_ENERGY, EnergyParams
from .layerspec import ModelGraph


@dataclass
class MensaSchedule:
    model: str
    mapping: list[AcceleratorConfig]
    clusters: list[int]
    phase1_mapping: list[AcceleratorConfig]
    n_remapped: int = 0

    def accelerator_names(self) -> list[str]:
        return [a.name for a in self.mapping]


def _edp(latency_s: float, energy_j: float) -> float:
    return latency_s * energy_j


class MensaScheduler:
    """Schedules a ModelGraph onto a set of heterogeneous accelerators."""

    def __init__(self, accelerators: tuple[AcceleratorConfig, ...] = MENSA_ACCELERATORS,
                 cluster_map: dict[int, AcceleratorConfig] | None = None,
                 energy: EnergyParams = DEFAULT_ENERGY,
                 policy: str = "cluster"):
        self.accelerators = accelerators
        self.cluster_map = cluster_map or dict(CLUSTER_TO_ACCELERATOR)
        self.energy = energy
        if policy not in ("cluster", "cost"):
            raise ValueError(policy)
        self.policy = policy

    # ------------------------------------------------------------- phase 1
    def phase1(self, graph: ModelGraph) -> tuple[list[AcceleratorConfig], list[int]]:
        chars = characterize_model(graph)
        clusters = [rule_cluster(c).cluster for c in chars]
        mapping: list[AcceleratorConfig] = []
        for spec, cl in zip(graph.layers, clusters):
            if self.policy == "cluster":
                acc = self.cluster_map[cl]
                if acc not in self.accelerators:          # restricted systems
                    acc = self._best_by_cost(spec)
            else:
                acc = self._best_by_cost(spec)
            mapping.append(acc)
        return mapping, clusters

    def _best_by_cost(self, spec) -> AcceleratorConfig:
        best, best_c = None, float("inf")
        for acc in self.accelerators:
            c = layer_cost(spec, acc, self.energy)
            v = _edp(c.latency_s, c.energy.total)
            if v < best_c:
                best, best_c = acc, v
        assert best is not None
        return best

    # ------------------------------------------------------------- phase 2
    def phase2(self, graph: ModelGraph,
               mapping: list[AcceleratorConfig]) -> tuple[list[AcceleratorConfig], int]:
        ep = self.energy
        graph.validate()      # the walk below relies on edges having s < d
        out = list(mapping)
        n_moved = 0
        preds: dict[int, list[int]] = {}
        for (s, d) in graph.edges:
            preds.setdefault(d, []).append(s)

        def node_edp(d: int, acc: AcceleratorConfig) -> float:
            """EDP of layer d on `acc`, including every in-edge transfer."""
            c = layer_cost(graph.layers[d], acc, ep)
            t_xfer, e_xfer = 0.0, 0.0
            for p in preds[d]:
                if out[p].name == acc.name:
                    continue
                edge_bytes = graph.layers[p].out_act_bytes
                bw = min(out[p].dram_bw, acc.dram_bw)
                t_xfer += 2 * edge_bytes / bw
                e_xfer += edge_bytes * (ep.e_dram(out[p].dram_kind)
                                        + ep.e_dram(acc.dram_kind))
            return _edp(c.latency_s + t_xfer, c.energy.total + e_xfer)

        # edges are topologically ordered (s < d), so walking nodes in index
        # order always sees each predecessor's final placement first
        for d in range(len(graph.layers)):
            if d not in preds:
                continue
            keep = out[d]
            best_acc, best_v = keep, node_edp(d, keep)
            seen = {keep.name}
            for p in preds[d]:
                cand = out[p]
                if cand.name in seen:
                    continue
                seen.add(cand.name)
                v = node_edp(d, cand)
                if v < best_v:
                    best_acc, best_v = cand, v
            if best_acc.name != keep.name:
                out[d] = best_acc
                n_moved += 1
        return out, n_moved

    # ------------------------------------------------------------- driver
    def schedule(self, graph: ModelGraph) -> MensaSchedule:
        p1, clusters = self.phase1(graph)
        p2, moved = self.phase2(graph, p1)
        return MensaSchedule(graph.name, p2, clusters, p1, moved)

    def evaluate(self, graph: ModelGraph) -> ScheduleCost:
        sched = self.schedule(graph)
        return schedule_cost(graph, sched.mapping, self.accelerators, self.energy)
