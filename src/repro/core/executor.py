"""Executor — applies a MensaPlan to the concrete launch configuration.

``plan_for_cell`` derives the Mensa strategy plan for an (arch x shape) cell;
``execution_profile`` turns it into the knobs the launcher understands:

  * ``strategy``      — the global sharding profile ("tp" | "dp"): phase-2 of
    the TPU-level scheduler collapses to one batch layout per program when
    every compute-heavy block class agrees (mixing batch layouts inside one
    step would reshard the residual stream every block — exactly the case the
    paper's phase 2 exists to veto).
  * ``cfg_overrides`` — per-cluster execution options chosen by measurement
    (§Perf): remat off under DP (activations fit), scatter MoE dispatch,
    block-diagonal RG-LRU gates.

This is the production entry point: `launch/dryrun.py --auto` and the
examples call through here, so the paper's technique — characterize ->
cluster -> schedule -> execute — is what actually configures every program
we lower.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..configs.shapes import ShapeSpec
from ..models.model_config import ArchConfig
from .strategy import MensaPlan, MeshShape, plan


# overrides that change only how a program lowers, never parameter shapes —
# the serving engine shares one param tree across its prefill/decode programs,
# so per-phase profiles may apply only these
RUNTIME_SAFE_KEYS = frozenset({
    "remat", "moe_impl", "unroll_scans", "scan_chunk", "attn_block_kv",
    "attn_f32", "attn_impl", "rglru_impl", "ssm_impl",
})


@dataclass(frozen=True)
class ExecutionProfile:
    arch: str
    shape: str
    strategy: str                    # "tp" | "dp"
    cfg_overrides: dict = field(default_factory=dict)
    plan: MensaPlan | None = None

    def apply(self, cfg: ArchConfig, *, runtime_only: bool = False
              ) -> ArchConfig:
        ov = self.cfg_overrides
        if runtime_only:
            ov = {k: v for k, v in ov.items() if k in RUNTIME_SAFE_KEYS}
        return cfg.replace(**ov) if ov else cfg


def plan_for_cell(cfg: ArchConfig, shape: ShapeSpec,
                  mesh: MeshShape = MeshShape()) -> MensaPlan:
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    return plan(cfg, tokens=tokens, batch=shape.global_batch,
                train=(shape.kind == "train"), mesh=mesh,
                shape_name=shape.name)


def execution_profile(cfg: ArchConfig, shape: ShapeSpec,
                      mesh: MeshShape = MeshShape()) -> ExecutionProfile:
    p = plan_for_cell(cfg, shape, mesh)
    # phase-2 collapse: one batch layout per program.  DP only when every
    # compute-heavy block class independently picked pascal_dp.
    heavy = [b for b in p.blocks if b.name in ("attn", "ffn", "moe", "rec",
                                               "ssm")]
    all_dp = heavy and all(b.strategy == "pascal_dp" for b in heavy)
    strategy = "dp" if all_dp else "tp"

    overrides: dict = {}
    if strategy == "dp" and shape.kind == "train":
        # measured (§Perf cell 1): DP activations fit; drop remat recompute
        overrides["remat"] = False
    if cfg.ffn_kind == "moe" and shape.kind == "train":
        # measured (§Perf cell 3): scatter dispatch cuts the compute term 35x
        overrides["moe_impl"] = "scatter"
    if cfg.d_rnn and cfg.d_rnn % (mesh.model or 1) == 0:
        # measured (§Perf cell 2): same collectives, -6% C/M, 16x fewer
        # gate params, faithful to Griffin's block-diagonal design
        overrides["rglru_gate_blocks"] = mesh.model
    return ExecutionProfile(cfg.name, shape.name, strategy, overrides, p)


def phase_profiles(cfg: ArchConfig,
                   prefill_shape: ShapeSpec | None = None,
                   decode_shape: ShapeSpec | None = None,
                   mesh: MeshShape = MeshShape(),
                   policy=None,
                   ) -> tuple[ExecutionProfile, ExecutionProfile]:
    """Per-phase serving profiles: prefill lowers compute-centric (Pascal
    cluster), decode memory-centric (Jacquard/Pavlov clusters).  The serving
    engine builds one jitted program per phase from these.

    ``policy`` (a ``serve.placement.PlacementPlan``, duck-typed so core stays
    import-independent of serve) merges the oracle's per-phase kernel-variant
    overrides into each profile; every merged key must be runtime-safe."""
    from ..configs.shapes import SHAPES
    pre = execution_profile(cfg, prefill_shape or SHAPES["prefill_32k"], mesh)
    dec = execution_profile(cfg, decode_shape or SHAPES["decode_32k"], mesh)
    if policy is not None:
        for extra in (policy.prefill_cfg_overrides, policy.decode_cfg_overrides):
            bad = set(extra) - RUNTIME_SAFE_KEYS
            if bad:
                raise ValueError(f"policy overrides {sorted(bad)} are not "
                                 "runtime-safe")
        pre = ExecutionProfile(
            pre.arch, pre.shape, pre.strategy,
            {**pre.cfg_overrides, **policy.prefill_cfg_overrides}, pre.plan)
        dec = ExecutionProfile(
            dec.arch, dec.shape, dec.strategy,
            {**dec.cfg_overrides, **policy.decode_cfg_overrides}, dec.plan)
    return pre, dec
