"""Mensa core: layer characterization, clustering, heterogeneous-accelerator cost
models, and the two-phase scheduler (paper §3-§5), plus the TPU-level execution
strategy layer (DESIGN.md §2 Level B)."""
from .accelerators import (BASE_HB, CLUSTER_TO_ACCELERATOR, EDGE_TPU, EYERISS_V2,
                           JACQUARD, MENSA_ACCELERATORS, PASCAL, PAVLOV,
                           AcceleratorConfig, by_name)
from .characterize import (LayerCharacteristics, characterize_layer,
                           characterize_model, characterize_zoo, variation_report)
from .clustering import (ClusterAssignment, agreement, cluster_all, kmeans_cluster,
                         rule_cluster, strict_fraction)
from .costmodel import LayerCost, ScheduleCost, layer_cost, monolithic_cost, \
    schedule_cost
from .energy import DEFAULT_ENERGY, EnergyBreakdown, EnergyParams
from .layerspec import LayerKind, LayerSpec, ModelGraph
from .mensa import ModelResult, ZooSummary, evaluate_model, evaluate_zoo, summarize
from .scheduler import MensaSchedule, MensaScheduler

__all__ = [
    "AcceleratorConfig", "BASE_HB", "CLUSTER_TO_ACCELERATOR", "EDGE_TPU",
    "EYERISS_V2", "JACQUARD", "MENSA_ACCELERATORS", "PASCAL", "PAVLOV", "by_name",
    "LayerCharacteristics", "characterize_layer", "characterize_model",
    "characterize_zoo", "variation_report",
    "ClusterAssignment", "agreement", "cluster_all", "kmeans_cluster",
    "rule_cluster", "strict_fraction",
    "LayerCost", "ScheduleCost", "layer_cost", "monolithic_cost", "schedule_cost",
    "DEFAULT_ENERGY", "EnergyBreakdown", "EnergyParams",
    "LayerKind", "LayerSpec", "ModelGraph",
    "ModelResult", "ZooSummary", "evaluate_model", "evaluate_zoo", "summarize",
    "MensaSchedule", "MensaScheduler",
]
