"""Latency + energy cost of running one layer on one accelerator, and of a whole
schedule — the paper's in-house simulator distilled to its analytical core.

Latency (roofline with overlap, §3.1 Fig.1): compute and DRAM transfer overlap,
so  t = max(t_compute, t_mem) + t_exposed  where t_exposed is dependent-fetch
latency that cannot be hidden (recurrent layers on the baseline scheduler).

Energy: see ``energy.py``.  Static energy is charged for the *whole system's*
accelerators over total inference latency (idle accelerators still leak).
"""
from __future__ import annotations

from dataclasses import dataclass

from .accelerators import AcceleratorConfig
from .dataflow import ExecutionProfile, profile
from .energy import DEFAULT_ENERGY, EnergyBreakdown, EnergyParams
from .layerspec import LayerSpec, ModelGraph


@dataclass(frozen=True)
class LayerCost:
    accelerator: str
    latency_s: float
    compute_s: float
    mem_s: float
    energy: EnergyBreakdown      # static excluded here; added at schedule level
    attained_flops: float
    utilization: float           # attained / accelerator peak
    prof: ExecutionProfile


def layer_cost(spec: LayerSpec, acc: AcceleratorConfig,
               ep: EnergyParams = DEFAULT_ENERGY) -> LayerCost:
    p = profile(spec, acc)
    flops = spec.flops
    eff = p.eff_map * p.eff_sched
    t_comp = flops / (acc.peak_flops * eff) if flops else 0.0
    t_mem = p.offchip_bytes / (acc.dram_bw * p.bw_efficiency)
    t = max(t_comp, t_mem) + p.exposed_latency_s
    t = max(t, 1e-12)

    e_pe = flops * ep.e_flop
    e_bp = (p.buf_param_reads * ep.e_sram(acc.param_buf_bytes)
            + p.buf_param_stream * ep.e_sram(min(acc.param_buf_bytes, 256 * 1024)))
    e_ba = p.buf_act_accesses * ep.e_sram(acc.act_buf_bytes)
    e_noc = p.noc_bytes * ep.e_noc
    e_dram = p.offchip_bytes * ep.e_dram(acc.dram_kind)
    energy = EnergyBreakdown(e_pe, e_bp, e_ba, e_noc, e_dram, 0.0)

    attained = flops / t
    return LayerCost(acc.name, t, t_comp, t_mem, energy, attained,
                     attained / acc.peak_flops, p)


@dataclass(frozen=True)
class ScheduleCost:
    """Aggregate cost of running `graph` under a layer->accelerator mapping."""
    model: str
    latency_s: float
    energy: EnergyBreakdown
    flops: int
    transfer_bytes: float
    per_layer: list[LayerCost]
    stage_time_s: float = 0.0   # max per-accelerator busy time (pipeline stage)

    @property
    def throughput_flops(self) -> float:
        """Steady-state inference throughput: successive inferences pipeline
        across the heterogeneous accelerators (each accelerator processes a
        different inference), so throughput is bounded by the busiest stage —
        the reason the paper's throughput gain (3.1x) exceeds its single-
        inference latency gain (1.96x)."""
        return self.flops / max(self.stage_time_s or self.latency_s, 1e-12)

    @property
    def efficiency_flops_per_j(self) -> float:
        return self.flops / max(self.energy.total, 1e-30)


def schedule_cost(graph: ModelGraph, mapping: list[AcceleratorConfig],
                  system_accels: tuple[AcceleratorConfig, ...],
                  ep: EnergyParams = DEFAULT_ENERGY,
                  transfer_bw: float | None = None) -> ScheduleCost:
    """Cost of executing `graph` with layer i on mapping[i].

    * Layers execute sequentially in topological order (the paper does not
      pipeline across layers).
    * When consecutive layers run on different accelerators, the activation is
      synchronized through DRAM (§4.2): one write + one read of the edge bytes,
      at the slower accelerator's DRAM energy/bandwidth.
    * Static energy = sum(static power of every accelerator in the system) x
      total latency.
    """
    assert len(mapping) == len(graph.layers)
    costs = [layer_cost(spec, acc, ep) for spec, acc in zip(graph.layers, mapping)]
    latency = sum(c.latency_s for c in costs)
    energy = EnergyBreakdown(0, 0, 0, 0, 0, 0)
    for c in costs:
        energy = energy + c.energy

    transfer_bytes = 0.0
    for (s, d) in graph.edges:
        if mapping[s].name != mapping[d].name:
            bytes_moved = graph.layers[s].out_act_bytes
            transfer_bytes += bytes_moved
            bw = transfer_bw or min(mapping[s].dram_bw, mapping[d].dram_bw)
            latency += 2 * bytes_moved / bw
            e_kind_w = mapping[s].dram_kind
            e_kind_r = mapping[d].dram_kind
            energy = energy + EnergyBreakdown(
                0, 0, 0, 0,
                bytes_moved * (ep.e_dram(e_kind_w) + ep.e_dram(e_kind_r)), 0)

    static_p = sum(ep.static_power(a) for a in system_accels)
    energy = energy + EnergyBreakdown(0, 0, 0, 0, 0, static_p * latency)
    busy: dict[str, float] = {}
    for c in costs:
        busy[c.accelerator] = busy.get(c.accelerator, 0.0) + c.latency_s
    stage = max(busy.values()) if busy else latency
    return ScheduleCost(graph.name, latency, energy, graph.total_flops,
                        transfer_bytes, costs, stage_time_s=stage)


def monolithic_cost(graph: ModelGraph, acc: AcceleratorConfig,
                    ep: EnergyParams = DEFAULT_ENERGY) -> ScheduleCost:
    """Whole model on a single accelerator (Baseline / Base+HB / Eyeriss v2)."""
    return schedule_cost(graph, [acc] * len(graph.layers), (acc,), ep)
