"""Level-B Mensa: the two-phase scheduler operating on TPU execution
strategies instead of physical accelerators (DESIGN.md §2).

Every block class of an architecture is characterized with the SAME
machinery as the edge models (LayerSpec -> cluster), then assigned an
execution strategy template:

  * ``pascal_tp``  — Megatron tensor parallelism on the `model` axis
    (compute-centric clusters 1/2: big matmuls, high reuse).
  * ``pascal_dp``  — pure data parallelism, params replicated, batch sharded
    over every mesh axis (when the layer's parallel dims don't divide the
    model axis — e.g. 9 attention heads on a 16-way axis — TP replicates
    compute and DP is strictly better).
  * ``jacquard_shard`` — weight-stationary sharding for huge low-reuse tables
    (vocab embeddings, MoE expert banks): weights sharded on `model`, never
    gathered; tokens move instead.
  * ``pavlov_seq`` — recurrent layers: width on `model`, sequence local,
    weights resident across the scan.

Phase 1 picks per block class by an analytic v5e cost model (compute /
memory / collective terms).  Phase 2 walks adjacent block classes and merges
strategies when the resharding (layout-change) collective cost exceeds the
in-place efficiency loss — the paper's §4.2 algorithm with "activation
transfer through DRAM" replaced by "resharding collective on ICI".
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..models.model_config import ArchConfig
from .accelerators import TPU_V5E
from .characterize import characterize_layer
from .clustering import rule_cluster
from .layerspec import LayerKind, LayerSpec

# v5e constants (per chip) — magnitudes live in core/accelerators.py (JL002)
PEAK_FLOPS = TPU_V5E.peak_flops
HBM_BW = TPU_V5E.hbm_bw
ICI_BW = TPU_V5E.ici_bw
BYTES = 2.0  # bf16


@dataclass(frozen=True)
class MeshShape:
    data: int = 16
    model: int = 16

    @property
    def devices(self) -> int:
        return self.data * self.model


@dataclass
class BlockClassPlan:
    name: str                  # "attn", "ffn", "moe", "rec", "ssm", "embed"
    cluster: int               # Mensa cluster id (1..5)
    strategy: str              # chosen strategy template
    candidates: dict = field(default_factory=dict)   # strategy -> est. seconds
    reason: str = ""


@dataclass
class MensaPlan:
    arch: str
    shape: str
    blocks: list[BlockClassPlan]
    phase2_merges: list[str] = field(default_factory=list)

    def strategy_for(self, name: str) -> str:
        for b in self.blocks:
            if b.name == name:
                return b.strategy
        return "pascal_tp"

    def summary(self) -> str:
        lines = [f"MensaPlan[{self.arch} x {self.shape}]"]
        for b in self.blocks:
            cand = ", ".join(f"{k}={v*1e3:.2f}ms" for k, v in
                             sorted(b.candidates.items(), key=lambda kv: kv[1]))
            lines.append(f"  {b.name:8s} cluster={b.cluster} -> {b.strategy}"
                         f"  ({cand})  {b.reason}")
        for m in self.phase2_merges:
            lines.append(f"  phase2: {m}")
        return "\n".join(lines)


def _block_specs(cfg: ArchConfig, tokens: int, batch: int) -> list[tuple[str, LayerSpec]]:
    """One LayerSpec per distinct block class (per-layer granularity, bf16)."""
    B = dict(bytes_per_param=BYTES, bytes_per_act=BYTES, batch=batch)
    seq = max(tokens // max(batch, 1), 1)
    out: list[tuple[str, LayerSpec]] = []
    kinds = set(cfg.layer_kinds)
    if kinds & {"attn", "local", "dec", "enc"}:
        out.append(("attn", LayerSpec(
            name="attn", kind=LayerKind.ATTENTION, hidden=cfg.d_model,
            heads=cfg.num_heads, kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, seq_len=seq,
            window=cfg.window, in_features=cfg.d_model, **B)))
    if cfg.ffn_kind in ("glu", "mlp"):
        out.append(("ffn", LayerSpec(
            name="ffn", kind=LayerKind.FC, in_features=cfg.d_model,
            out_features=3 * cfg.d_ff if cfg.ffn_kind == "glu" else 2 * cfg.d_ff,
            **{**B, "batch": tokens})))
    if cfg.ffn_kind == "moe":
        out.append(("moe", LayerSpec(
            name="moe", kind=LayerKind.MOE, in_features=cfg.d_model,
            hidden=cfg.d_ff, experts=cfg.num_experts, top_k=cfg.top_k,
            seq_len=seq, **B)))
    if "rec" in kinds:
        out.append(("rec", LayerSpec(
            name="rec", kind=LayerKind.RGLRU, in_features=cfg.d_model,
            hidden=cfg.d_rnn, seq_len=seq, **B)))
    if "ssm" in kinds:
        out.append(("ssm", LayerSpec(
            name="ssm", kind=LayerKind.SSM, in_features=cfg.d_model,
            hidden=cfg.d_inner, state=cfg.d_state, seq_len=seq, **B)))
    out.append(("embed", LayerSpec(
        name="embed", kind=LayerKind.EMBEDDING, vocab=cfg.vocab_padded,
        out_features=cfg.d_model, seq_len=seq, **B)))
    return out


HBM_BUDGET = TPU_V5E.hbm_budget   # usable bytes/chip for params+optimizer


def _ring_allreduce_wire(bytes_per_participant: float, group: int) -> float:
    """Per-device wire bytes of a ring all-reduce (RS + AG)."""
    return 2.0 * bytes_per_participant * (group - 1) / max(group, 1)


def _est_strategy_cost(name: str, spec: LayerSpec, strat: str,
                       mesh: MeshShape, train: bool,
                       layers_of_class: int = 1) -> float | None:
    """Per-layer step-time estimate (seconds) under a strategy. None = illegal
    (indivisible dims or out of HBM budget)."""
    flops = spec.flops * (3.0 if train else 1.0)      # bwd ~ 2x fwd
    n = mesh.devices
    tokens = spec.batch * max(spec.seq_len, 1)
    # block OUTPUT activation (d_model wide) — what inter-block collectives move
    block_out = tokens * max(spec.in_features, 1) * BYTES
    # per-parameter HBM bytes: bf16 weights; training adds fp32 master+m+v
    pmem_mult = 6.0 if train else 1.0

    def t(compute_shards, comm_bytes_per_dev, param_shards):
        tc = flops / compute_shards / PEAK_FLOPS
        tm = (spec.param_bytes / param_shards
              + (spec.in_act_bytes + spec.out_act_bytes) / n) / HBM_BW
        tx = comm_bytes_per_dev / ICI_BW
        return max(tc, tm) + tx

    if strat == "pascal_tp":
        if spec.kind is LayerKind.ATTENTION and spec.heads % mesh.model:
            # heads don't divide: GSPMD replicates the attention core over
            # `model`; only projections shard. Model as compute over data only.
            shards = mesh.data
        else:
            shards = n
        # megatron pair: 2 output all-reduces per layer fwd (x2 with bwd),
        # over the model axis, on data-sharded activations
        ar = _ring_allreduce_wire(block_out / mesh.data, mesh.model)
        comm = (4 if train else 2) * ar
        return t(shards, comm, param_shards=n)
    if strat == "pascal_dp":
        if tokens < n:
            return None                       # not enough batch to shard
        if spec.param_bytes * pmem_mult * layers_of_class > HBM_BUDGET:
            return None                       # replicated params do not fit
        comm = _ring_allreduce_wire(2 * spec.param_bytes, n) if train else 0.0
        return t(n, comm, param_shards=1)
    if strat == "jacquard_shard":
        if spec.kind is LayerKind.MOE:
            if spec.experts % mesh.model:
                return None
            # all-to-all token dispatch on the model axis, in + combine
            comm = 2 * (block_out / n) * spec.top_k
            if train:
                comm *= 2
            return t(n, comm, param_shards=n)
        if spec.kind is LayerKind.EMBEDDING:
            # vocab-sharded: masked local lookup + all-reduce of outputs
            comm = _ring_allreduce_wire(block_out / mesh.data, mesh.model)
            return t(n, comm, param_shards=n)
        return None
    if strat == "pavlov_seq":
        if spec.kind not in (LayerKind.RGLRU, LayerKind.SSM, LayerKind.LSTM):
            return None
        if spec.hidden % mesh.model:
            return None
        # width on model, batch on data; one gate psum per layer
        ar = _ring_allreduce_wire(block_out / mesh.data, mesh.model)
        comm = (2 if train else 1) * ar
        return t(n, comm, param_shards=n)
    return None


_CANDIDATES = {
    "attn": ("pascal_tp", "pascal_dp"),
    "ffn": ("pascal_tp", "pascal_dp"),
    "moe": ("jacquard_shard", "pascal_dp"),
    "rec": ("pavlov_seq", "pascal_dp"),
    "ssm": ("pavlov_seq", "pascal_dp"),
    "embed": ("jacquard_shard", "pascal_dp"),
}


def plan(cfg: ArchConfig, *, tokens: int, batch: int, train: bool,
         mesh: MeshShape = MeshShape(), shape_name: str = "") -> MensaPlan:
    blocks = []
    n_layers = max(cfg.num_layers, 1)
    for name, spec in _block_specs(cfg, tokens, batch):
        chars = characterize_layer(cfg.name, 0, spec)
        cluster = rule_cluster(chars).cluster
        cands = {}
        for strat in _CANDIDATES[name]:
            c = _est_strategy_cost(name, spec, strat, mesh, train,
                                   layers_of_class=n_layers
                                   if name != "embed" else 1)
            if c is not None:
                cands[strat] = c
        best = min(cands, key=cands.get)
        reason = ""
        if name == "attn" and cfg.num_heads % mesh.model:
            reason = (f"{cfg.num_heads} heads do not divide model={mesh.model}"
                      f" -> TP replicates attention compute")
        blocks.append(BlockClassPlan(name, cluster, best, cands, reason))

    plan_ = MensaPlan(cfg.name, shape_name, blocks)
    # ---- phase 2: unify adjacent strategies when resharding dominates
    # adjacent pairs execute once per layer; a layout change moves the whole
    # activation (all-to-all ~ act_bytes/devices per device).
    act_bytes = tokens * cfg.d_model * BYTES
    reshard_s = (act_bytes / mesh.devices) / ICI_BW
    by_name = {b.name: b for b in blocks}
    order = [k for k in ("attn", "ffn", "moe", "rec", "ssm") if k in by_name]
    for a, b in zip(order, order[1:]):
        pa, pb = by_name[a], by_name[b]
        la = pa.strategy.split("_")[-1]
        lb = pb.strategy.split("_")[-1]
        if (pa.strategy == "pascal_dp") != (pb.strategy == "pascal_dp"):
            # batch-layout change between blocks: price it
            keep = pb.candidates[pb.strategy] + 2 * reshard_s
            move = pb.candidates.get(pa.strategy)
            if move is not None and move < keep:
                plan_.phase2_merges.append(
                    f"{b}: {pb.strategy} -> {pa.strategy} "
                    f"(reshard {2 * reshard_s * 1e3:.2f}ms dominates)")
                pb.strategy = pa.strategy
    return plan_
