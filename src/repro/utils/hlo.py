"""Post-SPMD HLO analysis: collective-traffic extraction for the roofline.

Parses ``compiled.as_text()`` and sums operand/result sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Wire-byte conventions (ring algorithms over an n-device group):
  all-gather:          out_bytes * (n-1)/n        per participant
  reduce-scatter:      in_bytes  * (n-1)/n
  all-reduce:          2 * bytes * (n-1)/n        (RS + AG)
  all-to-all:          bytes * (n-1)/n
  collective-permute:  bytes
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.1 = bf16[2,4096,1024]{2,1,0} all-gather(bf16[...] %x), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = DTYPE_BYTES.get(dtype, 4)
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return float(n * b)


@dataclass
class CollectiveStats:
    # per-kind totals, already converted to wire bytes per participant
    wire_bytes: dict = field(default_factory=lambda: defaultdict(float))
    result_bytes: dict = field(default_factory=lambda: defaultdict(float))
    counts: dict = field(default_factory=lambda: defaultdict(int))

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def to_dict(self) -> dict:
        return {
            "wire_bytes": dict(self.wire_bytes),
            "result_bytes": dict(self.result_bytes),
            "counts": dict(self.counts),
            "total_wire_bytes": self.total_wire_bytes,
        }


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        kind = kind.replace("-start", "")
        out_bytes = _shape_bytes(dtype, dims)
        n = max(_group_size(line, default_group), 1)
        ring = (n - 1) / n if n > 1 else 0.0
        if kind == "all-reduce":
            wire = 2 * out_bytes * ring
        elif kind == "all-gather":
            wire = out_bytes * ring
        elif kind == "reduce-scatter":
            wire = out_bytes * (n - 1)   # in_bytes*(n-1)/n; in = out*n
        elif kind == "all-to-all":
            wire = out_bytes * ring
        else:  # collective-permute
            wire = out_bytes
        stats.wire_bytes[kind] += wire
        stats.result_bytes[kind] += out_bytes
        stats.counts[kind] += 1
    return stats


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


#: the CompiledMemoryStats fields the normalized view carries (device-side
#: sizes first; ``peak_memory_in_bytes`` exists only on some backends)
MEMORY_FIELDS = (
    "argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes",
    "alias_size_in_bytes", "generated_code_size_in_bytes",
    "peak_memory_in_bytes",
)


def normalize_memory_analysis(mem) -> dict:
    """Flatten ``compiled.memory_analysis()`` across JAX versions.

    The return shape drifts like ``cost_analysis()``'s: ``None`` on backends
    without the analysis, a ``CompiledMemoryStats`` object on current JAX, a
    plain dict on some, a list with one entry per executable program on
    others.  Returns one flat ``{field: int_bytes}`` dict over
    :data:`MEMORY_FIELDS`, summing across programs; absent fields are
    omitted, never invented as zeros."""
    if mem is None:
        return {}
    entries = mem if isinstance(mem, (list, tuple)) else [mem]
    out: dict = {}
    for entry in entries:
        if entry is None:
            continue
        get = entry.get if isinstance(entry, dict) \
            else lambda k, e=entry: getattr(e, k, None)
        for key in MEMORY_FIELDS:
            val = get(key)
            if val is None:
                continue
            out[key] = out.get(key, 0) + int(val)
    return out


def normalize_cost_analysis(cost) -> dict:
    """Flatten ``compiled.cost_analysis()`` across JAX versions.

    Older JAX returns one dict; newer JAX returns a list with one dict per
    executable program.  Returns a single flat dict, summing numeric values
    across programs (non-numeric values keep the first occurrence)."""
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    out: dict = {}
    for entry in cost:
        for key, val in (entry or {}).items():
            try:
                out[key] = out.get(key, 0.0) + float(val)
            except (TypeError, ValueError):
                out.setdefault(key, val)
    return out
