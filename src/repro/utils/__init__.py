"""repro.utils"""
