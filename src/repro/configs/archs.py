"""The 10 assigned architecture configs (exact dims from the assignment table)
plus ``reduced_config`` for CPU smoke tests.

Sources ([source; verified-tier] per assignment):
  recurrentgemma-2b   [arXiv:2402.19427; hf]   hybrid RG-LRU + local attn, 1:2
  qwen3-0.6b          [hf:Qwen/Qwen3-8B; hf]   qk_norm, GQA
  starcoder2-7b       [arXiv:2402.19173; hf]   GQA, RoPE, layernorm+MLP
  smollm-135m         [hf:HuggingFaceTB/SmolLM-135M; hf]  llama-arch small
  qwen2-0.5b          [arXiv:2407.10671; hf]   GQA, QKV bias
  internvl2-2b        [arXiv:2404.16821; hf]   InternViT stub + InternLM2
  phi3.5-moe-42b      [hf:microsoft/Phi-3.5-MoE-instruct; hf]  16e top-2
  llama4-scout-17b    [hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 16e top-1
  seamless-m4t-medium [arXiv:2308.11596; hf]   enc-dec, audio-frontend stub
  falcon-mamba-7b     [arXiv:2410.05355; unverified]  mamba1, attn-free
"""
from __future__ import annotations


from ..models.model_config import ArchConfig

RECURRENTGEMMA_2B = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    block_pattern=("rec", "rec", "local"),   # 1 attn : 2 recurrent
    ffn_kind="glu", activation="gelu", norm="rms",
    window=2048, d_rnn=2560, d_conv=4,
    rope_theta=10000.0, tie_embeddings=True)

QWEN3_0_6B = ArchConfig(
    name="qwen3-0.6b", family="dense",
    num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=3072, vocab_size=151936,
    ffn_kind="glu", activation="silu", norm="rms", qk_norm=True,
    rope_theta=1000000.0, tie_embeddings=True)

STARCODER2_7B = ArchConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4, head_dim=128,
    d_ff=18432, vocab_size=49152,
    ffn_kind="mlp", activation="gelu", norm="layer", qkv_bias=True,
    rope_theta=100000.0, tie_embeddings=True)

SMOLLM_135M = ArchConfig(
    name="smollm-135m", family="dense",
    num_layers=30, d_model=576, num_heads=9, num_kv_heads=3, head_dim=64,
    d_ff=1536, vocab_size=49152,
    ffn_kind="glu", activation="silu", norm="rms",
    rope_theta=10000.0, tie_embeddings=True)

QWEN2_0_5B = ArchConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151936,
    ffn_kind="glu", activation="silu", norm="rms", qkv_bias=True,
    rope_theta=1000000.0, tie_embeddings=True)

INTERNVL2_2B = ArchConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92553,
    ffn_kind="glu", activation="silu", norm="rms",
    rope_theta=1000000.0, tie_embeddings=False,
    modality_tokens=256, modality_dim=1024)   # InternViT patch embeds (stub)

PHI35_MOE = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    ffn_kind="moe", num_experts=16, top_k=2, activation="silu", norm="layer",
    rope_theta=10000.0, tie_embeddings=False)

LLAMA4_SCOUT = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    ffn_kind="moe", num_experts=16, top_k=1, moe_shared_expert=True,
    activation="silu", norm="rms",
    rope_theta=500000.0, tie_embeddings=False)

SEAMLESS_M4T_MEDIUM = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    block_pattern=("dec",), enc_layers=12,
    ffn_kind="mlp", activation="relu", norm="layer",
    rope_theta=10000.0, tie_embeddings=True,
    modality_tokens=0, modality_dim=1024)     # encoder takes frame embeds

FALCON_MAMBA_7B = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=65024,
    block_pattern=("ssm",), ffn_kind="none",
    d_inner=8192, d_state=16, d_conv=4, dt_rank=256,
    tie_embeddings=True)

ARCHS: dict[str, ArchConfig] = {c.name: c for c in [
    RECURRENTGEMMA_2B, QWEN3_0_6B, STARCODER2_7B, SMOLLM_135M, QWEN2_0_5B,
    INTERNVL2_2B, PHI35_MOE, LLAMA4_SCOUT, SEAMLESS_M4T_MEDIUM,
    FALCON_MAMBA_7B]}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(name: str) -> ArchConfig:
    """Same family/topology, tiny dims — for CPU smoke tests.  Keeps every
    structural feature (pattern, GQA ratio, qk-norm, biases, MoE top-k,
    shared expert, enc-dec, modality stub) while shrinking width/depth."""
    c = get_config(name)
    pat = len(c.block_pattern)
    layers = max(pat + (1 if c.num_layers % pat else 0), 2 * pat) \
        if pat > 1 else 2
    if c.num_layers % pat:
        layers = pat + (c.num_layers % pat)      # exercise the tail path
    kw = dict(
        num_layers=layers,
        d_model=64,
        d_ff=128 if c.d_ff else 0,
        vocab_size=512,
        scan_chunk=16,
        attn_block_kv=32,
        window=16 if c.window else 0,
        remat=False,
    )
    if c.num_heads:
        # keep the GQA ratio
        ratio = max(1, c.num_heads // max(c.num_kv_heads, 1))
        kw["num_kv_heads"] = 2 if c.num_kv_heads > 1 else 1
        kw["num_heads"] = kw["num_kv_heads"] * ratio
        kw["head_dim"] = 16
    if c.d_rnn:
        kw["d_rnn"] = 64
    if c.d_inner:
        kw["d_inner"] = 128
        kw["d_state"] = 4
        kw["dt_rank"] = 8
    if c.num_experts:
        kw["num_experts"] = 4
        kw["top_k"] = min(c.top_k, 2)
        # capacity >= all tokens: no drops, so decode == forward exactly
        kw["moe_capacity"] = 4.0 / kw["top_k"]
    if c.enc_layers:
        kw["enc_layers"] = 2
    if c.modality_tokens:
        kw["modality_tokens"] = 8
        kw["modality_dim"] = 32
    if c.is_encdec:
        kw["modality_dim"] = 64                   # frame embeds at d_model
    return c.replace(**kw)
