"""Config registry: ``get_config(arch_id)`` for every assigned architecture."""
from __future__ import annotations

from .archs import ARCHS, get_config, reduced_config
from .shapes import ALL_SHAPES, SHAPES, ShapeSpec, applicable

__all__ = ["ARCHS", "get_config", "reduced_config", "ALL_SHAPES", "SHAPES",
           "ShapeSpec", "applicable"]
