"""The assigned input-shape sets (LM-family: seq_len x global_batch).

``train_4k`` lowers ``train_step``;  ``prefill_32k`` lowers a full-sequence
prefill; ``decode_32k`` / ``long_500k`` lower ``serve_step`` (one new token
against a KV cache / recurrent state of the given length).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def applicable(arch_cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs; decode only
    for archs with a decoder (all of ours have one)."""
    if shape.name == "long_500k" and not arch_cfg.sub_quadratic:
        return False, "SKIP(full-attention): 512k dense KV cache is quadratic-cost"
    return True, ""
