"""Model assembly: decoder-only LMs, hybrid (RG-LRU + local attention), SSM,
MoE, encoder-decoder, and VLM backbones — all from one block vocabulary.

Layer stacking uses ``jax.lax.scan`` over repeating block groups (the config's
``block_pattern``) so HLO size and compile time stay bounded at 64 layers.  A
tail of ``num_layers % len(pattern)`` blocks continues the pattern cycle
outside the scan (e.g. RecurrentGemma's 26 = 8x(rec,rec,local) + rec,rec).

Three entry points per model:
  * ``forward``      — full-sequence logits (training / prefill-as-scoring).
  * ``loss``         — next-token cross-entropy (+ MoE aux losses).
  * ``prefill`` / ``decode_step`` — KV-cache/recurrent-state serving path.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import ffn as ffn_lib
from . import moe as moe_lib
from . import recurrent as rec_lib
from .common import (cross_entropy_loss, embed, fan_in_init, init_embedding,
                     layer_norm, rms_norm, unembed)
from .model_config import ArchConfig

PyTree = Any


# ----------------------------------------------------------------------- norms
def _norm(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def _init_norm(cfg: ArchConfig, dim: int, dtype) -> dict:
    if cfg.norm == "rms":
        return {"scale": jnp.zeros((dim,), dtype)}
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


# ----------------------------------------------------------------- block init
def _init_block(cfg: ArchConfig, kind: str, key, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": _init_norm(cfg, cfg.d_model, dtype)}
    if kind in ("attn", "local", "dec", "enc"):
        p["attn"] = attn_lib.init_attention(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=dtype)
        if kind == "dec":
            p["ln_x"] = _init_norm(cfg, cfg.d_model, dtype)
            p["xattn"] = attn_lib.init_attention(
                ks[3], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.head_dim, dtype=dtype)
    elif kind == "rec":
        p["rec"] = rec_lib.init_rglru_block(
            ks[0], cfg.d_model, cfg.d_rnn, conv_width=cfg.d_conv,
            gate_blocks=cfg.rglru_gate_blocks, dtype=dtype)
    elif kind == "ssm":
        p["ssm"] = rec_lib.init_mamba_block(
            ks[0], cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv,
            cfg.dt_rank or None, dtype=dtype)
        return p                                  # Mamba block has no FFN
    else:
        raise ValueError(kind)
    if cfg.ffn_kind == "glu":
        p["ln2"] = _init_norm(cfg, cfg.d_model, dtype)
        p["ffn"] = ffn_lib.init_glu_ffn(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif cfg.ffn_kind == "mlp":
        p["ln2"] = _init_norm(cfg, cfg.d_model, dtype)
        p["ffn"] = ffn_lib.init_mlp_ffn(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif cfg.ffn_kind == "moe":
        p["ln2"] = _init_norm(cfg, cfg.d_model, dtype)
        p["ffn"] = moe_lib.init_moe(ks[1], cfg.d_model, cfg.d_ff,
                                    cfg.num_experts,
                                    shared_expert=cfg.moe_shared_expert,
                                    dtype=dtype)
    return p


# ---------------------------------------------------------------- block apply
class BlockState(NamedTuple):
    """Per-block serving state (exactly one of the fields is populated)."""
    kv: attn_lib.KVCache | None = None
    rec: dict | None = None            # {"conv": ..., "h": ...}
    cross_kv: tuple | None = None      # (k, v) from encoder memory


def _attn_ffn_tail(cfg, p, x):
    """Returns (x, load_balance_aux) — aux flows through scan carries."""
    h = _norm(cfg, p["ln2"], x)
    if cfg.ffn_kind == "moe":
        y, moe_aux = moe_lib.moe_ffn(p["ffn"], h, top_k=cfg.top_k,
                                     capacity_factor=cfg.moe_capacity,
                                     activation=cfg.activation,
                                     return_aux=True, impl=cfg.moe_impl)
        return x + y, moe_aux["load_balance"]
    zero = jnp.zeros((), jnp.float32)
    if cfg.ffn_kind == "glu":
        return x + ffn_lib.glu_ffn(p["ffn"], h, cfg.activation), zero
    if cfg.ffn_kind == "mlp":
        return x + ffn_lib.mlp_ffn(p["ffn"], h, cfg.activation), zero
    return x, zero


def apply_block(cfg: ArchConfig, kind: str, p: dict, x: jax.Array,
                positions: jax.Array, *,
                mode: str = "train",
                state: BlockState | None = None,
                memory: jax.Array | None = None,
                length: jax.Array | None = None,
                offset: jax.Array | None = None,
                block_table: jax.Array | None = None,
                gather_spec=None,
                ) -> tuple[jax.Array, BlockState | None, jax.Array]:
    """One residual block. mode: train|prefill|decode.
    ``length``: (B,) valid prefix lengths for right-padded prefill — serving
    states then reflect position length-1, not S-1.  In decode mode a 0/1
    ``length`` acts as an activity mask: rows with length 0 leave all state
    (KV append, conv context, recurrent h) unchanged.
    ``offset``: (B,) tokens already consumed when this prefill call resumes a
    chunked prompt — attention continues against the cache, recurrences
    continue from the carried state (zeroed where offset == 0).
    ``block_table``: (B, max_len/bs) physical block ids when this block's KV
    cache is paged (state.kv is a PagedKVCache) — one table shared by every
    paged layer.
    ``gather_spec``: optional NamedSharding for the paged ops' gathered
    (B, S, KVH, hd) K/V — set when the block pool is sharded over a mesh so
    the cross-shard gather lands in the slot layout once (see
    attention.gather_paged_kv).
    Returns (x, new_state, load_balance_aux)."""
    new_state = state
    lb = jnp.zeros((), jnp.float32)
    paged = state is not None and isinstance(state.kv, attn_lib.PagedKVCache)
    if kind in ("attn", "local", "dec", "enc"):
        h = _norm(cfg, p["ln1"], x)
        q, k, v = attn_lib.qkv_project(
            p["attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            positions, rope_theta=cfg.rope_theta, use_rope=(kind != "enc"))
        if mode == "decode":
            wm = None if length is None else length > 0
            if paged:
                out, kv = attn_lib.paged_decode_attention(
                    q, k, v, state.kv, block_table, write_mask=wm,
                    gather_spec=gather_spec, impl=cfg.attn_impl)
            else:
                out, kv = attn_lib.decode_attention(
                    q, k, v, state.kv,
                    window=cfg.window if kind == "local" else 0,
                    write_mask=wm)
            new_state = state._replace(kv=kv)
        elif mode == "prefill" and offset is not None:
            if kind not in ("attn", "local"):
                raise NotImplementedError(
                    "chunked prefill supports decoder-only self-attention")
            if paged:
                out, kv = attn_lib.paged_chunk_attention(
                    q, k, v, state.kv, block_table, offset=offset,
                    length=length, gather_spec=gather_spec)
            else:
                out, kv = attn_lib.chunk_attention(
                    q, k, v, state.kv, offset=offset, length=length,
                    window=cfg.window if kind == "local" else 0)
            new_state = state._replace(kv=kv)
        elif kind == "local":
            if q.shape[1] % cfg.window == 0:
                out = attn_lib.local_attention(q, k, v, window=cfg.window)
            else:  # short prompts: flash with a window mask (same math)
                out = attn_lib.flash_attention(q, k, v, causal=True,
                                               window=cfg.window,
                                               block_kv=cfg.attn_block_kv,
                                               unroll=cfg.unroll_scans,
                                               impl=cfg.attn_impl)
        elif kind == "enc":
            out = attn_lib.flash_attention(q, k, v, causal=False,
                                           block_kv=cfg.attn_block_kv,
                                           unroll=cfg.unroll_scans,
                                           f32_probs=cfg.attn_f32,
                                           impl=cfg.attn_impl)
        else:
            out = attn_lib.flash_attention(q, k, v, causal=True,
                                           block_kv=cfg.attn_block_kv,
                                           unroll=cfg.unroll_scans,
                                           f32_probs=cfg.attn_f32,
                                           impl=cfg.attn_impl)
        if mode == "prefill" and offset is None \
                and kind in ("attn", "local", "dec"):
            if paged:
                kv = attn_lib.paged_fill_cache(state.kv, k, v, block_table,
                                               length=length)
            else:
                kv = _fill_cache(state.kv, k, v, window=cfg.window
                                 if kind == "local" else 0, length=length)
            new_state = state._replace(kv=kv)
        b, s, _, _ = out.shape
        o = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
        x = x + jnp.einsum("bsh,hd->bsd", o, p["attn"]["wo"].astype(x.dtype))
        if kind == "dec":
            hx = _norm(cfg, p["ln_x"], x)
            qx, _, _ = attn_lib.qkv_project(
                p["xattn"], hx, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                positions, rope_theta=cfg.rope_theta, use_rope=False)
            if state is not None and state.cross_kv is not None:
                ck, cv = state.cross_kv
            else:
                _, ck, cv = attn_lib.qkv_project(
                    p["xattn"], memory, cfg.num_heads, cfg.num_kv_heads,
                    cfg.head_dim, jnp.zeros(memory.shape[:2], jnp.int32),
                    rope_theta=cfg.rope_theta, use_rope=False)
            xo = attn_lib.flash_attention(qx, ck, cv, causal=False,
                                          block_kv=cfg.attn_block_kv)
            b, s, _, _ = xo.shape
            xo = xo.reshape(b, s, cfg.num_heads * cfg.head_dim)
            x = x + jnp.einsum("bsh,hd->bsd", xo,
                               p["xattn"]["wo"].astype(x.dtype))
        x, lb = _attn_ffn_tail(cfg, p, x)
    elif kind == "rec":
        h = _norm(cfg, p["ln1"], x)
        if mode == "train":
            x = x + rec_lib.rglru_block(p["rec"], h, chunk=cfg.scan_chunk,
                                        unroll=cfg.unroll_scans,
                                        impl=cfg.rglru_impl)
        else:
            y, rec_state = rec_lib.rglru_block(
                p["rec"], h, chunk=min(cfg.scan_chunk, h.shape[1]),
                state=_resume_rec(state.rec, offset), return_state=True,
                length=length, impl=cfg.rglru_impl)
            x = x + y
            new_state = state._replace(rec=rec_state)
        x, lb = _attn_ffn_tail(cfg, p, x)
    elif kind == "ssm":
        h = _norm(cfg, p["ln1"], x)
        if mode == "train":
            x = x + rec_lib.mamba_block(p["ssm"], h, d_state=cfg.d_state,
                                        dt_rank=cfg.dt_rank or None,
                                        chunk=cfg.scan_chunk,
                                        unroll=cfg.unroll_scans,
                                        impl=cfg.ssm_impl)
        else:
            y, rec_state = rec_lib.mamba_block(
                p["ssm"], h, d_state=cfg.d_state,
                dt_rank=cfg.dt_rank or None,
                chunk=min(cfg.scan_chunk, h.shape[1]),
                state=_resume_rec(state.rec, offset), return_state=True,
                length=length)
            x = x + y
            new_state = state._replace(rec=rec_state)
    else:
        raise ValueError(kind)
    return x, new_state, lb


def _resume_rec(rec: dict | None, offset: jax.Array | None) -> dict | None:
    """Carried conv/recurrent state for a (possibly resumed) prefill chunk.
    A slot being prefilled from scratch (offset == 0) may hold a previous
    request's residue — zero it per row; offset > 0 rows continue theirs."""
    if rec is None or offset is None:
        return rec
    live = offset > 0
    return {k: jnp.where(live.reshape((-1,) + (1,) * (a.ndim - 1)),
                         a, jnp.zeros_like(a))
            for k, a in rec.items()}


def _fill_cache(cache: attn_lib.KVCache, k, v, window: int = 0,
                length: jax.Array | None = None):
    """Write prefill K/V into the cache (left-aligned; ring for local).

    ``length``: (B,) valid prefix lengths for right-padded prefill.  Entries
    past ``length`` may hold padding garbage: they sit at cache positions that
    decode overwrites before its validity mask ever admits them, so they are
    never attended to."""
    b, s = k.shape[0], k.shape[1]
    smax = cache.k.shape[1]
    if length is not None and window:
        # ring layout: slot j must hold the last real position p < length with
        # p % smax == j (garbage slots are masked/overwritten downstream)
        j = jnp.arange(smax)[None, :]
        p = (length[:, None] - 1) - ((length[:, None] - 1 - j) % smax)
        p = jnp.clip(p, 0, s - 1)
        ck = jnp.take_along_axis(k, p[:, :, None, None], axis=1)
        cv = jnp.take_along_axis(v, p[:, :, None, None], axis=1)
        return attn_lib.KVCache(ck.astype(cache.k.dtype),
                                cv.astype(cache.v.dtype),
                                cache.length + length)
    if window and s > smax:
        k, v = k[:, -smax:], v[:, -smax:]
        s = smax
    ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                      (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                      (0, 0, 0, 0))
    new_len = cache.length + (s if length is None else length)
    return attn_lib.KVCache(ck, cv, new_len)


# ------------------------------------------------------------------- the model
class Model:
    """Bundles init/forward/loss/prefill/decode for one ArchConfig."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.pattern = cfg.block_pattern
        self.n_groups = cfg.num_layers // len(self.pattern)
        self.tail_kinds = tuple(
            self.pattern[i % len(self.pattern)]
            for i in range(self.n_groups * len(self.pattern), cfg.num_layers))

    # ------------------------------------------------------------------- init
    def init(self, key) -> PyTree:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        keys = jax.random.split(key, 8)
        params: dict = {
            "embed": init_embedding(keys[0], cfg.vocab_padded, cfg.d_model,
                                    dtype),
            "final_norm": _init_norm(cfg, cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init_embedding(keys[1], cfg.vocab_padded,
                                               cfg.d_model, dtype)
        # scanned groups: one stacked tree per pattern position
        group_params = {}
        for j, kind in enumerate(self.pattern):
            if self.n_groups > 0:
                ks = jax.random.split(jax.random.fold_in(keys[2], j),
                                      self.n_groups)
                group_params[str(j)] = jax.vmap(
                    lambda k: _init_block(cfg, kind, k, dtype))(ks)
        params["groups"] = group_params
        params["tail"] = [
            _init_block(cfg, kind, jax.random.fold_in(keys[3], i), dtype)
            for i, kind in enumerate(self.tail_kinds)]
        if cfg.is_encdec:
            ks = jax.random.split(keys[4], cfg.enc_layers)
            params["encoder"] = jax.vmap(
                lambda k: _init_block(cfg, "enc", k, dtype))(ks)
            params["enc_norm"] = _init_norm(cfg, cfg.d_model, dtype)
        if cfg.modality_tokens:
            k1, k2 = jax.random.split(keys[5])
            params["mm_proj"] = {
                "w1": fan_in_init(k1, (cfg.modality_dim, cfg.d_model), dtype),
                "w2": fan_in_init(k2, (cfg.d_model, cfg.d_model), dtype),
            }
        return params

    # ------------------------------------------------------------- embeddings
    def _embed_inputs(self, params, tokens, modality=None):
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        x = embed(params["embed"], tokens, dt) * jnp.sqrt(
            jnp.asarray(cfg.d_model, dt))
        if modality is not None and cfg.modality_tokens:
            m = modality.astype(dt)
            m = jnp.einsum("bmd,de->bme", m, params["mm_proj"]["w1"].astype(dt))
            m = jax.nn.gelu(m, approximate=True)
            m = jnp.einsum("bme,ef->bmf", m, params["mm_proj"]["w2"].astype(dt))
            x = jnp.concatenate([m, x], axis=1)
        return x

    # -------------------------------------------------------------- backbone
    def _run_stack(self, params, x, positions, memory=None):
        """Returns (x, total_load_balance_aux)."""
        cfg = self.cfg

        def group_fn(x, gp):
            lb_sum = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(self.pattern):
                x, _, lb = apply_block(cfg, kind, gp[str(j)], x, positions,
                                       mode="train", memory=memory)
                lb_sum = lb_sum + lb
            return x, lb_sum

        if cfg.remat:
            group_fn = jax.checkpoint(group_fn,
                                      policy=jax.checkpoint_policies.nothing_saveable)
        lb_total = jnp.zeros((), jnp.float32)
        if self.n_groups > 0:
            if cfg.unroll_scans:
                for gi in range(self.n_groups):
                    gp = jax.tree.map(lambda a, gi=gi: a[gi], params["groups"])
                    x, lb = group_fn(x, gp)
                    lb_total = lb_total + lb
            else:
                def scan_step(carry, gp):
                    x, lb_acc = carry
                    x, lb = group_fn(x, gp)
                    return (x, lb_acc + lb), None
                (x, lb_total), _ = jax.lax.scan(scan_step, (x, lb_total),
                                                params["groups"])
        for p_t, kind in zip(params["tail"], self.tail_kinds):
            x, _, lb = apply_block(cfg, kind, p_t, x, positions,
                                   mode="train", memory=memory)
            lb_total = lb_total + lb
        return x, lb_total

    def _encode(self, params, src_embeds):
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        x = src_embeds.astype(dt)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                     x.shape[:2])

        def enc_fn(x, p):
            x, _, _ = apply_block(cfg, "enc", p, x, positions, mode="train")
            return x, None

        if cfg.unroll_scans:
            for li in range(cfg.enc_layers):
                x, _ = enc_fn(x, jax.tree.map(lambda a, li=li: a[li],
                                              params["encoder"]))
        else:
            x, _ = jax.lax.scan(enc_fn, x, params["encoder"])
        return _norm(cfg, params["enc_norm"], x)

    # ---------------------------------------------------------------- forward
    def forward(self, params, tokens, modality=None, src_embeds=None):
        """Full-sequence logits: (B,S) -> (B,S,V) fp32."""
        cfg = self.cfg
        memory = None
        if cfg.is_encdec:
            memory = self._encode(params, src_embeds)
        x = self._embed_inputs(params, tokens, modality)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
        x, lb = self._run_stack(params, x, positions, memory)
        x = _norm(cfg, params["final_norm"], x)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = unembed(x, table)[..., :cfg.vocab_size]
        if cfg.modality_tokens and modality is not None:
            logits = logits[:, modality.shape[1]:]
        aux = {"load_balance": lb} if cfg.ffn_kind == "moe" else {}
        return logits, aux

    # ------------------------------------------------------------------- loss
    def loss(self, params, batch):
        logits, aux = self.forward(
            params, batch["tokens"], batch.get("modality"),
            batch.get("src_embeds"))
        loss = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
        metrics = {"ce_loss": loss}
        if "load_balance" in aux:
            lb = aux["load_balance"] / max(self.cfg.num_layers, 1)
            loss = loss + 0.01 * lb
            metrics["load_balance"] = lb
        metrics["loss"] = loss
        return loss, metrics

    # ----------------------------------------------------------- serving path
    def init_states(self, batch: int, max_len: int, *,
                    kv_block_size: int | None = None,
                    kv_blocks: int | None = None,
                    shardings: PyTree | None = None) -> PyTree:
        """Stacked per-group states + tail states for the serving path.

        ``shardings``: optional pytree of ``NamedSharding`` mirroring the
        returned structure (``launch.shardings.serve_state_specs`` builds it)
        — the states are placed onto the mesh before returning, so a
        mesh-aware engine never round-trips the full dense pool through a
        single device.

        ``kv_block_size``/``kv_blocks``: when set, full-attention layers
        ("attn"/"dec" self-attention) store KV as a PAGED pool of
        ``kv_blocks`` blocks of ``kv_block_size`` tokens, addressed through a
        per-slot block table passed to prefill/decode_step.  Sliding-window
        layers keep their dense ring (already right-sized at ``window``
        tokens — the Mensa lesson of per-layer-class memory organization) and
        recurrent/SSM layers keep their fixed-size state."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        if kv_block_size is not None and kv_blocks is None:
            kv_blocks = batch * (-(-max_len // kv_block_size))

        def one(kind):
            if kind in ("attn", "dec"):
                if kv_block_size is not None:
                    kv = attn_lib.init_paged_kv_cache(
                        batch, kv_blocks, kv_block_size, cfg.num_kv_heads,
                        cfg.head_dim, dt)
                else:
                    kv = attn_lib.init_kv_cache(batch, max_len,
                                                cfg.num_kv_heads,
                                                cfg.head_dim, dt)
                return BlockState(kv=kv)
            if kind == "local":
                kv = attn_lib.init_kv_cache(batch, min(max_len, cfg.window),
                                            cfg.num_kv_heads, cfg.head_dim, dt)
                return BlockState(kv=kv)
            if kind == "rec":
                return BlockState(rec={
                    "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_rnn), dt),
                    "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32)})
            if kind == "ssm":
                return BlockState(rec={
                    "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dt),
                    "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state),
                                   jnp.float32)})
            raise ValueError(kind)

        groups = {}
        for j, kind in enumerate(self.pattern):
            if self.n_groups > 0:
                groups[str(j)] = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (self.n_groups,) + a.shape).copy(), one(kind))
        out = {"groups": groups,
               "tail": [one(k) for k in self.tail_kinds]}
        if shardings is not None:
            out = jax.device_put(out, shardings)
        return out

    def _run_stack_serving(self, params, states, x, positions, mode,
                           memory=None, length=None, offset=None,
                           block_table=None, gather_spec=None):
        cfg = self.cfg

        def group_fn(x, gp_state):
            gp, gstate = gp_state
            new_states = {}
            for j, kind in enumerate(self.pattern):
                x, ns, _ = apply_block(cfg, kind, gp[str(j)], x, positions,
                                       mode=mode, state=gstate[str(j)],
                                       memory=memory, length=length,
                                       offset=offset, block_table=block_table,
                                       gather_spec=gather_spec)
                new_states[str(j)] = ns
            return x, new_states

        if self.n_groups > 0:
            if cfg.unroll_scans:
                outs = []
                for gi in range(self.n_groups):
                    gp_state = jax.tree.map(
                        lambda a, gi=gi: a[gi],
                        (params["groups"], states["groups"]))
                    x, ns = group_fn(x, gp_state)
                    outs.append(ns)
                new_group_states = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *outs)
            else:
                def scan_step(x, gp_state):
                    x, ns = group_fn(x, gp_state)
                    return x, ns
                x, new_group_states = jax.lax.scan(
                    scan_step, x, (params["groups"], states["groups"]))
        else:
            new_group_states = states["groups"]
        new_tail = []
        for p_t, st, kind in zip(params["tail"], states["tail"],
                                 self.tail_kinds):
            x, ns, _ = apply_block(cfg, kind, p_t, x, positions,
                                   mode=mode, state=st, memory=memory,
                                   length=length, offset=offset,
                                   block_table=block_table,
                                   gather_spec=gather_spec)
            new_tail.append(ns)
        return x, {"groups": new_group_states, "tail": new_tail}

    def prefill(self, params, tokens, states, modality=None, src_embeds=None,
                length=None, offset=None, block_table=None,
                gather_spec=None):
        """Process the prompt; fill caches; return last-position logits.

        ``length``: optional (B,) int32 valid prompt lengths for RIGHT-padded
        ``tokens`` (the bucketed serving path: pad every prompt to a shared
        bucket size so one compiled program serves all lengths in the bucket).
        Causal masking keeps real positions exact under right padding; the
        recurrent/SSM state updates freeze past ``length`` and caches record
        ``length`` (not S), so decode continues from the true prompt end.
        Logits are taken at position length-1 per row.

        ``offset``: optional (B,) int32 — ``tokens`` is one CHUNK of a longer
        prompt whose first ``offset`` tokens already live in ``states``
        (vLLM-style chunked prefill).  Attention resumes against the cache,
        recurrent/conv state continues from the carry (zeroed per row where
        offset == 0, so a recycled slot starts clean), and logits land at
        chunk position length-1.  Requires ``length``; decoder-only token
        models only.

        ``block_table``: (B, max_len/bs) int32, required when the states were
        built with ``init_states(kv_block_size=...)`` — paged layers write
        (and, for chunked continuation, read) their KV through it.

        ``gather_spec``: optional NamedSharding (or ``batch -> sharding``
        callable) for the paged ops' gathered K/V — a mesh-aware engine
        passes its layout here per call; the model itself stays stateless."""
        cfg = self.cfg
        memory = None
        if offset is not None:
            if length is None:
                raise ValueError("chunked prefill (offset=...) needs length")
            if cfg.is_encdec or cfg.modality_tokens:
                raise NotImplementedError(
                    "chunked prefill supports decoder-only token models")
        if cfg.is_encdec:
            memory = self._encode(params, src_embeds)
        x = self._embed_inputs(params, tokens, modality)
        base = jnp.arange(x.shape[1])[None]
        positions = jnp.broadcast_to(base, x.shape[:2]) if offset is None \
            else offset[:, None] + base
        x, states = self._run_stack_serving(params, states, x, positions,
                                            "prefill", memory, length, offset,
                                            block_table, gather_spec)
        x = _norm(cfg, params["final_norm"], x)
        if length is None:
            x_last = x[:, -1:]
        else:
            idx = jnp.clip(length - 1, 0)[:, None, None]
            x_last = jnp.take_along_axis(
                x, jnp.broadcast_to(idx, (x.shape[0], 1, x.shape[2])), axis=1)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = unembed(x_last, table)[..., :cfg.vocab_size]
        return logits, states, memory

    def decode_step(self, params, token, states, position, memory=None,
                    active=None, block_table=None, gather_spec=None):
        """token: (B,1) -> logits (B,1,V), updated states.

        ``active``: optional (B,) bool — False rows leave every piece of
        per-slot state (KV append + cache length, conv context, recurrent h)
        bit-for-bit unchanged and produce garbage logits, so an engine can
        tick a pool containing dead or mid-prefill slots without corrupting
        them.  Active rows are bitwise identical to active=None.

        ``block_table``: (B, max_len/bs) int32 for paged states — the new
        token's KV is scattered through it and attention gathers the slot's
        logical sequence from the block pool.

        ``gather_spec``: optional NamedSharding (or ``batch -> sharding``
        callable) routing the gathered K/V onto a mesh (see ``prefill``)."""
        cfg = self.cfg
        x = self._embed_inputs(params, token)
        positions = jnp.broadcast_to(position[:, None], token.shape)
        length = None if active is None else active.astype(jnp.int32)
        x, states = self._run_stack_serving(params, states, x, positions,
                                            "decode", memory, length,
                                            block_table=block_table,
                                            gather_spec=gather_spec)
        x = _norm(cfg, params["final_norm"], x)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = unembed(x, table)[..., :cfg.vocab_size]
        return logits, states


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
