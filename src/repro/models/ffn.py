"""Feed-forward blocks: gated-linear-unit MLPs (SwiGLU/GeGLU) and plain MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ACTIVATIONS, fan_in_init


def init_glu_ffn(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": fan_in_init(k1, (d_model, d_ff), dtype),
        "w_up": fan_in_init(k2, (d_model, d_ff), dtype),
        "w_down": fan_in_init(k3, (d_ff, d_model), dtype),
    }


def glu_ffn(params: dict, x: jax.Array, activation: str = "silu") -> jax.Array:
    dt = x.dtype
    act = ACTIVATIONS[activation]
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt))
    return jnp.einsum("bsf,fd->bsd", act(g) * u, params["w_down"].astype(dt))


def init_mlp_ffn(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": fan_in_init(k1, (d_model, d_ff), dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": fan_in_init(k2, (d_ff, d_model), dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def mlp_ffn(params: dict, x: jax.Array, activation: str = "gelu") -> jax.Array:
    dt = x.dtype
    act = ACTIVATIONS[activation]
    h = act(jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(dt))
            + params["b_in"].astype(dt))
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(dt)) \
        + params["b_out"].astype(dt)
