"""Attention: GQA projections (+optional bias/qk-norm), RoPE, and three cores:

* ``flash_attention``  — blockwise online-softmax attention (lax.scan over KV
  blocks).  This is the memory-bounded production path: peak live memory is
  O(S x block) instead of O(S^2).  Supports causal + sliding-window masks and
  GQA without materializing repeated KV heads.
* ``local_attention``  — exact sliding-window attention via chunking (each
  chunk attends to itself + the previous chunk with a band mask); cost is
  O(S x 2w) — the sub-quadratic path used by RecurrentGemma.
* ``decode_attention`` — single-token attention against a KV cache.

The Pallas TPU kernel (kernels/flash_attention) implements the same math with
explicit VMEM tiling; these jnp versions are its oracle and the CPU/dry-run
lowering path.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import apply_rope, fan_in_init, rms_norm

NEG_INF = -1e30


# ------------------------------------------------------------------ parameters
def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   qk_norm: bool = False, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": fan_in_init(ks[0], (d_model, num_heads * head_dim), dtype),
        "wk": fan_in_init(ks[1], (d_model, num_kv_heads * head_dim), dtype),
        "wv": fan_in_init(ks[2], (d_model, num_kv_heads * head_dim), dtype),
        "wo": fan_in_init(ks[3], (num_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), dtype)
        p["k_norm"] = jnp.zeros((head_dim,), dtype)
    return p


def qkv_project(params: dict, x: jax.Array, num_heads: int, num_kv_heads: int,
                head_dim: int, positions: jax.Array, *, rope_theta: float,
                use_rope: bool = True):
    """x: (B,S,D) -> q (B,S,H,hd), k/v (B,S,KVH,hd), all rotated."""
    b, s, _ = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(b, s, num_heads, head_dim)
    k = k.reshape(b, s, num_kv_heads, head_dim)
    v = v.reshape(b, s, num_kv_heads, head_dim)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


# ------------------------------------------------------- blockwise (flash) core
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_kv: int = 512,
                    q_offset: int | jax.Array = 0,
                    unroll: bool = False,
                    f32_probs: bool = True,
                    impl: str = "xla") -> jax.Array:
    """Online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KVH, hd) with H % KVH == 0.
    Returns (B, Sq, H, hd).  ``q_offset`` is the absolute position of q[0]
    relative to k[0] (for cached prefill continuation).

    ``impl="pallas"`` routes the aligned case (q starts at position 0, the
    shape every bucketed-prefill program compiles) to the fused Pallas flash
    kernel; chunk continuations carry a traced ``q_offset`` and fall back to
    the XLA scan, which lowers to the same math.
    """
    if impl == "pallas" and isinstance(q_offset, int) and q_offset == 0:
        # function-level import: kernels/paged_attention's package init pulls
        # this module back in for its jnp reference oracle
        from ..kernels.flash_attention.ops import flash_attention as _pallas
        return _pallas(q, k, v, causal=causal, window=window)
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    assert h % kvh == 0
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    blocks = max(1, math.ceil(skv / block_kv))
    pad = blocks * block_kv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, blocks, block_kv, kvh, hd)
    vb = v.reshape(b, blocks, block_kv, kvh, hd)

    qg = (q.reshape(b, sq, kvh, g, hd) * scale).astype(jnp.float32)
    q_pos = jnp.arange(sq) + q_offset                       # (Sq,)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, kv_start = blk                          # (B,bk,KVH,hd) x2
        s = jnp.einsum("bqnGd,bknd->bnGqk", qg,
                       kblk.astype(jnp.float32))            # (B,KVH,G,Sq,bk)
        kv_pos = kv_start + jnp.arange(block_kv)            # (bk,)
        mask = kv_pos[None, :] <= skv - 1                   # padding
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        if f32_probs:
            pv = jnp.einsum("bnGqk,bknd->bnGqd", p,
                            vblk.astype(jnp.float32))
        else:
            # bf16 probabilities into the PV matmul (fp32 accumulation):
            # halves the dominant (Sq x block) buffer traffic
            pv = jnp.einsum("bnGqk,bknd->bnGqd", p.astype(jnp.bfloat16),
                            vblk.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, hd), jnp.float32)
    kv_starts = jnp.arange(blocks) * block_kv
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kv_starts),
        unroll=blocks if unroll else 1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, hd)      # (B,Sq,H,hd)
    return out.astype(q.dtype)


# ----------------------------------------------------------- local (sliding) core
def local_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int) -> jax.Array:
    """Exact causal sliding-window attention, O(S*2w) memory.

    Chunks the sequence by ``window``; each chunk attends to itself + previous
    chunk under (causal AND distance<window) masking — exactly the sliding
    window.  q,k,v: (B,S,H|KVH,hd); S % window must be 0 (pad upstream).
    """
    b, s, h, hd = q.shape
    _, _, kvh, _ = k.shape
    g = h // kvh
    assert s % window == 0, "pad sequence to a multiple of the window"
    c = s // window
    scale = 1.0 / math.sqrt(hd)
    qc = (q.reshape(b, c, window, kvh, g, hd) * scale).astype(jnp.float32)
    kc = k.reshape(b, c, window, kvh, hd).astype(jnp.float32)
    vc = v.reshape(b, c, window, kvh, hd).astype(jnp.float32)
    # previous chunk (zero-pad for the first)
    kp = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vp = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    kk = jnp.concatenate([kp, kc], axis=2)                   # (B,c,2w,KVH,hd)
    vv = jnp.concatenate([vp, vc], axis=2)
    scores = jnp.einsum("bcqnGd,bcknd->bcnGqk", qc, kk)      # (B,c,KVH,G,w,2w)
    qpos = jnp.arange(window)[:, None]
    kpos = jnp.arange(2 * window)[None, :] - window
    mask = (kpos <= qpos) & (kpos > qpos - window)
    first_chunk_mask = kpos >= 0                             # no phantom prev
    scores = jnp.where(mask, scores, NEG_INF)
    s_first = jnp.where(first_chunk_mask & mask, scores[:, 0], NEG_INF)
    scores = scores.at[:, 0].set(s_first)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bcnGqk,bcknd->bcqnGd", p, vv)
    return out.reshape(b, s, h, hd).astype(q.dtype)


# -------------------------------------------------------------------- decoding
class KVCache(NamedTuple):
    k: jax.Array        # (B, S_max, KVH, hd)
    v: jax.Array
    length: jax.Array   # (B,) int32 — tokens currently cached


def init_kv_cache(batch: int, max_len: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32))


def decode_attention(q: jax.Array, new_k: jax.Array, new_v: jax.Array,
                     cache: KVCache, *, window: int = 0,
                     write_mask: jax.Array | None = None
                     ) -> tuple[jax.Array, KVCache]:
    """One-token attention against the cache.

    q/new_k/new_v: (B,1,H|KVH,hd).  Appends the new KV at position length[b]
    and attends to all cached positions (optionally only the last `window`).
    ``write_mask``: optional (B,) bool — False rows leave the cache (contents
    and length) untouched, so a batching engine can tick dead or mid-prefill
    slots without corrupting them; their outputs are garbage.
    """
    b, one, h, hd = q.shape
    _, _, kvh, _ = new_k.shape
    g = h // kvh
    smax = cache.k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    idx = cache.length                                           # (B,)
    if window:
        # ring-buffer the window: write at position length % window
        idx = cache.length % jnp.int32(cache.k.shape[1])
    onehot = jax.nn.one_hot(idx, smax, dtype=cache.k.dtype)      # (B,Smax)
    if write_mask is not None:
        onehot = onehot * write_mask.astype(cache.k.dtype)[:, None]
    oh = onehot[:, :, None, None]
    k = cache.k * (1 - oh) + oh * new_k.astype(cache.k.dtype)    # replace slot
    v = cache.v * (1 - oh) + oh * new_v.astype(cache.v.dtype)

    qg = (q.reshape(b, kvh, g, hd) * scale).astype(jnp.float32)
    s = jnp.einsum("bnGd,bknd->bnGk", qg, k.astype(jnp.float32))  # (B,KVH,G,Smax)
    pos = jnp.arange(smax)[None, :]
    valid = pos <= cache.length[:, None]                          # incl. new tok
    if window:
        valid = pos < jnp.minimum(cache.length + 1, window)[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnGk,bknd->bnGd", p, v.astype(jnp.float32))
    out = out.reshape(b, 1, h, hd).astype(q.dtype)
    inc = 1 if write_mask is None else write_mask.astype(jnp.int32)
    return out, KVCache(k=k, v=v, length=cache.length + inc)


# ------------------------------------------------------------------ paged KV
class PagedKVCache(NamedTuple):
    """KV storage as a pool of fixed-size blocks shared by all slots.

    ``k``/``v`` have NO batch axis — they are the layer's global block pool;
    a per-slot *block table* (passed separately, shape ``(B, max_len/bs)``)
    maps logical position ``p`` of slot ``b`` to physical storage
    ``k[table[b, p // bs], p % bs]``.  Table entries >= the pool size mean
    "no block": writes through them are dropped and reads are masked, so one
    compiled program serves every allocation pattern.  Blocks may be shared
    read-only between slots (prefix cache); the host-side allocator
    (serve/kvpool.py) guarantees no two slots ever *write* the same block.
    """
    k: jax.Array        # (N_blocks, block_size, KVH, hd)
    v: jax.Array
    length: jax.Array   # (B,) int32 — tokens currently cached per slot


def init_paged_kv_cache(batch: int, num_blocks: int, block_size: int,
                        kv_heads: int, head_dim: int,
                        dtype=jnp.bfloat16) -> PagedKVCache:
    return PagedKVCache(
        k=jnp.zeros((num_blocks, block_size, kv_heads, head_dim), dtype),
        v=jnp.zeros((num_blocks, block_size, kv_heads, head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32))


def gather_paged_kv(cache: PagedKVCache, block_table: jax.Array,
                    gather_spec=None):
    """Materialize each slot's logical KV sequence through its table row:
    (B, nb*bs, KVH, hd).  Sentinel entries clamp to the last block — their
    positions are always masked by the callers' validity masks.

    ``gather_spec``: optional ``jax.sharding.NamedSharding`` for the gathered
    (B, S, KVH, hd) tensors — or a callable ``batch_size -> sharding | None``
    (the serving programs gather at different batch sizes: the decode step at
    ``slots``, batched prefill at the batch bucket, the chunk continuation at
    1).  When the pool's block axis is sharded over a mesh, the gather
    crosses shards; constraining its output to the *slot* layout (batch on
    the data axes) lets XLA route the cross-shard traffic once here instead
    of re-deciding the layout per consumer — and keeps the downstream
    attention math slot-local."""
    b, nb = block_table.shape
    bs = cache.k.shape[1]
    idx = jnp.minimum(block_table, cache.k.shape[0] - 1)
    ks = cache.k[idx].reshape(b, nb * bs, *cache.k.shape[2:])
    vs = cache.v[idx].reshape(b, nb * bs, *cache.v.shape[2:])
    if callable(gather_spec):
        gather_spec = gather_spec(b)
    if gather_spec is not None:
        ks = jax.lax.with_sharding_constraint(ks, gather_spec)
        vs = jax.lax.with_sharding_constraint(vs, gather_spec)
    return ks, vs


def _scatter_paged(pool: jax.Array, blk: jax.Array, off: jax.Array,
                   vals: jax.Array) -> jax.Array:
    """pool (N,bs,...), blk/off integer index arrays of matching lead shape,
    vals (*blk.shape, ...).  Out-of-range block ids drop the write."""
    return pool.at[blk, off].set(vals.astype(pool.dtype), mode="drop")


def paged_decode_attention(q: jax.Array, new_k: jax.Array, new_v: jax.Array,
                           cache: PagedKVCache, block_table: jax.Array, *,
                           write_mask: jax.Array | None = None,
                           gather_spec=None,
                           impl: str = "xla"
                           ) -> tuple[jax.Array, PagedKVCache]:
    """One-token attention against the paged pool — the paged twin of
    :func:`decode_attention`, bitwise-identical to it on any trace whose
    block table tiles ``max_len`` exactly (nb * bs == Smax).

    q/new_k/new_v: (B,1,H|KVH,hd).  Writes the new KV at logical position
    ``length[b]`` through the block table, then attends over the gathered
    sequence.  ``write_mask``: (B,) bool — False rows drop the write and
    keep their length, exactly like the dense path's masked rows.
    """
    b, one, h, hd = q.shape
    _, _, kvh, _ = new_k.shape
    g = h // kvh
    bs = cache.k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    idx = cache.length                                           # (B,)
    blk = jnp.take_along_axis(block_table, (idx // bs)[:, None], axis=1)[:, 0]
    if write_mask is not None:
        blk = jnp.where(write_mask, blk, jnp.int32(cache.k.shape[0]))
    k_pool = _scatter_paged(cache.k, blk, idx % bs, new_k[:, 0])
    v_pool = _scatter_paged(cache.v, blk, idx % bs, new_v[:, 0])
    new_cache = cache._replace(k=k_pool, v=v_pool)
    inc = 1 if write_mask is None else write_mask.astype(jnp.int32)
    if impl == "pallas" and gather_spec is None:
        # scalar-prefetch gather kernel — no materialized (B,Smax) gather.
        # gather_spec (cross-shard block layouts) stays on the jnp path: the
        # kernel's block-table prefetch assumes the pool's native layout.
        from ..kernels.common import use_interpret
        from ..kernels.paged_attention.kernel import paged_decode_attention_raw
        table = jnp.minimum(block_table,
                            cache.k.shape[0] - 1).astype(jnp.int32)
        out = paged_decode_attention_raw(
            q[:, 0], k_pool, v_pool, table, cache.length.astype(jnp.int32),
            interpret=use_interpret())
        return (out[:, None].astype(q.dtype),
                new_cache._replace(length=cache.length + inc))
    ks, vs = gather_paged_kv(new_cache, block_table,
                             gather_spec)                        # (B,Smax,..)
    smax = ks.shape[1]

    qg = (q.reshape(b, kvh, g, hd) * scale).astype(jnp.float32)
    s = jnp.einsum("bnGd,bknd->bnGk", qg, ks.astype(jnp.float32))
    pos = jnp.arange(smax)[None, :]
    valid = pos <= cache.length[:, None]                         # incl. new tok
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnGk,bknd->bnGd", p, vs.astype(jnp.float32))
    out = out.reshape(b, 1, h, hd).astype(q.dtype)
    return out, new_cache._replace(length=cache.length + inc)


def paged_fill_cache(cache: PagedKVCache, k: jax.Array, v: jax.Array,
                     block_table: jax.Array, *,
                     length: jax.Array | None = None) -> PagedKVCache:
    """Write prefill K/V through the block table (the paged `_fill_cache`).

    k/v: (B,S,KVH,hd) right-padded; only rows < ``length`` are written —
    unlike the dense path there is no garbage-then-overwrite dance, padding
    writes are simply dropped.  Rows whose table entry is the sentinel (e.g.
    batch-bucket padding rows aliasing a real slot) drop every write, so the
    reverse-splice trick isn't needed for the KV part."""
    b, s = k.shape[0], k.shape[1]
    bs = cache.k.shape[1]
    j = jnp.arange(s)
    blk = jnp.take_along_axis(
        block_table, jnp.broadcast_to(j[None, :] // bs, (b, s)), axis=1)
    off = jnp.broadcast_to(j[None, :] % bs, (b, s))
    if length is not None:
        valid = j[None, :] < length[:, None]
        blk = jnp.where(valid, blk, jnp.int32(cache.k.shape[0]))
    k_pool = _scatter_paged(cache.k, blk, off, k)
    v_pool = _scatter_paged(cache.v, blk, off, v)
    new_len = cache.length + (s if length is None else length)
    return PagedKVCache(k_pool, v_pool, new_len)


def paged_chunk_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                          cache: PagedKVCache, block_table: jax.Array, *,
                          offset: jax.Array, length: jax.Array,
                          gather_spec=None
                          ) -> tuple[jax.Array, PagedKVCache]:
    """Chunked-prefill continuation against the paged pool (full causal
    attention only — the paged twin of the ``window == 0`` arm of
    :func:`chunk_attention`).

    q/k/v: (B,C,H|KVH,hd) at absolute positions ``offset + i``; the chunk's
    real rows are written through the table, then every q row attends to its
    full causal horizon over the gathered sequence.  The prefix below
    ``offset`` may live in *shared* blocks (prefix-cache hits): because KV
    depends only on the token prefix, the gathered values are exactly what
    this slot would have computed, so the continuation — and every token
    decoded after it — matches a cold full prefill.
    """
    b, c, h, hd = q.shape
    _, _, kvh, _ = k.shape
    g = h // kvh
    bs = cache.k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    q_pos = offset[:, None] + jnp.arange(c)[None, :]            # (B,C)
    pos = q_pos                                                 # write targets
    blk = jnp.take_along_axis(block_table, pos // bs, axis=1)
    valid = jnp.arange(c)[None, :] < length[:, None]
    blk = jnp.where(valid, blk, jnp.int32(cache.k.shape[0]))
    k_pool = _scatter_paged(cache.k, blk, pos % bs, k)
    v_pool = _scatter_paged(cache.v, blk, pos % bs, v)
    new_cache = cache._replace(k=k_pool, v=v_pool)
    ks, vs = gather_paged_kv(new_cache, block_table,
                             gather_spec)                       # (B,Smax,...)
    smax = ks.shape[1]

    qg = (q.reshape(b, c, kvh, g, hd) * scale).astype(jnp.float32)
    s = jnp.einsum("bqnGd,bknd->bnGqk", qg, ks.astype(jnp.float32))
    mask = jnp.arange(smax)[None, None, :] <= q_pos[:, :, None]  # (B,C,Smax)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnGqk,bknd->bnGqd", p, vs.astype(jnp.float32))
    out = jnp.moveaxis(out, 3, 1).reshape(b, c, h, hd)
    return out.astype(q.dtype), new_cache._replace(length=offset + length)


# ---------------------------------------------------- chunked prefill (resume)
def chunk_attention(q: jax.Array, k: jax.Array, v: jax.Array, cache: KVCache,
                    *, offset: jax.Array, length: jax.Array, window: int = 0
                    ) -> tuple[jax.Array, KVCache]:
    """Attention for one prefill chunk resuming from a cache at ``offset``.

    q/k/v: (B,C,H|KVH,hd) projected at absolute positions ``offset + i``;
    ``length``: (B,) valid (non-padding) tokens in this right-padded chunk;
    ``offset``: (B,) tokens already cached (the ``q_offset`` of row 0).  The
    chunk's real K/V are written into the cache — left-aligned at ``offset``
    for full attention, ring slots for sliding-window — and every real q row
    attends to its full causal (and window) horizon, exactly as if the whole
    prompt had been prefilled in one call.  Rows past ``length`` produce
    garbage outputs that callers mask downstream.  Returns
    (out (B,C,H,hd), cache with length = offset + length).

    One compiled program serves every chunk of every prompt: offset/length
    are traced, the chunk width C is the only shape.
    """
    b, c, h, hd = q.shape
    _, _, kvh, _ = k.shape
    g = h // kvh
    smax = cache.k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    q_pos = offset[:, None] + jnp.arange(c)[None, :]            # (B,C)
    qg = (q.reshape(b, c, kvh, g, hd) * scale).astype(jnp.float32)
    new_len = offset + length

    def gather_chunk(src, arr, dtype):
        i = jnp.clip(src, 0, c - 1)
        return jnp.take_along_axis(arr.astype(dtype), i[:, :, None, None],
                                   axis=1)

    if not window:
        # write chunk rows < length at cache positions offset..offset+length-1
        # (stale entries past new_len stay, masked until overwritten — the
        # same invariant _fill_cache documents)
        j = jnp.arange(smax)[None, :]                           # (1,Smax)
        src = j - offset[:, None]                               # (B,Smax)
        in_chunk = (src >= 0) & (src < length[:, None])
        m4 = in_chunk[:, :, None, None]
        ck = jnp.where(m4, gather_chunk(src, k, cache.k.dtype), cache.k)
        cv = jnp.where(m4, gather_chunk(src, v, cache.v.dtype), cache.v)
        s = jnp.einsum("bqnGd,bknd->bnGqk", qg,
                       ck.astype(jnp.float32))                  # (B,KVH,G,C,Smax)
        mask = j[:, None, :] <= q_pos[:, :, None]               # (B,C,Smax)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bnGqk,bknd->bnGqd", p, cv.astype(jnp.float32))
        out = jnp.moveaxis(out, 3, 1).reshape(b, c, h, hd)
        return out.astype(q.dtype), KVCache(ck, cv, new_len)

    # sliding window over a ring of W slots: attend over (prior ring ++ chunk)
    # BEFORE writing, because the chunk overwrites ring slots whose old
    # occupants are still inside early q rows' windows
    W = smax
    jw = jnp.arange(W)[None, :]                                  # (1,W)
    # ring slot j holds the last position p < offset with p % W == j
    p_prior = (offset[:, None] - 1) - ((offset[:, None] - 1 - jw) % W)
    chunk_valid = jnp.arange(c)[None, :] < length[:, None]       # (B,C)
    kv_pos = jnp.concatenate([p_prior, q_pos], axis=1)           # (B,W+C)
    kv_valid = jnp.concatenate([p_prior >= 0, chunk_valid], axis=1)
    kk = jnp.concatenate([cache.k.astype(jnp.float32),
                          k.astype(jnp.float32)], axis=1)
    vv = jnp.concatenate([cache.v.astype(jnp.float32),
                          v.astype(jnp.float32)], axis=1)
    s = jnp.einsum("bqnGd,bknd->bnGqk", qg, kk)                  # (B,KVH,G,C,W+C)
    mask = (kv_valid[:, None, :]
            & (kv_pos[:, None, :] <= q_pos[:, :, None])
            & (kv_pos[:, None, :] > q_pos[:, :, None] - window))
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnGqk,bknd->bnGqd", p, vv)
    out = jnp.moveaxis(out, 3, 1).reshape(b, c, h, hd)
    # ring write: slot j's new occupant is the last real position < new_len
    # congruent to j — from the chunk if >= offset, else keep the old entry
    last = new_len[:, None] - 1
    p_new = last - ((last - jw) % W)
    src = p_new - offset[:, None]
    m4 = (src >= 0)[:, :, None, None]
    ck = jnp.where(m4, gather_chunk(src, k, cache.k.dtype), cache.k)
    cv = jnp.where(m4, gather_chunk(src, v, cache.v.dtype), cache.v)
    return out.astype(q.dtype), KVCache(ck, cv, new_len)


# ------------------------------------------------------------------- reference
def reference_attention(q, k, v, *, causal=True, window: int = 0,
                        q_offset: int | jax.Array = 0) -> jax.Array:
    """Naive O(S^2) oracle used by tests."""
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qg = (q.reshape(b, sq, kvh, g, hd) * scale).astype(jnp.float32)
    s = jnp.einsum("bqnGd,bknd->bnGqk", qg, k.astype(jnp.float32))
    q_pos = jnp.arange(sq)[:, None] + q_offset
    kv_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnGqk,bknd->bnGqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(out, 3, 1).reshape(b, sq, h, hd).astype(q.dtype)
