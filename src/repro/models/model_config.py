"""Architecture configuration — every assigned arch is an ``ArchConfig``."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # block structure: per-layer kind cycles through this pattern
    block_pattern: tuple[str, ...] = ("attn",)   # attn|local|rec|ssm|dec
    ffn_kind: str = "glu"             # glu|mlp|moe|none
    activation: str = "silu"
    norm: str = "rms"                 # rms|layer
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int = 0                   # sliding-window size for "local" blocks
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_shared_expert: bool = False
    moe_capacity: float = 1.25
    moe_impl: str = "einsum"          # einsum | scatter | ragged (see moe.py)
    # recurrent dims
    rglru_gate_blocks: int = 0        # 0 = dense gates; >0 = block-diagonal
    d_rnn: int = 0                    # RG-LRU width
    d_inner: int = 0                  # Mamba inner width
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0
    # encoder-decoder
    enc_layers: int = 0               # >0 => encoder-decoder (dec uses num_layers)
    # modality frontend stub (assignment: precomputed frame/patch embeddings)
    modality_tokens: int = 0
    modality_dim: int = 0
    tie_embeddings: bool = True
    # execution knobs (tuned per shape by the launcher)
    scan_chunk: int = 512             # recurrence chunk
    attn_block_kv: int = 512          # flash KV block
    remat: bool = True
    attn_f32: bool = True             # False: bf16 score/probability path
                                      # (fp32 m/l accumulators kept)
    unroll_scans: bool = False        # roofline mode: no while loops, so
                                      # compiled.cost_analysis() counts every
                                      # iteration (XLA counts loop bodies once)
    # kernel-variant switches ("xla" reference path | "pallas" fused kernel).
    # Owned by serve/placement.ExecutionPolicy at serving time — the oracle
    # resolves them per cluster before warmup; they never change shapes.
    attn_impl: str = "xla"            # flash prefill / paged decode kernels
    rglru_impl: str = "xla"           # pavlov_rglru linear-scan kernel
    ssm_impl: str = "xla"             # pavlov_ssm selective-scan kernel
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        return tuple(self.block_pattern[i % len(self.block_pattern)]
                     for i in range(self.num_layers))

    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to a multiple of 16 so the Jacquard
        vocab-sharded strategy divides any production mesh axis."""
        return -(-self.vocab_size // 16) * 16

    @property
    def sub_quadratic(self) -> bool:
        """True when no block needs a full-length dense KV cache — the
        assignment's criterion for running long_500k."""
        return all(k in ("rec", "ssm", "local") for k in set(self.layer_kinds))

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # parameter count (for roofline MODEL_FLOPS = 6*N*D)
    def param_count(self, active_only: bool = False) -> int:
        d, h = self.d_model, self.num_heads * self.head_dim
        kv = self.num_kv_heads * self.head_dim
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_kind = {}
        per_kind["attn"] = per_kind["local"] = d * h + 2 * d * kv + h * d
        per_kind["dec"] = 2 * per_kind["attn"]
        per_kind["rec"] = (2 * self.d_rnn * self.d_rnn
                           + 2 * self.d_model * self.d_rnn
                           + self.d_rnn * self.d_model + 5 * self.d_rnn)
        dtr = self.dt_rank or max(1, d // 16)
        per_kind["ssm"] = (2 * d * self.d_inner
                           + self.d_inner * (dtr + 2 * self.d_state)
                           + dtr * self.d_inner + self.d_inner * d
                           + (self.d_conv + self.d_state + 2) * self.d_inner)
        if self.ffn_kind == "glu":
            ffn = 3 * d * self.d_ff
        elif self.ffn_kind == "mlp":
            ffn = 2 * d * self.d_ff
        elif self.ffn_kind == "moe":
            e = self.top_k if active_only else self.num_experts
            ffn = e * 3 * d * self.d_ff + d * self.num_experts
            if self.moe_shared_expert:
                ffn += 3 * d * self.d_ff
        else:
            ffn = 0
        for k in self.layer_kinds:
            n += per_kind[k] + (ffn if k != "ssm" else 0)
        if self.is_encdec:
            n += self.enc_layers * (per_kind["attn"] + ffn)
        if self.modality_tokens:
            n += self.modality_dim * d + d * d   # 2-layer projector
        return n
