"""Top-k routed mixture-of-experts FFN (GShard/Switch-style capacity dispatch).

Dispatch is einsum/one-hot based (dense dispatch tensors), which maps cleanly
onto TPU expert parallelism: experts are sharded on the `model` mesh axis and
the dispatch einsum lowers to an all-to-all.  Capacity bounds the per-expert
token count so all shapes stay static (required for pjit).

This is the Mensa "Jacquard" cluster at pod scale: expert weights have a huge
footprint and per-token reuse is low (top-k of E), so the strategy keeps
weights stationary (sharded, never gathered) and moves tokens instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ACTIVATIONS, fan_in_init


def init_moe(key, d_model: int, d_ff: int, num_experts: int, *,
             shared_expert: bool = False, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    p = {
        "router": fan_in_init(ks[0], (d_model, num_experts), dtype),
        "w_gate": fan_in_init(ks[1], (num_experts, d_model, d_ff), dtype),
        "w_up": fan_in_init(ks[2], (num_experts, d_model, d_ff), dtype),
        "w_down": fan_in_init(ks[3], (num_experts, d_ff, d_model), dtype),
    }
    if shared_expert:
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": fan_in_init(kk[0], (d_model, d_ff), dtype),
            "w_up": fan_in_init(kk[1], (d_model, d_ff), dtype),
            "w_down": fan_in_init(kk[2], (d_ff, d_model), dtype),
        }
    return p


def moe_ffn(params: dict, x: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25, activation: str = "silu",
            return_aux: bool = False, impl: str = "einsum"):
    """x: (B,S,D) -> (B,S,D) [, aux_losses dict].

    impl:
      "einsum"  — GShard-style dense one-hot dispatch (cleanly shardable, but
                  the dispatch einsum costs O(N * E * C) FLOPs — quadratic in
                  tokens; fine at small scale, wasteful at 1M tokens).
      "scatter" — same capacity semantics with zero-FLOP dispatch: tokens are
                  scatter-added into the (E, C, D) expert buffers and gathered
                  back (hillclimb: removes the dispatch-einsum compute term).
      "ragged"  — dropless sorted dispatch + jax.lax.ragged_dot grouped GEMM
                  (MegaBlocks-style); exact active-expert FLOPs, no capacity.
    """
    if impl == "scatter":
        return _moe_ffn_scatter(params, x, top_k=top_k,
                                capacity_factor=capacity_factor,
                                activation=activation, return_aux=return_aux)
    if impl == "ragged":
        return _moe_ffn_ragged(params, x, top_k=top_k,
                               activation=activation, return_aux=return_aux)
    b, s, d = x.shape
    dt = x.dtype
    e = params["router"].shape[1]
    n = b * s
    xt = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (N,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (N,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = max(1, int(capacity_factor * n * top_k / e))
    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)      # (N,K,E)
    flat = onehot.reshape(n * top_k, e)
    pos = jnp.cumsum(flat, axis=0) - flat                      # (N*K,E)
    pos_in_expert = jnp.sum(pos * flat, axis=-1).reshape(n, top_k)
    keep = pos_in_expert < capacity

    # dispatch (N,K,E,C) one-hot — built as product of two one-hots
    disp = (jax.nn.one_hot(gate_idx, e, dtype=dt)
            * keep[..., None].astype(dt))[..., None] \
        * jax.nn.one_hot(pos_in_expert, capacity, dtype=dt)[..., None, :]
    # expert inputs: (E,C,D)
    xe = jnp.einsum("nkec,nd->ecd", disp, xt)
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dt))
    h = ACTIVATIONS[activation](g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))
    # combine with gate weights
    comb = disp * gate_vals[..., None, None].astype(dt)
    y = jnp.einsum("nkec,ecd->nd", comb, ye)

    if "shared" in params:
        sp = params["shared"]
        sg = jnp.einsum("nd,df->nf", xt, sp["w_gate"].astype(dt))
        su = jnp.einsum("nd,df->nf", xt, sp["w_up"].astype(dt))
        y = y + jnp.einsum("nf,fd->nd", ACTIVATIONS[activation](sg) * su,
                           sp["w_down"].astype(dt))

    y = y.reshape(b, s, d)
    if not return_aux:
        return y
    # load-balance aux loss (Switch): E * sum(frac_tokens * frac_probs)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = {"load_balance": e * jnp.sum(frac_tokens * frac_probs),
           "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y, aux


def _route(params, xt, top_k):
    """Shared router: returns (probs, gate_vals (N,K), gate_idx (N,K))."""
    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    return probs, gate_vals, gate_idx


def _shared_expert(params, xt, activation, dt):
    sp = params["shared"]
    sg = jnp.einsum("nd,df->nf", xt, sp["w_gate"].astype(dt))
    su = jnp.einsum("nd,df->nf", xt, sp["w_up"].astype(dt))
    return jnp.einsum("nf,fd->nd", ACTIVATIONS[activation](sg) * su,
                      sp["w_down"].astype(dt))


def _aux(probs, gate_idx, keep=None):
    e = probs.shape[-1]
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    out = {"load_balance": e * jnp.sum(frac_tokens * frac_probs)}
    out["dropped_frac"] = (1.0 - jnp.mean(keep.astype(jnp.float32))
                           if keep is not None else jnp.zeros(()))
    return out


def _moe_ffn_scatter(params: dict, x: jax.Array, *, top_k: int,
                     capacity_factor: float, activation: str,
                     return_aux: bool):
    """Capacity-based dispatch with scatter/gather instead of one-hot einsums:
    the (N,K,E,C) dispatch tensor never exists and dispatch costs 0 FLOPs."""
    b, s, d = x.shape
    dt = x.dtype
    e = params["router"].shape[1]
    n = b * s
    xt = x.reshape(n, d)
    probs, gate_vals, gate_idx = _route(params, xt, top_k)

    capacity = max(1, int(capacity_factor * n * top_k / e))
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)      # (N,K,E)
    flat = onehot.reshape(n * top_k, e)
    pos = jnp.cumsum(flat, axis=0) - flat
    pos_in_expert = jnp.sum(pos * flat, axis=-1)               # (N*K,)
    expert_flat = gate_idx.reshape(-1)                         # (N*K,)
    keep = pos_in_expert < capacity
    # clamp dropped tokens into a scratch row (capacity index C == dropped)
    slot = jnp.where(keep, pos_in_expert, capacity)

    # scatter tokens into (E, C+1, D); the +1 row collects drops
    xe = jnp.zeros((e, capacity + 1, d), dt)
    tok_idx = jnp.arange(n * top_k) // top_k
    xe = xe.at[expert_flat, slot].add(xt[tok_idx])
    xe = xe[:, :capacity]                                      # (E,C,D)

    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dt))
    h = ACTIVATIONS[activation](g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))

    # gather back + combine (dropped tokens read the zero row)
    ye_pad = jnp.concatenate([ye, jnp.zeros((e, 1, d), dt)], axis=1)
    contrib = ye_pad[expert_flat, slot]                        # (N*K, D)
    w = (gate_vals.reshape(-1) * keep.astype(jnp.float32)).astype(dt)
    y = jnp.zeros((n, d), dt).at[tok_idx].add(contrib * w[:, None])

    if "shared" in params:
        y = y + _shared_expert(params, xt, activation, dt)
    y = y.reshape(b, s, d)
    if not return_aux:
        return y
    return y, _aux(probs, gate_idx, keep)


def _moe_ffn_ragged(params: dict, x: jax.Array, *, top_k: int,
                    activation: str, return_aux: bool):
    """Dropless sorted dispatch + grouped GEMM (jax.lax.ragged_dot) —
    MegaBlocks-style; FLOPs == active-expert FLOPs exactly."""
    b, s, d = x.shape
    dt = x.dtype
    e = params["router"].shape[1]
    n = b * s
    xt = x.reshape(n, d)
    probs, gate_vals, gate_idx = _route(params, xt, top_k)

    expert_flat = gate_idx.reshape(-1)                  # (N*K,)
    order = jnp.argsort(expert_flat)                    # stable
    tok_of = order // top_k
    xs = xt[tok_of]                                     # (N*K, D) sorted
    group_sizes = jnp.bincount(expert_flat, length=e).astype(jnp.int32)

    g = jax.lax.ragged_dot(xs, params["w_gate"].astype(dt), group_sizes)
    u = jax.lax.ragged_dot(xs, params["w_up"].astype(dt), group_sizes)
    h = ACTIVATIONS[activation](g) * u
    ys = jax.lax.ragged_dot(h, params["w_down"].astype(dt), group_sizes)

    w = gate_vals.reshape(-1)[order].astype(dt)
    y = jnp.zeros((n, d), dt).at[tok_of].add(ys * w[:, None])

    if "shared" in params:
        y = y + _shared_expert(params, xt, activation, dt)
    y = y.reshape(b, s, d)
    if not return_aux:
        return y
    return y, _aux(probs, gate_idx)
