"""Shared model building blocks: norms, rotary embeddings, initializers, and the
parameter-tree conventions used across all architectures.

Conventions
-----------
* Parameters are plain nested dicts of jnp arrays (no flax/haiku): ``params``.
* Every module exposes ``init(key, cfg) -> params`` and a pure ``apply``.
* Compute dtype is bf16, parameters are stored in the config's param_dtype
  (fp32 for training masters, bf16 for serving), accumulation in fp32.
* Layer-stacked parameters (for ``lax.scan`` over layers) have a leading
  ``num_layers`` axis produced by ``jax.vmap``-ed init.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


# ----------------------------------------------------------------- initializers
def normal_init(key, shape, scale: float, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def fan_in_init(key, shape, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 1 else 1
    return normal_init(key, shape, 1.0 / math.sqrt(max(fan_in, 1)), dtype)


# ------------------------------------------------------------------------ norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ------------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, max_pos: int, theta: float = 10000.0,
                     dtype=jnp.float32):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)
    return jnp.cos(freqs).astype(dtype), jnp.sin(freqs).astype(dtype)


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    freqs = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    cos = jnp.cos(freqs)[..., :, None, :]
    sin = jnp.sin(freqs)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- embeds
def init_embedding(key, vocab: int, dim: int, dtype=jnp.float32):
    return normal_init(key, (vocab, dim), 1.0 / math.sqrt(dim), dtype)


def embed(table: jax.Array, tokens: jax.Array, compute_dtype=jnp.bfloat16):
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Logits in fp32 (softmax stability)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      table.astype(jnp.float32))


# ---------------------------------------------------------------------- softmax
def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None) -> jax.Array:
    """Mean token cross-entropy. logits fp32 (..., vocab); labels int (...)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# --------------------------------------------------------------------- residual
def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS: dict[str, Callable] = {
    "gelu": gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}
