"""JAX model substrate: attention/FFN/MoE/recurrent blocks and arch assembly."""
from .model_config import ArchConfig
from .transformer import Model, build_model

__all__ = ["ArchConfig", "Model", "build_model"]
