"""Recurrent blocks — the Mensa "Pavlov cluster" at pod scale.

* ``rglru_block`` — RecurrentGemma/Griffin recurrent block: temporal conv1d +
  RG-LRU gated linear recurrence, GeLU-gated output branch.
* ``mamba_block`` — Mamba-1 selective SSM (Falcon-Mamba).
* ``lstm_layer``  — classic LSTM (reference for the Pavlov kernels and the
  edge-model examples).

All recurrences are expressed as first-order linear recurrences
h_t = a_t * h_{t-1} + b_t and computed with ``jax.lax.associative_scan``
inside sequence chunks (lax.scan carries the state across chunks), which
bounds peak memory to O(chunk) per layer and keeps the HLO compact.

The Pavlov design maps here as: recurrence weights are fetched once and stay
resident across the whole time scan (VMEM-resident in the Pallas kernels);
input projections for *all* timesteps are hoisted out of the recurrence as one
large GEMM (the paper's decoupled input/hidden MVM schedule, §5.4).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import fan_in_init, normal_init


def _chunked_linear_scan(a, b, h0, chunk: int, unroll: bool = False):
    """h_t = a_t * h_{t-1} + b_t along axis 1.  a,b: (B,S,...), h0: (B,...).
    S need not divide chunk: the tail is identity-padded (a=1, b=0), which
    passes the state through unchanged, and the padded outputs are sliced
    off."""
    B, S = a.shape[0], a.shape[1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        widths = ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2)
        a = jnp.pad(a, widths, constant_values=1.0)
        b = jnp.pad(b, widths, constant_values=0.0)
    nc = (S + pad) // chunk
    a_c = a.reshape((B, nc, chunk) + a.shape[2:])
    b_c = b.reshape((B, nc, chunk) + b.shape[2:])

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def step(h, ab):
        ac, bc = ab                          # (B, chunk, ...)
        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_new = aa * h[:, None] + bb         # fold in carry
        return h_new[:, -1], h_new

    h_last, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a_c, 1, 0),
                                         jnp.moveaxis(b_c, 1, 0)),
                              unroll=nc if unroll else 1)
    hs = jnp.moveaxis(hs, 0, 1).reshape((B, S + pad) + a.shape[2:])
    return hs[:, :S], h_last


def causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None = None,
                  length: jax.Array | None = None):
    """Depthwise causal temporal conv.  x: (B,S,C), w: (K,C).
    ``state``: (B,K-1,C) trailing context from the previous segment (decode).
    ``length``: (B,) valid prefix lengths for right-padded x — the returned
    state is then the context trailing position ``length-1``, not S-1.
    Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    if k > 1:
        if length is None:
            new_state = xp[:, -(k - 1):]
        else:
            # xp index of real token t is (k-1)+t, so the k-1 inputs trailing
            # position length-1 live at xp[length .. length+k-2]
            idx = length[:, None] + jnp.arange(k - 1)[None, :]
            idx = jnp.clip(idx, 0, xp.shape[1] - 1)
            new_state = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    else:
        new_state = state
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------- RG-LRU
def init_rglru_block(key, d_model: int, d_rnn: int, *, conv_width: int = 4,
                     gate_blocks: int = 0, dtype=jnp.float32) -> dict:
    """gate_blocks > 0: block-diagonal recurrence/input gates (Griffin's
    actual design) — with #blocks a multiple of the mesh `model` axis the
    gate matmuls are fully local under TP (no collectives)."""
    ks = jax.random.split(key, 7)
    # Lambda init so that a = sigmoid(L)^(c*r) sits in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (d_rnn,), jnp.float32, 0.9, 0.999)
    c = 8.0
    lam = jnp.log(u ** (1.0 / c) / (1.0 - u ** (1.0 / c)))
    if gate_blocks:
        assert d_rnn % gate_blocks == 0
        bd = d_rnn // gate_blocks
        gk1 = jax.random.split(ks[4], gate_blocks)
        gk2 = jax.random.split(ks[5], gate_blocks)
        w_a = jnp.stack([fan_in_init(k, (bd, bd), dtype) for k in gk1])
        w_i = jnp.stack([fan_in_init(k, (bd, bd), dtype) for k in gk2])
    else:
        w_a = fan_in_init(ks[4], (d_rnn, d_rnn), dtype)
        w_i = fan_in_init(ks[5], (d_rnn, d_rnn), dtype)
    return {
        "w_x": fan_in_init(ks[1], (d_model, d_rnn), dtype),
        "w_y": fan_in_init(ks[2], (d_model, d_rnn), dtype),
        "conv_w": normal_init(ks[3], (conv_width, d_rnn),
                              1.0 / math.sqrt(conv_width), dtype),
        "w_a": w_a,   # recurrence gate
        "w_i": w_i,   # input gate
        "lambda": lam.astype(dtype),
        "w_out": fan_in_init(ks[6], (d_rnn, d_model), dtype),
    }


def _divisor_block(n: int, target: int) -> int:
    """Largest block size <= target that divides n (Pallas kernels assert
    exact tiling; n is a static shape so this runs at trace time)."""
    b = max(1, min(n, target))
    while n % b:
        b -= 1
    return b


def rglru_core(params: dict, x: jax.Array, h0: jax.Array | None = None,
               chunk: int = 512, unroll: bool = False,
               seq_mask: jax.Array | None = None, impl: str = "xla"):
    """The RG-LRU recurrence.  x: (B,S,d_rnn) (post-conv).  Returns (y, h_T).
    ``seq_mask``: (B,S) bool; False positions pass the state through
    unchanged (a=1, b=0), so h_T is the state at the last True position.
    ``impl="pallas"`` runs the scan through the fused pavlov_rglru kernel
    (h0 folded into b[:, 0]; identical math, same masking semantics)."""
    dt = x.dtype
    c = 8.0
    xf = x.astype(jnp.float32)
    if params["w_a"].ndim == 3:       # block-diagonal gates (local under TP)
        g = params["w_a"].shape[0]
        xg = xf.reshape(xf.shape[0], xf.shape[1], g, -1)
        r = jax.nn.sigmoid(jnp.einsum(
            "bsgd,gde->bsge", xg, params["w_a"].astype(jnp.float32)
        ).reshape(xf.shape))
        i = jax.nn.sigmoid(jnp.einsum(
            "bsgd,gde->bsge", xg, params["w_i"].astype(jnp.float32)
        ).reshape(xf.shape))
    else:
        # gate matmuls in compute dtype (bf16): the TP partial-sum all-reduce
        # moves half the bytes vs f32; sigmoid applied in f32 after
        r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x,
                                      params["w_a"].astype(dt)
                                      ).astype(jnp.float32))
        i = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x,
                                      params["w_i"].astype(dt)
                                      ).astype(jnp.float32))
    log_a = -c * r * jax.nn.softplus(-params["lambda"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = i * xf
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * gated
    if seq_mask is not None:
        m = seq_mask[:, :, None]
        a = jnp.where(m, a, 1.0)
        b = jnp.where(m, b, 0.0)
    if h0 is None:
        h0 = jnp.zeros((x.shape[0], x.shape[2]), jnp.float32)
    if impl == "pallas":
        from ..kernels.pavlov_rglru.ops import pavlov_rglru
        # the kernel scans from h=0; folding a_0*h0 into b_0 reproduces the
        # h0-seeded recurrence exactly (h_1 = a_0*h0 + b_0 either way)
        b = b.at[:, 0].add(a[:, 0] * h0)
        h = pavlov_rglru(a, b,
                         block_t=_divisor_block(a.shape[1], 128),
                         block_e=_divisor_block(a.shape[2], 512))
        # masked tail positions are identity steps (a=1, b=0), so the final
        # row already holds the state at the last valid position
        return h.astype(dt), h[:, -1].astype(jnp.float32)
    h, h_last = _chunked_linear_scan(a, b, h0, chunk, unroll)
    return h.astype(dt), h_last


def rglru_block(params: dict, x: jax.Array, *, chunk: int = 512,
                unroll: bool = False,
                state: dict | None = None, return_state: bool = False,
                length: jax.Array | None = None, impl: str = "xla"):
    """Full Griffin recurrent block.  x: (B,S,D) -> (B,S,D).
    ``length``: (B,) valid prefix lengths when x is right-padded (bucketed
    prefill) — the returned state then reflects position length-1."""
    dt = x.dtype
    y = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, params["w_y"].astype(dt)),
                    approximate=True)
    u = jnp.einsum("bsd,de->bse", x, params["w_x"].astype(dt))
    conv_state = state["conv"] if state else None
    h0 = state["h"] if state else None
    seq_mask = None if length is None else \
        jnp.arange(x.shape[1])[None, :] < length[:, None]
    u, new_conv = causal_conv1d(u, params["conv_w"].astype(dt), conv_state,
                                length=length)
    h, h_last = rglru_core(params, u, h0, chunk, unroll, seq_mask=seq_mask,
                           impl=impl)
    out = jnp.einsum("bse,ed->bsd", (h * y), params["w_out"].astype(dt))
    if return_state:
        return out, {"conv": new_conv, "h": h_last}
    return out


# ---------------------------------------------------------------------- Mamba-1
def init_mamba_block(key, d_model: int, d_inner: int, d_state: int = 16,
                     d_conv: int = 4, dt_rank: int | None = None,
                     dtype=jnp.float32) -> dict:
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 7)
    a_init = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None],
                      (d_inner, 1))
    return {
        "in_proj": fan_in_init(ks[0], (d_model, 2 * d_inner), dtype),
        "conv_w": normal_init(ks[1], (d_conv, d_inner),
                              1.0 / math.sqrt(d_conv), dtype),
        "x_proj": fan_in_init(ks[2], (d_inner, dt_rank + 2 * d_state), dtype),
        "dt_proj": fan_in_init(ks[3], (dt_rank, d_inner), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jax.random.uniform(ks[4], (d_inner,), jnp.float32, 1e-3, 1e-1)
        )).astype(dtype),
        "a_log": jnp.log(a_init).astype(dtype),
        "d_skip": jnp.ones((d_inner,), dtype),
        "out_proj": fan_in_init(ks[5], (d_inner, d_model), dtype),
    }


def mamba_ssm(params: dict, x: jax.Array, dt_rank: int, d_state: int,
              h0: jax.Array | None = None, chunk: int = 256,
              unroll: bool = False, seq_mask: jax.Array | None = None,
              impl: str = "xla"):
    """Selective scan.  x: (B,S,d_inner) (post conv+silu).  Returns (y, h_T).
    ``seq_mask``: (B,S) bool; False positions leave the state unchanged.
    ``impl="pallas"`` runs the fused pavlov_ssm kernel; it scans from h=0 and
    yields outputs only, so it requires ``h0 is None`` and returns h_T=None —
    callers that carry state across calls (serving) must stay on "xla"."""
    B_, S, di = x.shape
    xf = x.astype(jnp.float32)
    proj = jnp.einsum("bsd,dr->bsr", xf, params["x_proj"].astype(jnp.float32))
    dt_in, b_in, c_in = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in, params["dt_proj"].astype(jnp.float32))
        + params["dt_bias"].astype(jnp.float32))                    # (B,S,di)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))               # (di,Ns)
    if impl == "pallas" and h0 is None:
        from ..kernels.pavlov_ssm.ops import pavlov_ssm
        # tail padding only contributes to padded output rows (the state at
        # valid positions never sees a later timestep), so the unmasked
        # kernel matches the masked scan on every valid position
        y = pavlov_ssm(delta, xf, b_in, c_in, a,
                       params["d_skip"].astype(jnp.float32),
                       block_t=_divisor_block(S, 128),
                       block_d=_divisor_block(di, 512))
        return y.astype(x.dtype), None
    # first-order recurrence per (channel, state): h = exp(delta*a) h + delta*B*x
    alpha = jnp.exp(delta[..., None] * a[None, None])               # (B,S,di,Ns)
    beta = (delta * xf)[..., None] * b_in[:, :, None, :]            # (B,S,di,Ns)
    if seq_mask is not None:
        m = seq_mask[:, :, None, None]
        alpha = jnp.where(m, alpha, 1.0)
        beta = jnp.where(m, beta, 0.0)
    if h0 is None:
        h0 = jnp.zeros((B_, di, d_state), jnp.float32)
    h, h_last = _chunked_linear_scan(alpha, beta, h0, chunk, unroll)
    y = jnp.einsum("bsdn,bsn->bsd", h, c_in) \
        + xf * params["d_skip"].astype(jnp.float32)
    return y.astype(x.dtype), h_last


def mamba_block(params: dict, x: jax.Array, *, d_state: int = 16,
                dt_rank: int | None = None, chunk: int = 256,
                unroll: bool = False,
                state: dict | None = None, return_state: bool = False,
                length: jax.Array | None = None, impl: str = "xla"):
    """Full Mamba-1 block.  x: (B,S,D) -> (B,S,D).
    ``length``: (B,) valid prefix lengths when x is right-padded."""
    dt = x.dtype
    d_model = x.shape[-1]
    dt_rank = dt_rank or max(1, d_model // 16)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt))
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state else None
    h0 = state["h"] if state else None
    seq_mask = None if length is None else \
        jnp.arange(x.shape[1])[None, :] < length[:, None]
    xi, new_conv = causal_conv1d(xi, params["conv_w"].astype(dt), conv_state,
                                 length=length)
    xi = jax.nn.silu(xi)
    # the fused kernel yields no carry state — callers that thread state
    # (serving prefill/decode) must take the XLA scan
    ssm_impl = impl if (state is None and not return_state) else "xla"
    y, h_last = mamba_ssm(params, xi, dt_rank, d_state, h0, chunk, unroll,
                          seq_mask=seq_mask, impl=ssm_impl)
    out = jnp.einsum("bse,ed->bsd", y * jax.nn.silu(z),
                     params["out_proj"].astype(dt))
    if return_state:
        return out, {"conv": new_conv, "h": h_last}
    return out


# ------------------------------------------------------------------------ LSTM
def init_lstm_layer(key, d_in: int, d_hidden: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_x": fan_in_init(k1, (d_in, 4 * d_hidden), dtype),
        "w_h": fan_in_init(k2, (d_hidden, 4 * d_hidden), dtype),
        "b": jnp.zeros((4 * d_hidden,), dtype),
    }


def lstm_layer(params: dict, x: jax.Array,
               state: tuple[jax.Array, jax.Array] | None = None):
    """x: (B,S,Din) -> (B,S,H).  The input MVMs for *all* timesteps are
    computed as one batched GEMM before the recurrence (the paper's Pavlov
    decoupled schedule) so W_x is read exactly once."""
    b, s, _ = x.shape
    h4 = params["w_x"].shape[1]
    hd = h4 // 4
    dt = x.dtype
    if state is None:
        state = (jnp.zeros((b, hd), jnp.float32), jnp.zeros((b, hd), jnp.float32))
    # decoupled input MVMs (one GEMM over the whole sequence)
    xg = jnp.einsum("bsd,dh->bsh", x, params["w_x"].astype(dt)) \
        + params["b"].astype(dt)

    wh = params["w_h"].astype(jnp.float32)

    def step(carry, xg_t):
        h, c = carry
        gates = xg_t.astype(jnp.float32) + h @ wh
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    (h, c), ys = jax.lax.scan(step, state, jnp.moveaxis(xg, 1, 0))
    return jnp.moveaxis(ys, 0, 1).astype(dt), (h, c)
