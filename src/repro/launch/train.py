"""End-to-end training driver: data pipeline -> train loop -> checkpointing ->
fault-tolerant auto-resume -> straggler watchdog.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --steps 50 \\
      --reduced --ckpt-dir /tmp/ckpt --ckpt-every 20

On a pod this runs under `jax.distributed.initialize()` with the production
mesh; on CPU it runs the same code on a 1-device mesh (reduced configs).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..ckpt import checkpoint as ckpt_lib
from ..configs import get_config, reduced_config
from ..data.pipeline import DataConfig, SyntheticTokens
from ..ft.watchdog import FailureInjector, StepWatchdog, \
    run_with_restarts
from ..models import build_model
from ..train import optim
from ..train.trainer import make_train_step


def train_once(cfg, *, steps: int, global_batch: int, seq_len: int,
               ckpt_dir: str | None, ckpt_every: int, seed: int = 0,
               accum_steps: int = 1, fail_at: int = -1,
               injector: FailureInjector | None = None,
               log_every: int = 10, lr: float = 3e-4,
               metrics_out: list | None = None) -> dict:
    model = build_model(cfg)
    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=global_batch,
        seed=seed, modality_tokens=cfg.modality_tokens,
        modality_dim=cfg.modality_dim, encdec=cfg.is_encdec,
        d_model=cfg.d_model))
    schedule = optim.cosine_schedule(lr, warmup=max(steps // 20, 5),
                                     total=steps)
    step_fn = jax.jit(make_train_step(model, accum_steps=accum_steps,
                                      schedule=schedule))
    # the injector survives restarts (fail_once semantics); pass one in to
    # exercise the checkpoint->resume path exactly once
    injector = injector or FailureInjector(fail_at_step=fail_at)
    watchdog = StepWatchdog()

    start = 0
    params = opt_state = None
    if ckpt_dir:
        last = ckpt_lib.latest_step(ckpt_dir)
        if last is not None:
            shapes = jax.eval_shape(
                lambda: _init_all(model))
            params, opt_state = ckpt_lib.restore(
                ckpt_dir, last, shapes)
            start = last
            print(f"[train] resumed from step {last}")
    if params is None:
        params, opt_state = _init_all(model)

    losses = {}
    t_last = time.time()
    for step in range(start, steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        injector.maybe_fail(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if watchdog.observe(time.time() - t_last):
            print(f"[train] straggler event at step {step}")
        t_last = time.time()
        loss = float(metrics["loss"])
        losses[step] = loss
        if metrics_out is not None:
            metrics_out.append((step, loss))
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            ckpt_lib.save(ckpt_dir, step + 1, (params, opt_state))
    if ckpt_dir:
        ckpt_lib.save(ckpt_dir, steps, (params, opt_state))
    return {"final_loss": losses.get(steps - 1),
            "losses": losses,
            "stragglers": watchdog.stragglers_detected,
            "params": params}


def _init_all(model):
    params = model.init(jax.random.PRNGKey(0))
    return params, optim.adamw_init(params)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (CPU-size) config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--max-restarts", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    injector = FailureInjector(fail_at_step=args.fail_at)

    def once():
        train_once(cfg, steps=args.steps, global_batch=args.global_batch,
                   seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every, accum_steps=args.accum,
                   injector=injector, lr=args.lr)

    restarts = run_with_restarts(
        once, max_restarts=args.max_restarts,
        on_restart=lambda n, e: print(f"[train] restart {n} after {e!r}"))
    print(f"[train] done ({restarts} restarts)")


if __name__ == "__main__":
    main()
