"""Sharding strategies — the Mensa clusters mapped to mesh layouts (Level B).

Each parameter gets a PartitionSpec from its Mensa strategy cluster:

* Pascal (compute-centric attn/FFN matmuls): Megatron column->row pairing —
  only one collective per block on the forward pass.
* Jacquard (huge-footprint, low-reuse): vocab/embedding tables and MoE expert
  banks sharded on `model` and NEVER gathered; compute moves to the shard.
* Pavlov (recurrent): recurrence width (d_rnn / d_inner) sharded on `model`,
  sequence kept local so the time scan has no cross-device dependency;
  weights stay resident across the whole scan.

Batch is sharded on (pod, data).  KV caches for decode shard the *sequence*
axis on `model` (context parallelism): softmax reductions over the sharded
axis lower to small all-reduces instead of gathering the cache.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.model_config import ArchConfig
from ..models.transformer import Model
from .mesh import data_axes

PyTree = Any


# ------------------------------------------------------------------ parameters
def _base_spec(names: list[str], leaf, is_moe: bool,
               blockdiag_gates: bool = False,
               dense_2d: bool = False) -> tuple:
    """PartitionSpec entries for the *unstacked* rank of this parameter."""
    name = names[-1]
    in_moe = is_moe and "ffn" in names and "shared" not in names
    # --- Jacquard cluster: big tables / expert banks, sharded & stationary
    if name in ("embed", "lm_head"):
        return ("model", None)
    if in_moe and name in ("w_gate", "w_up"):
        # experts on `model` (EP) + d_ff on `data` (FSDP-style 2D sharding):
        # pure EP leaves the expert bank replicated across `data`, which
        # overflows HBM for the 42B/109B MoE archs (caught by the dry-run
        # memory analysis) — the second axis shards it 256-way.
        return ("model", None, "data")
    if in_moe and name == "w_down":
        return ("model", "data", None)
    # --- Pascal cluster: Megatron column->row pairs.  For >20B-param archs
    # the second mesh axis also shards the non-contracted weight dim
    # (FSDP-style 2D) so replicated dense weights never exceed HBM.
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "w_in"):
        return ("data" if dense_2d else None, "model")
    if name in ("wo", "w_down", "w_out", "out_proj", "x_proj"):
        return ("model", "data" if dense_2d else None)
    if name in ("bq", "bk", "bv", "b_in"):
        return ("model",)
    if name in ("b_out",):
        return (None,)
    # --- Pavlov cluster: recurrence width on `model`
    if name in ("w_x", "w_y", "in_proj", "dt_proj"):
        return (None, "model")
    if name in ("w_a", "w_i"):
        # dense (rank 2): row-parallel (psum).  block-diagonal (rank 3,
        # flagged): blocks on `model` -> fully local gate matmuls
        if blockdiag_gates:
            return ("model", None, None)
        return ("model", None)
    if name == "conv_w":
        return (None, "model")
    if name in ("lambda", "dt_bias", "d_skip"):
        return ("model",)
    if name == "a_log":
        return ("model", None)
    if name == "b":                        # lstm bias (4H,)
        return ("model",)
    if name == "w_h":
        return (None, "model")
    # --- small/replicated
    return (None,) * leaf_rank(leaf)


def leaf_rank(leaf) -> int:
    return len(leaf.shape)


def _names_from_path(path) -> list[str]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
    return names


# parameter-name families, used to route each leaf to the block kinds whose
# ExecutionPolicy governs it under strategy="auto" (plan-aware sharding)
_ATTN_PARAMS = frozenset(
    {"wq", "wk", "wv", "wo", "bq", "bk", "bv"})
_REC_PARAMS = frozenset(
    {"w_x", "w_y", "in_proj", "dt_proj", "w_a", "w_i", "conv_w", "lambda",
     "dt_bias", "d_skip", "a_log", "w_h", "b", "out_proj", "x_proj"})
_FAMILY_KINDS = {
    "attn": ("attn", "local", "dec", "enc"),
    "rec": ("rec", "ssm"),
    "ffn": ("ffn",),
}


def _family_of(names: list[str]) -> str | None:
    name = names[-1]
    if name in _ATTN_PARAMS:
        return "attn"
    if name in _REC_PARAMS:
        return "rec"
    if "ffn" in names or name in ("w_gate", "w_up", "w_down", "w_in",
                                  "w_out", "b_in", "b_out"):
        return "ffn"
    return None


def _plan_family_axes(plan) -> dict:
    """family -> preferred mesh axis from the plan's per-cluster policies
    (``ExecutionPolicy.sharding_axis``).  "model" wins when a family spans
    clusters that disagree; families the plan says nothing about map to
    None (the TP templates decide)."""
    out = {}
    for family, kinds in _FAMILY_KINDS.items():
        axes = []
        for k in kinds:
            pol = plan.policy_for(k)
            if pol is not None and pol.sharding_axis:
                axes.append(pol.sharding_axis)
        out[family] = ("model" if "model" in axes
                       else (axes[0] if axes else None))
    return out


def param_specs(cfg: ArchConfig, params_shape: PyTree,
                strategy: str = "tp", plan=None) -> PyTree:
    """PartitionSpec tree matching `params_shape` (ShapeDtypeStructs or arrays).
    Stacked (scan) leading axes are padded with None on the left.

    strategy:
      "tp"   — the Mensa cluster templates (Pascal-TP / Jacquard / Pavlov).
      "dp"   — pascal_dp plan: every block parameter replicated (batch shards
               over all mesh axes); embeddings stay Jacquard vocab-sharded.
      "auto" — per-cluster, from ``plan`` (a ``serve.placement.PlacementPlan``):
               families whose policy prefers the "data" axis (memory-centric
               clusters — they scale by replication over slots) drop to
               replicated specs, families preferring "model" keep the TP
               templates.  Embeddings always stay Jacquard vocab-sharded
               (the table must never be replicated).  A plan with no
               policies (``fixed_plan``) degrades to plain "tp".
    """
    if strategy == "auto" and plan is None:
        raise ValueError('param_specs(strategy="auto") needs a PlacementPlan '
                         "(build the engine with a policy, or pass plan=...)")
    family_axes = _plan_family_axes(plan) if strategy == "auto" else {}
    is_moe = cfg.ffn_kind == "moe"
    blockdiag = getattr(cfg, "rglru_gate_blocks", 0) > 0
    dense_2d = cfg.param_count() > 20e9

    def spec(path, leaf):
        names = _names_from_path(path)
        if names[-1] not in ("embed", "lm_head"):
            if strategy == "dp":
                return P(*((None,) * len(leaf.shape)))
            if strategy == "auto":
                fam = _family_of(names)
                if fam is not None and family_axes.get(fam) == "data":
                    return P(*((None,) * len(leaf.shape)))
        base = _base_spec(names, leaf, is_moe, blockdiag, dense_2d)
        pad = len(leaf.shape) - len(base)
        if pad < 0:       # scalar-ish leaf with generic base
            base = base[-len(leaf.shape):] if len(leaf.shape) else ()
            pad = 0
        return P(*((None,) * pad + tuple(base)))

    return jax.tree_util.tree_map_with_path(spec, params_shape)


# ----------------------------------------------------------------- batch/state
def batch_specs(cfg: ArchConfig, mesh: Mesh, global_batch: int,
                strategy: str = "tp") -> dict:
    """Specs for the training batch dict."""
    d = data_axes(mesh)
    if strategy == "dp":
        d = d + ("model",)                  # batch over every mesh axis
    nd = int(np.prod([mesh.shape[a] for a in d]))
    bspec = d if global_batch % nd == 0 and global_batch >= nd else None
    out = {"tokens": P(bspec, None), "labels": P(bspec, None)}
    if cfg.modality_tokens:
        out["modality"] = P(bspec, None, None)
    if cfg.is_encdec:
        out["src_embeds"] = P(bspec, None, None)
    return out


def state_specs(model: Model, mesh: Mesh, batch: int, max_len: int) -> PyTree:
    """Specs mirroring Model.init_states structure.

    KV caches shard sequence on `model` (context parallelism) and batch on
    data; recurrent states shard their width on `model`.
    """
    cfg = model.cfg
    d = data_axes(mesh)
    nd = int(np.prod([mesh.shape[a] for a in d]))
    b = d if batch % nd == 0 and batch >= nd else None

    from ..models.transformer import BlockState
    from ..models.attention import KVCache

    def one(kind, stacked: bool):
        pad = (None,) if stacked else ()
        if kind in ("attn", "dec", "local"):
            kv = KVCache(
                k=P(*pad, b, "model", None, None),
                v=P(*pad, b, "model", None, None),
                length=P(*pad, b))
            return BlockState(kv=kv)
        if kind == "rec" or kind == "ssm":
            h = P(*pad, b, "model", None) if kind == "ssm" \
                else P(*pad, b, "model")
            return BlockState(rec={
                "conv": P(*pad, b, None, "model"),
                "h": h})
        raise ValueError(kind)

    groups = {}
    for j, kind in enumerate(model.pattern):
        if model.n_groups > 0:
            groups[str(j)] = one(kind, True)
    return {"groups": groups,
            "tail": [one(k, False) for k in model.tail_kinds]}


def serve_state_specs(model: Model, mesh: Mesh, slots: int, max_len: int, *,
                      kv_block_size: int | None = None,
                      kv_blocks: int | None = None) -> PyTree:
    """Specs mirroring ``Model.init_states`` for the SERVING path.

    Serving shards differently from training (:func:`state_specs`): the slot
    (batch) axis goes on the data axes — per-slot decode math then never
    crosses a shard, which keeps a pure-dp mesh bitwise identical to
    single-device — and per-head / recurrence-width axes go on ``model`` only
    when they divide the axis size.  A paged KV pool has no batch axis; its
    BLOCK axis is sharded over the data axes instead (each shard owns a
    contiguous stripe of physical blocks — the layout serve/kvpool.py's
    per-shard accounting mirrors), falling back to replicated when
    ``kv_blocks`` does not divide evenly.
    """
    cfg = model.cfg
    d = data_axes(mesh)
    nd = int(np.prod([mesh.shape[a] for a in d]))
    mp = int(mesh.shape.get("model", 1))
    b = d if slots % nd == 0 and slots >= nd else None
    if kv_block_size is not None and kv_blocks is None:
        kv_blocks = slots * (-(-max_len // kv_block_size))
    blk = d if kv_blocks is not None and kv_blocks % nd == 0 \
        and kv_blocks >= nd else None

    def wax(n: int):
        """`model` for a width/head axis only when it splits evenly."""
        return "model" if mp > 1 and n and n % mp == 0 else None

    from ..models.attention import KVCache, PagedKVCache
    from ..models.transformer import BlockState

    def one(kind, stacked: bool):
        pad = (None,) if stacked else ()
        if kind in ("attn", "dec") and kv_block_size is not None:
            kv = PagedKVCache(
                k=P(*pad, blk, None, wax(cfg.num_kv_heads), None),
                v=P(*pad, blk, None, wax(cfg.num_kv_heads), None),
                length=P(*pad, b))
            return BlockState(kv=kv)
        if kind in ("attn", "dec", "local"):
            kv = KVCache(
                k=P(*pad, b, None, wax(cfg.num_kv_heads), None),
                v=P(*pad, b, None, wax(cfg.num_kv_heads), None),
                length=P(*pad, b))
            return BlockState(kv=kv)
        if kind == "rec":
            return BlockState(rec={
                "conv": P(*pad, b, None, wax(cfg.d_rnn)),
                "h": P(*pad, b, wax(cfg.d_rnn))})
        if kind == "ssm":
            return BlockState(rec={
                "conv": P(*pad, b, None, wax(cfg.d_inner)),
                "h": P(*pad, b, wax(cfg.d_inner), None)})
        raise ValueError(kind)

    groups = {}
    for j, kind in enumerate(model.pattern):
        if model.n_groups > 0:
            groups[str(j)] = one(kind, True)
    return {"groups": groups,
            "tail": [one(k, False) for k in model.tail_kinds]}


def to_named(tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def abstract_with_sharding(shapes: PyTree, specs: PyTree, mesh: Mesh) -> PyTree:
    """Attach shardings to ShapeDtypeStructs (for .lower without allocation)."""
    named = to_named(specs, mesh)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, named)
