import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
# ShapeDtypeStruct inputs (no allocation) and record memory / cost / collective
# analyses for the roofline.
#
# The first two lines force 512 placeholder host devices and MUST run before
# ANY other import (jax locks the device count on first init).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
#   PYTHONPATH=src python -m repro.launch.dryrun --all

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, SHAPES, ShapeSpec, applicable, get_config
from ..models import build_model
from ..train import optim
from ..train.trainer import make_train_step
from ..utils.hlo import (normalize_cost_analysis, normalize_memory_analysis,
                         parse_collectives)
from . import shardings as sh
from .mesh import data_axes, make_production_mesh

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# grad-accumulation per cell: keeps per-microbatch activations within HBM
ACCUM = {
    "default": 8,
    "smollm-135m": 2, "qwen3-0.6b": 4, "qwen2-0.5b": 4,
    "falcon-mamba-7b": 16, "llama4-scout-17b-a16e": 32,
    "starcoder2-7b": 8, "phi3.5-moe-42b-a6.6b": 16,
}


def input_specs(cfg, shape: ShapeSpec, mesh, strategy: str = "tp") -> dict:
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no device allocation)."""
    b, s = shape.global_batch, shape.seq_len
    s_text = s - cfg.modality_tokens          # image/audio tokens count
    bspecs = sh.batch_specs(cfg, mesh, b, strategy)
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32),
               "labels": jax.ShapeDtypeStruct((b, s_text), jnp.int32)}
        if cfg.modality_tokens:
            out["modality"] = jax.ShapeDtypeStruct(
                (b, cfg.modality_tokens, cfg.modality_dim), jnp.float32)
        if cfg.is_encdec:
            out["src_embeds"] = jax.ShapeDtypeStruct(
                (b, s // 2, cfg.d_model), jnp.float32)
            out["tokens"] = jax.ShapeDtypeStruct((b, s // 2), jnp.int32)
            out["labels"] = jax.ShapeDtypeStruct((b, s // 2), jnp.int32)
        return sh.abstract_with_sharding(
            out, {k: bspecs.get(k, bspecs["tokens"]) for k in out}, mesh)
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32)}
        if cfg.modality_tokens:
            out["modality"] = jax.ShapeDtypeStruct(
                (b, cfg.modality_tokens, cfg.modality_dim), jnp.float32)
        if cfg.is_encdec:
            out["src_embeds"] = jax.ShapeDtypeStruct(
                (b, s // 2, cfg.d_model), jnp.float32)
            out["tokens"] = jax.ShapeDtypeStruct((b, s // 2), jnp.int32)
        specs = {"tokens": bspecs["tokens"]}
        if "modality" in out:
            specs["modality"] = bspecs["modality"]
        if "src_embeds" in out:
            specs["src_embeds"] = bspecs["src_embeds"]
        return sh.abstract_with_sharding(out, specs, mesh)
    # decode: one new token against a seq_len-deep cache
    out = {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
           "position": jax.ShapeDtypeStruct((b,), jnp.int32)}
    d = data_axes(mesh)
    nd = int(np.prod([mesh.shape[a] for a in d]))
    bs = d if b % nd == 0 and b >= nd else None
    from jax.sharding import PartitionSpec as P
    specs = {"token": P(bs, None), "position": P(bs)}
    if cfg.is_encdec:
        out["memory"] = jax.ShapeDtypeStruct((b, shape.seq_len // 2,
                                              cfg.d_model), jnp.bfloat16)
        specs["memory"] = P(bs, None, None)
    return sh.abstract_with_sharding(out, specs, mesh)


def _tree_bytes(tree) -> float:
    return float(sum(int(np.prod(l.shape)) * l.dtype.itemsize
                     for l in jax.tree.leaves(tree)))


def build_lowerable(cfg, shape: ShapeSpec, mesh, strategy: str = "tp"):
    """Returns (fn, abstract_args, out_shardings) ready to lower."""
    model = build_model(cfg)
    pshape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = sh.param_specs(cfg, pshape, strategy)
    params_abs = sh.abstract_with_sharding(pshape, pspecs, mesh)
    inputs = input_specs(cfg, shape, mesh, strategy)

    if shape.kind == "train":
        accum = ACCUM.get(cfg.name, ACCUM["default"])
        if shape.global_batch % accum or shape.global_batch // accum < 1:
            accum = 1
        step_fn = make_train_step(model, accum_steps=accum)
        opt_shape = jax.eval_shape(optim.adamw_init, pshape)
        opt_specs = optim.AdamWState(
            step=jax.sharding.PartitionSpec(), mu=pspecs,
            nu=jax.tree.map(lambda s: s, pspecs))
        opt_abs = sh.abstract_with_sharding(opt_shape, opt_specs, mesh)
        args = (params_abs, opt_abs, inputs)
        fn = step_fn
        out_sh = None
        meta = {"accum_steps": accum}
    elif shape.kind == "prefill":
        model_states = jax.eval_shape(
            lambda: model.init_states(shape.global_batch, shape.seq_len))
        sspecs = sh.state_specs(model, mesh, shape.global_batch, shape.seq_len)
        states_abs = sh.abstract_with_sharding(model_states, sspecs, mesh)

        def fn(params, tokens_dict, states):
            return model.prefill(params, tokens_dict["tokens"], states,
                                 tokens_dict.get("modality"),
                                 tokens_dict.get("src_embeds"))
        args = (params_abs, inputs, states_abs)
        out_sh = None
        meta = {}
    else:  # decode
        model_states = jax.eval_shape(
            lambda: model.init_states(shape.global_batch, shape.seq_len))
        sspecs = sh.state_specs(model, mesh, shape.global_batch, shape.seq_len)
        states_abs = sh.abstract_with_sharding(model_states, sspecs, mesh)

        def fn(params, io, states):
            return model.decode_step(params, io["token"], states,
                                     io["position"], io.get("memory"))
        args = (params_abs, inputs, states_abs)
        out_sh = None
        meta = {}
    meta["param_bytes"] = _tree_bytes(pshape)
    return fn, args, out_sh, meta


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: Path = RESULTS_DIR, save_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}.json"
    if not ok:
        rec.update(status="skip", reason=why)
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: SKIP ({why})")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        fn, args, out_sh, meta = build_lowerable(cfg, shape, mesh)
        # donate params/opt (train) or states (serve): updates alias their
        # inputs in place, as on a real pod
        donate = (0, 1) if shape.kind == "train" else (2,)
        with mesh:
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            # lower()/compile() block on the host — wall-clock pairs here
            # measure real work, no device sync involved
            t_lower = time.time() - t0      # jitlint: ignore[JL008]
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = normalize_memory_analysis(compiled.memory_analysis())
            cost = normalize_cost_analysis(compiled.cost_analysis())
            hlo = compiled.as_text()
        n_dev = int(np.prod(list(mesh.shape.values())))
        coll = parse_collectives(hlo, default_group=n_dev)
        rec.update(
            status="ok", meta=meta,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            n_devices=n_dev,
            flops=float(cost.get("flops", 0.0)) if cost else 0.0,
            bytes_accessed=float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
            memory=mem,
            collectives=coll.to_dict(),
            hlo_bytes=len(hlo),
        )
        if save_hlo:
            (out_dir / f"{arch}__{shape_name}__{mesh_kind}.hlo.txt"
             ).write_text(hlo)
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s, "
              f"flops/dev {rec['flops']:.3g}, "
              f"coll wire {coll.total_wire_bytes:.3g}B)")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: "
              f"ERROR {type(e).__name__}: {e}")
    out_path.write_text(json.dumps(rec, indent=1, default=float))
    return rec


def all_cells() -> list[tuple[str, str, str]]:
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                cells.append((arch, shape, mesh))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)
    if args.all:
        for arch, shape, mesh in all_cells():
            p = out_dir / f"{arch}__{shape}__{mesh}.json"
            if args.skip_done and p.exists() \
                    and json.loads(p.read_text()).get("status") in ("ok", "skip"):
                continue
            run_cell(arch, shape, mesh, out_dir, args.save_hlo)
    else:
        assert args.arch and args.shape
        run_cell(args.arch, args.shape, args.mesh, out_dir, args.save_hlo)


if __name__ == "__main__":
    main()
