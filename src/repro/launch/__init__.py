"""repro.launch"""
