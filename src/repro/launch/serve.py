"""Serving driver: per-phase Mensa plans -> engine -> batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \\
      --requests 8 --slots 4 --max-prefill-per-step 4 --max-prefill-batch 4
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import numpy as np

from ..configs import get_config, reduced_config
from ..core.executor import phase_profiles
from ..models import build_model
from ..obs import profile_trace
from ..serve.disagg import DisaggEngine
from ..serve.engine import Request, ServeEngine, prefill_buckets
from ..serve.placement import ExecutionOracle, PlacementPlan


def build_engine(cfg, params=None, *, slots: int = 4, max_len: int = 256,
                 min_bucket: int = 16, max_bucket: int | None = None,
                 max_prefill_per_step: int = 1, max_prefill_batch: int = 4,
                 prefill_chunk: int | None = None,
                 kv_block_size: int | None = None,
                 kv_blocks: int | None = None,
                 prefix_cache: bool = True,
                 mesh=None, param_strategy: str = "tp",
                 plan_cfg=None, profiles=None,
                 policy="auto", program_memory: bool = False) -> ServeEngine:
    """Engine with the prefill/decode programs routed through their
    Mensa execution profiles (runtime-safe overrides only — the phase models
    share one parameter tree).  With today's cost model the serve-shape
    profiles often carry no runtime-safe overrides; the routing is the hook
    that picks them up as soon as measurement adds them.  Pass ``profiles``
    (a (prefill, decode) pair) to reuse already-computed plans.
    ``max_bucket`` caps the prefill buckets below max_len so longer prompts
    exercise the chunked path.  ``kv_block_size``/``kv_blocks``/
    ``prefix_cache`` switch KV storage to the paged pool (serve/kvpool.py).
    ``mesh`` shards weights, slot state, and the block pool over a
    (data, model) device mesh (``launch.mesh.make_serve_mesh``);
    ``param_strategy`` picks the weight layout ("tp" Mensa clusters /
    "dp" replicated / "auto" per-cluster from the plan's
    ``sharding_axis`` — see ``launch.shardings.param_specs``).

    ``policy``: "auto" (default) resolves a ``PlacementPlan`` through the
    ExecutionOracle (characterize -> cluster -> cost) and applies its
    per-phase kernel-variant overrides on top of the Mensa profiles;
    "fixed" keeps the constructor-global knobs; a pre-resolved
    ``PlacementPlan`` is used as-is.  Policies only pick among
    token-identical implementations and are resolved before anything
    compiles — on a backend without native Pallas lowering the auto plan
    is exactly the fixed engine."""
    plan = None
    if isinstance(policy, PlacementPlan):
        plan = policy
    elif policy == "auto":
        plan = ExecutionOracle(
            plan_cfg or cfg, slots=slots, max_len=max_len,
            min_bucket=min_bucket, max_bucket=max_bucket,
            mesh_axes=tuple(mesh.axis_names) if mesh is not None else (),
        ).resolve()
    elif policy != "fixed":
        raise ValueError(f"policy must be 'auto', 'fixed', or a "
                         f"PlacementPlan, got {policy!r}")
    prefill_prof, decode_prof = profiles or phase_profiles(plan_cfg or cfg,
                                                           policy=plan)
    model = build_model(cfg)
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    prefill_cfg = prefill_prof.apply(cfg, runtime_only=True)
    decode_cfg = decode_prof.apply(cfg, runtime_only=True)
    buckets = None
    if max_bucket is not None:
        buckets = prefill_buckets(min(max_bucket, max_len), min_bucket)
    return ServeEngine(
        model, params, slots=slots, max_len=max_len, min_bucket=min_bucket,
        buckets=buckets,
        max_prefill_per_step=max_prefill_per_step,
        max_prefill_batch=max_prefill_batch,
        prefill_chunk=prefill_chunk,
        kv_block_size=kv_block_size, kv_blocks=kv_blocks,
        prefix_cache=prefix_cache,
        mesh=mesh, param_strategy=param_strategy,
        prefill_model=build_model(prefill_cfg) if prefill_cfg != cfg else None,
        decode_model=build_model(decode_cfg) if decode_cfg != cfg else None,
        policy=plan, program_memory=program_memory)


def build_disagg_engine(cfg, params=None, *, roles, prefill_slots: int = 4,
                        decode_slots: int = 4, max_len: int = 256,
                        min_bucket: int = 16, max_bucket: int | None = None,
                        max_prefill_per_step: int = 1,
                        max_prefill_batch: int = 4,
                        prefill_chunk: int | None = None,
                        kv_block_size: int | None = None,
                        kv_blocks: int | None = None,
                        prefix_cache: bool = True,
                        param_strategy: str = "tp",
                        plan_cfg=None, profiles=None, policy="auto",
                        program_memory: bool = False) -> DisaggEngine:
    """The disaggregated counterpart of :func:`build_engine`: a prefill and
    a decode engine pinned to the disjoint submeshes of ``roles`` (a
    ``launch.mesh.RoleConfig``; None keeps the pair on the default device —
    the functional model the identity tests drive).  Plan resolution, phase
    profiles, and knob precedence match ``build_engine``; the plan's
    ``role_knobs`` additionally specialize each role's buckets/chunk."""
    from .mesh import make_role_meshes
    pm, dm = make_role_meshes(roles) if roles is not None else (None, None)
    plan = None
    if isinstance(policy, PlacementPlan):
        plan = policy
    elif policy == "auto":
        plan = ExecutionOracle(
            plan_cfg or cfg, slots=decode_slots, max_len=max_len,
            min_bucket=min_bucket, max_bucket=max_bucket,
            mesh_axes=tuple(pm.axis_names) if pm is not None else (),
        ).resolve()
    elif policy != "fixed":
        raise ValueError(f"policy must be 'auto', 'fixed', or a "
                         f"PlacementPlan, got {policy!r}")
    prefill_prof, decode_prof = profiles or phase_profiles(plan_cfg or cfg,
                                                           policy=plan)
    model = build_model(cfg)
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    prefill_cfg = prefill_prof.apply(cfg, runtime_only=True)
    decode_cfg = decode_prof.apply(cfg, runtime_only=True)
    buckets = None
    if max_bucket is not None:
        buckets = prefill_buckets(min(max_bucket, max_len), min_bucket)
    return DisaggEngine(
        model, params, prefill_mesh=pm, decode_mesh=dm,
        prefill_slots=prefill_slots, decode_slots=decode_slots,
        max_len=max_len, min_bucket=min_bucket, buckets=buckets,
        max_prefill_per_step=max_prefill_per_step,
        max_prefill_batch=max_prefill_batch, prefill_chunk=prefill_chunk,
        kv_block_size=kv_block_size, kv_blocks=kv_blocks,
        prefix_cache=prefix_cache, param_strategy=param_strategy,
        prefill_model=build_model(prefill_cfg) if prefill_cfg != cfg else None,
        decode_model=build_model(decode_cfg) if decode_cfg != cfg else None,
        policy=plan, program_memory=program_memory)


def build_parser() -> argparse.ArgumentParser:
    """The serving CLI, exposed as a function so tooling (and the
    docs/serving.md drift-check test) can introspect the live flag set."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--min-bucket", type=int, default=16)
    ap.add_argument("--max-bucket", type=int, default=None,
                    help="cap prefill buckets below max-len; longer prompts "
                         "run the chunked path")
    ap.add_argument("--max-prefill-per-step", type=int, default=1,
                    help="admissions per engine tick")
    ap.add_argument("--max-prefill-batch", type=int, default=4,
                    help="same-bucket admissions stacked into one compiled "
                         "prefill call")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunk width for prompts longer than the largest "
                         "bucket (default: the largest bucket)")
    ap.add_argument("--long-prompts", type=int, default=0,
                    help="also submit this many prompts longer than the "
                         "largest bucket (chunked prefill)")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-compile every engine program before serving")
    ap.add_argument("--kv-block-size", type=int, default=None,
                    help="page the KV cache into blocks of this many tokens "
                         "(must divide max-len); default: dense KV")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="physical blocks in the paged pool (default: the "
                         "dense equivalent slots*max-len/block-size)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="share same-prefix KV blocks across requests "
                         "(paged engines, full-attention models)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for submitted requests "
                         "(0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k most likely tokens (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1 = off)")
    ap.add_argument("--mesh", default="off",
                    help="device mesh for sharded serving: 'off' (default), "
                         "'auto' (all devices, data-parallel), or 'DPxMP' "
                         "(e.g. '4x2'); emulate devices on CPU with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    ap.add_argument("--dp", type=int, default=None,
                    help="data-parallel mesh axis (overrides --mesh; shards "
                         "slots and the paged block pool)")
    ap.add_argument("--mp", type=int, default=None,
                    help="model-parallel mesh axis (overrides --mesh; Mensa "
                         "cluster tensor parallelism)")
    ap.add_argument("--roles", default="off",
                    help="disaggregated prefill/decode serving: "
                         "'prefill=N,decode=M' pins each role to a disjoint "
                         "submesh of N (resp. M) x mp devices with paged-KV "
                         "suitcase handoff between them; 'off' (default) "
                         "keeps the single interleaved engine; mutually "
                         "exclusive with --mesh/--dp (tensor parallelism "
                         "inside each role comes from --mp)")
    ap.add_argument("--param-strategy", default="tp",
                    choices=("tp", "dp", "auto"),
                    help="weight sharding template on a mesh: Mensa cluster "
                         "TP, replicated-dp, or 'auto' — per cluster from "
                         "the placement plan's sharding_axis (memory-centric "
                         "clusters replicate, compute-centric ones take TP)")
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event JSON of the run here "
                         "(view at ui.perfetto.dev); tracing is on either "
                         "way — this just saves the buffer")
    ap.add_argument("--profile-dir", default="",
                    help="collect a jax.profiler trace of the serve loop "
                         "into this directory (TensorBoard/XLA view)")
    ap.add_argument("--metrics-json", default="",
                    help="write the final stats summary (including the "
                         "versioned obs metrics section) as JSON here")
    ap.add_argument("--metrics-prom", default="",
                    help="write the metrics registry in Prometheus/"
                         "OpenMetrics text exposition format here (a "
                         "node_exporter textfile-collector drop-in)")
    ap.add_argument("--program-memory",
                    action=argparse.BooleanOptionalAction, default=False,
                    help="AOT-compile each warmed program once for its "
                         "temp/argument/output memory watermarks (the "
                         "programs section always carries static FLOPs/"
                         "bytes; this adds the memory_analysis fields at "
                         "roughly 2x warmup compile time)")
    ap.add_argument("--policy", default="auto", choices=("auto", "fixed"),
                    help="'auto': the placement oracle characterizes and "
                         "clusters the served layers and picks kernel "
                         "variant / chunk / buckets per cluster; 'fixed': "
                         "constructor-global knobs only")
    ap.add_argument("--policy-dump", action="store_true",
                    help="print the resolved PlacementPlan as JSON and exit "
                         "without building the engine")
    return ap


def parse_args(argv=None) -> argparse.Namespace:
    return build_parser().parse_args(argv)


def mesh_from_args(args):
    """Resolve --mesh / --dp / --mp into a Mesh (or None for unsharded)."""
    from .mesh import make_serve_mesh, parse_mesh_arg
    if args.dp is not None or args.mp is not None:
        return make_serve_mesh(args.dp, args.mp or 1)
    return parse_mesh_arg(args.mesh)


def main(argv=None) -> None:
    args = parse_args(argv)

    plan_cfg = get_config(args.arch)
    from .mesh import parse_roles_arg
    roles = parse_roles_arg(args.roles)
    if roles is not None and (args.mesh != "off" or args.dp is not None):
        raise SystemExit("--roles is mutually exclusive with --mesh/--dp: "
                         "each role gets its own (N, mp) submesh")
    mesh = None if roles is not None else mesh_from_args(args)
    if roles is not None and args.mp is not None:
        roles = type(roles)(prefill=roles.prefill, decode=roles.decode,
                            mp=args.mp)
    plan_axes = ("data", "model") if roles is not None \
        else (tuple(mesh.axis_names) if mesh is not None else ())
    plan = None
    if args.policy == "auto" or args.policy_dump:
        plan = ExecutionOracle(
            plan_cfg, slots=args.slots, max_len=args.max_len,
            min_bucket=args.min_bucket, max_bucket=args.max_bucket,
            mesh_axes=plan_axes,
        ).resolve()
    if args.policy_dump:
        print(plan.dumps())
        return
    if plan is not None:
        print(f"[serve] placement plan ({plan.source}, backend "
              f"{plan.backend}): clusters {list(plan.layer_clusters)} "
              f"chunk={plan.prefill_chunk} "
              f"overrides={plan.decode_cfg_overrides}")
    prefill_prof, decode_prof = phase_profiles(plan_cfg, policy=plan)
    print(f"[serve] Mensa prefill plan for {args.arch}:")
    print(prefill_prof.plan.summary())
    print(f"[serve] prefill strategy={prefill_prof.strategy} "
          f"overrides={prefill_prof.cfg_overrides}")
    print(f"[serve] decode  strategy={decode_prof.strategy} "
          f"overrides={decode_prof.cfg_overrides}")

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if mesh is not None:
        print(f"[serve] mesh {dict(mesh.shape)} over {mesh.size} devices "
              f"(param strategy {args.param_strategy})")
    if roles is not None:
        print(f"[serve] disaggregated roles: prefill {roles.prefill}x"
              f"{roles.mp} devices, decode {roles.decode}x{roles.mp} "
              f"devices (param strategy {args.param_strategy})")
        engine = build_disagg_engine(
            cfg, roles=roles, prefill_slots=args.slots,
            decode_slots=args.slots,
            max_len=args.max_len, min_bucket=args.min_bucket,
            max_bucket=args.max_bucket,
            max_prefill_per_step=args.max_prefill_per_step,
            max_prefill_batch=args.max_prefill_batch,
            prefill_chunk=args.prefill_chunk,
            kv_block_size=args.kv_block_size, kv_blocks=args.kv_blocks,
            prefix_cache=args.prefix_cache,
            param_strategy=args.param_strategy,
            profiles=(prefill_prof, decode_prof),
            policy=plan if plan is not None else "fixed",
            program_memory=args.program_memory)
    else:
        engine = build_engine(
            cfg, slots=args.slots, max_len=args.max_len,
            min_bucket=args.min_bucket,
            max_bucket=args.max_bucket,
            max_prefill_per_step=args.max_prefill_per_step,
            max_prefill_batch=args.max_prefill_batch,
            prefill_chunk=args.prefill_chunk,
            kv_block_size=args.kv_block_size,
            kv_blocks=args.kv_blocks,
            prefix_cache=args.prefix_cache,
            mesh=mesh, param_strategy=args.param_strategy,
            profiles=(prefill_prof, decode_prof),
            policy=plan if plan is not None else "fixed",
            program_memory=args.program_memory)
    if args.warmup:
        engine.warmup()
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, 4 + i % 6).tolist(),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature, top_k=args.top_k,
                    top_p=args.top_p)
            for i in range(args.requests)]
    if args.long_prompts:
        long_len = min(engine.buckets[-1] + engine.prefill_chunk,
                       args.max_len - 1)
        if long_len <= engine.buckets[-1]:
            raise SystemExit(
                f"--long-prompts needs prompts longer than the largest "
                f"bucket ({engine.buckets[-1]}), but max_len {args.max_len} "
                f"leaves no admissible length above it — pass --max-bucket "
                f"below max_len (e.g. --max-bucket {args.max_len // 4})")
        reqs += [Request(rid=args.requests + i,
                         prompt=rng.randint(1, cfg.vocab_size,
                                            long_len).tolist(),
                         max_new_tokens=args.max_new,
                         temperature=args.temperature, top_k=args.top_k,
                         top_p=args.top_p)
                 for i in range(args.long_prompts)]
    with profile_trace(args.profile_dir):
        engine.run(reqs)
    summary = engine.summary() if isinstance(engine, DisaggEngine) \
        else engine.stats.summary()
    print(json.dumps(summary, indent=1))
    if args.trace:
        engine.save_trace(args.trace)
        print(f"[serve] trace written to {args.trace} "
              f"({len(engine.tracer)} events, {engine.tracer.dropped} "
              f"dropped) — load at ui.perfetto.dev")
    if args.metrics_json:
        Path(args.metrics_json).write_text(json.dumps(summary, indent=1)
                                           + "\n")
    if args.metrics_prom:
        registry = engine.decode.stats.metrics \
            if isinstance(engine, DisaggEngine) else engine.stats.metrics
        Path(args.metrics_prom).write_text(registry.to_prometheus())
        print(f"[serve] Prometheus metrics written to {args.metrics_prom}"
              + (" (decode role's registry; the prefill role keeps its own)"
                 if isinstance(engine, DisaggEngine) else ""))


if __name__ == "__main__":
    main()
