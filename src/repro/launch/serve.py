"""Serving driver: per-phase Mensa plans -> engine -> batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \\
      --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from ..configs import get_config, reduced_config
from ..core.executor import phase_profiles
from ..models import build_model
from ..serve.engine import Request, ServeEngine


def build_engine(cfg, params=None, *, slots: int = 4, max_len: int = 256,
                 min_bucket: int = 16, max_prefill_per_step: int = 1,
                 plan_cfg=None, profiles=None) -> ServeEngine:
    """Engine with the prefill/decode programs routed through their
    Mensa execution profiles (runtime-safe overrides only — the phase models
    share one parameter tree).  With today's cost model the serve-shape
    profiles often carry no runtime-safe overrides; the routing is the hook
    that picks them up as soon as measurement adds them.  Pass ``profiles``
    (a (prefill, decode) pair) to reuse already-computed plans."""
    prefill_prof, decode_prof = profiles or phase_profiles(plan_cfg or cfg)
    model = build_model(cfg)
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    prefill_cfg = prefill_prof.apply(cfg, runtime_only=True)
    decode_cfg = decode_prof.apply(cfg, runtime_only=True)
    return ServeEngine(
        model, params, slots=slots, max_len=max_len, min_bucket=min_bucket,
        max_prefill_per_step=max_prefill_per_step,
        prefill_model=build_model(prefill_cfg) if prefill_cfg != cfg else None,
        decode_model=build_model(decode_cfg) if decode_cfg != cfg else None)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--min-bucket", type=int, default=16)
    args = ap.parse_args()

    plan_cfg = get_config(args.arch)
    prefill_prof, decode_prof = phase_profiles(plan_cfg)
    print(f"[serve] Mensa prefill plan for {args.arch}:")
    print(prefill_prof.plan.summary())
    print(f"[serve] prefill strategy={prefill_prof.strategy} "
          f"overrides={prefill_prof.cfg_overrides}")
    print(f"[serve] decode  strategy={decode_prof.strategy} "
          f"overrides={decode_prof.cfg_overrides}")

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    engine = build_engine(cfg, slots=args.slots, max_len=args.max_len,
                          min_bucket=args.min_bucket,
                          profiles=(prefill_prof, decode_prof))
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, 4 + i % 6).tolist(),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    engine.run(reqs)
    print(json.dumps(engine.stats.summary(), indent=1))


if __name__ == "__main__":
    main()
