"""Serving driver: Mensa plan -> engine -> batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \\
      --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import SHAPES, get_config, reduced_config
from ..core.executor import execution_profile
from ..models import build_model
from ..serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    prof = execution_profile(get_config(args.arch), SHAPES["decode_32k"])
    print(f"[serve] Mensa plan for {args.arch}:")
    print(prof.plan.summary())
    print(f"[serve] strategy={prof.strategy} overrides={prof.cfg_overrides}")

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    cfg = prof.apply(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, 4 + i % 6).tolist(),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {tok} tokens, {dt:.2f}s "
          f"({tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
