"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required because the dry-run forces 512 host
devices while tests/benches must see 1.
"""
from __future__ import annotations

import dataclasses

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips, axes (data, model).
    Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model) — the `pod` axis
    carries only DCN-friendly gradient/batch parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that carry batch parallelism."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_host_mesh(shape=(1,), axes=("data",)):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    import numpy as np
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())}")
    return jax.make_mesh(shape, axes)


def make_serve_mesh(dp: int | None = None, mp: int = 1):
    """Serving mesh: a (data, model) grid over the first dp*mp devices.

    ``dp`` defaults to every device not consumed by ``mp`` — so
    ``make_serve_mesh()`` is pure data parallelism over all devices, the
    layout that keeps sharded serving bitwise identical to single-device
    (per-slot math never crosses a shard).  ``mp > 1`` adds tensor
    parallelism through the Mensa cluster specs in shardings.py.

    Host-device emulation (CI, laptops):
      XLA_FLAGS=--xla_force_host_platform_device_count=8
    """
    import numpy as np
    ndev = len(jax.devices())
    if mp < 1:
        raise ValueError(f"mp must be >= 1, got {mp}")
    if dp is None:
        dp = max(1, ndev // mp)
    if dp < 1:
        raise ValueError(f"dp must be >= 1, got {dp}")
    if dp * mp > ndev:
        raise RuntimeError(f"mesh {dp}x{mp} needs {dp * mp} devices, "
                           f"have {ndev}")
    devices = np.asarray(jax.devices()[:dp * mp]).reshape(dp, mp)
    return jax.sharding.Mesh(devices, ("data", "model"))


@dataclasses.dataclass(frozen=True)
class RoleConfig:
    """Device partition for disaggregated serving: ``prefill`` data-parallel
    ranks feed ``decode`` ranks over disjoint submeshes of one device set.
    ``mp`` multiplies both (tensor parallelism within each role)."""
    prefill: int
    decode: int
    mp: int = 1

    def __post_init__(self):
        if self.prefill < 1 or self.decode < 1 or self.mp < 1:
            raise ValueError(f"role counts must be >= 1, got {self}")

    @property
    def devices(self) -> int:
        return (self.prefill + self.decode) * self.mp


def parse_roles_arg(spec: str) -> RoleConfig | None:
    """Parse a ``--roles`` string: "off"/"none"/"" (interleaved engine) or
    "prefill=N,decode=M" (disaggregated, N+M devices)."""
    s = spec.strip().lower()
    if s in ("off", "none", ""):
        return None
    kv = {}
    for part in s.split(","):
        key, eq, val = part.partition("=")
        try:
            if not eq:
                raise ValueError
            kv[key.strip()] = int(val)
        except ValueError as e:
            raise ValueError(f"--roles {spec!r}: expected "
                             f"'prefill=N,decode=M' or 'off'") from e
    unknown = set(kv) - {"prefill", "decode"}
    if unknown or set(kv) != {"prefill", "decode"}:
        raise ValueError(f"--roles {spec!r}: expected exactly "
                         f"'prefill=N,decode=M' or 'off'")
    return RoleConfig(prefill=kv["prefill"], decode=kv["decode"])


def make_role_meshes(roles: RoleConfig):
    """Disjoint (data, model) submeshes for the two roles: prefill takes the
    first ``prefill*mp`` devices, decode the next ``decode*mp``.  Disjointness
    is the point — a prefill burst cannot steal decode's cycles — so the
    partition raises rather than oversubscribing."""
    import numpy as np
    devs = jax.devices()
    if roles.devices > len(devs):
        raise RuntimeError(f"roles {roles.prefill}+{roles.decode} (mp="
                           f"{roles.mp}) need {roles.devices} devices, "
                           f"have {len(devs)}")
    n_pre = roles.prefill * roles.mp
    pre = np.asarray(devs[:n_pre]).reshape(roles.prefill, roles.mp)
    dec = np.asarray(devs[n_pre:n_pre + roles.decode * roles.mp]) \
            .reshape(roles.decode, roles.mp)
    return (jax.sharding.Mesh(pre, ("data", "model")),
            jax.sharding.Mesh(dec, ("data", "model")))


def parse_mesh_arg(spec: str):
    """Parse a ``--mesh`` string: "auto" (all devices, data-parallel),
    "off"/"none" (no mesh), or "DPxMP" (e.g. "4x2")."""
    s = spec.strip().lower()
    if s in ("off", "none", ""):
        return None
    if s == "auto":
        return make_serve_mesh()
    dp, _, mp = s.partition("x")
    try:
        dp, mp = int(dp), int(mp) if mp else 1
    except ValueError as e:
        raise ValueError(f"--mesh {spec!r}: expected 'auto', 'off', or "
                         f"'DPxMP' like '4x2'") from e
    return make_serve_mesh(dp, mp)
