"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required because the dry-run forces 512 host
devices while tests/benches must see 1.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips, axes (data, model).
    Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model) — the `pod` axis
    carries only DCN-friendly gradient/batch parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that carry batch parallelism."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_host_mesh(shape=(1,), axes=("data",)):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    import numpy as np
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())}")
    return jax.make_mesh(shape, axes)
