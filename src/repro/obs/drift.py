"""Placement drift: measured phase latency vs the frozen plan's predictions.

PR 7's ``ExecutionOracle`` freezes a :class:`~repro.serve.placement.PlacementPlan`
with predicted per-phase costs before anything compiles; this module is the
*runtime* side of that loop — the shared arithmetic for comparing what the
engine measured (through device-synchronized ``Timed`` sections) against what
the plan promised.  ``benchmarks/calibrate.py`` fits its cross-arch platform
scale with the same :func:`geomean` / :func:`residual_factor` used here, so
the drift section in ``EngineStats.summary()`` (and in every saved trace)
agrees number-for-number with the calibration gate.

Both sides are normalized to comparable units before the ratio:
``prefill_token_s`` (the plan predicts one full chunk; divide by the chunk
width) and ``decode_step_s`` (one lockstep tick, already per step).  A ratio
of 1.0 means the cost model nailed it on this platform; the residual factor
``exp(|log ratio|) >= 1`` is the symmetric multiplicative miss.
"""
from __future__ import annotations

import math

#: phases the drift monitor tracks (plan prediction keys normalized per unit)
PHASES = ("prefill_token_s", "decode_step_s")


def geomean(xs) -> float:
    """Geometric mean of positive values (the log-space fit center)."""
    xs = list(xs)
    if not xs:
        raise ValueError("geomean of an empty sequence")
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def residual_factor(ratio: float, scale: float = 1.0) -> float:
    """Symmetric multiplicative residual ``exp(|log(ratio / scale)|) >= 1``:
    2x-too-fast and 2x-too-slow both score 2.0."""
    return math.exp(abs(math.log(ratio / scale)))


def plan_predictions(placement: dict) -> dict:
    """Per-unit predicted phase times from a plan ``summary()`` dict: the
    plan predicts one full prefill chunk, so prefill normalizes per token.
    Phases without a positive prediction (fixed plans) are omitted."""
    pred = placement.get("predicted") or {}
    chunk = placement.get("prefill_chunk") or 0
    out = {}
    if pred.get("prefill_chunk_s") and chunk:
        out["prefill_token_s"] = pred["prefill_chunk_s"] / chunk
    if pred.get("decode_step_s"):
        out["decode_step_s"] = pred["decode_step_s"]
    return out


def drift_report(predicted: dict, measured: dict) -> dict:
    """Per-phase predicted/measured/ratio/residual, for every phase both
    sides have a positive value for.  Empty dict when nothing is comparable
    (fixed plans, engines that have not run yet)."""
    phases = {}
    worst = 1.0
    for ph in PHASES:
        pv, mv = predicted.get(ph), measured.get(ph)
        if not pv or not mv or pv <= 0 or mv <= 0:
            continue
        ratio = mv / pv
        rf = residual_factor(ratio)
        worst = max(worst, rf)
        phases[ph] = {"predicted": pv, "measured": mv, "ratio": ratio,
                      "residual_factor": rf}
    if not phases:
        return {}
    return {"phases": phases, "max_residual_factor": worst}
