"""Serving metrics registry: counters, gauges + fixed-bucket log2 histograms.

The registry replaces ad-hoc windowed sample lists in ``EngineStats``.  Each
histogram keeps a preallocated array of log2 buckets (bucket ``i`` covers
``[base * 2**(i-1), base * 2**i)``; bucket 0 is everything below ``base``)
next to exact streaming aggregates (count / sum / min / max), so recording a
sample is O(1) with no growth, percentiles stay available forever on a
long-lived engine, and serialization is a fixed-size dict however much
traffic flowed through.  Quantiles interpolate inside the landing bucket and
are clamped to the exact [min, max] envelope — within one bucket width
(a factor of 2 at ``base=1e-6``-grained latencies) of the true value.

``MetricsRegistry.to_dict()`` is the versioned ``obs`` section of
``EngineStats.summary()``; bump ``OBS_SCHEMA_VERSION`` on any shape change.
``to_prometheus()`` renders the same registry in the Prometheus text
exposition format (one scrape-able snapshot, counters as ``_total``,
histograms as cumulative ``le`` buckets) for ``--metrics-prom``.
"""
from __future__ import annotations

import math
import re

#: version of the serialized ``obs`` stats section (see docs/observability.md)
#: v2: added the ``gauges`` section (device-memory telemetry)
OBS_SCHEMA_VERSION = 2

_PROM_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(prefix: str, name: str) -> str:
    n = _PROM_NAME_RE.sub("_", f"{prefix}_{name}" if prefix else name)
    return n if not n[:1].isdigit() else f"_{n}"


def _prom_num(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f.is_integer() and abs(f) < 2 ** 53 else repr(f)


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "unit", "value")

    def __init__(self, name: str, unit: str = ""):
        self.name = name
        self.unit = unit
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def to_dict(self) -> dict:
        return {"unit": self.unit, "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value (pool bytes, watermarks)."""

    __slots__ = ("name", "unit", "value")

    def __init__(self, name: str, unit: str = ""):
        self.name = name
        self.unit = unit
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def to_dict(self) -> dict:
        return {"unit": self.unit, "value": self.value}


class Histogram:
    """Fixed-size log2 histogram with exact streaming aggregates.

    ``base`` is the resolution floor: bucket 0 counts samples below it,
    bucket ``i >= 1`` counts ``[base * 2**(i-1), base * 2**i)``, and the last
    bucket absorbs everything above the range.  64 buckets at ``base=1e-6``
    span microseconds to ~290 years of latency.
    """

    __slots__ = ("name", "unit", "base", "nbuckets", "counts",
                 "count", "sum", "min", "max")

    def __init__(self, name: str, *, base: float = 1e-6, nbuckets: int = 64,
                 unit: str = "s"):
        if base <= 0 or nbuckets < 2:
            raise ValueError(f"need base > 0 and >= 2 buckets, got "
                             f"{base} x {nbuckets}")
        self.name = name
        self.unit = unit
        self.base = base
        self.nbuckets = nbuckets
        self.counts = [0] * nbuckets
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def bucket_of(self, v: float) -> int:
        if v < self.base:
            return 0
        # frexp: v/base = m * 2**e with m in [0.5, 1) -> floor(log2) == e - 1,
        # so values in [base * 2**(i-1), base * 2**i) land in bucket i
        e = math.frexp(v / self.base)[1]
        return min(self.nbuckets - 1, max(0, e))

    def record(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self.counts[self.bucket_of(v)] += 1

    def bucket_lo(self, i: int) -> float:
        return 0.0 if i == 0 else self.base * 2.0 ** (i - 1)

    def bucket_hi(self, i: int) -> float:
        return self.base * 2.0 ** i

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Approximate quantile: linear interpolation inside the landing
        bucket, clamped to the exact [min, max] envelope."""
        if not self.count:
            return 0.0
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        rank = q * self.count
        seen = 0.0
        for i, n in enumerate(self.counts):
            if not n:
                continue
            if seen + n >= rank:
                frac = min(1.0, max(0.0, (rank - seen) / n))
                lo, hi = self.bucket_lo(i), self.bucket_hi(i)
                return min(self.max, max(self.min, lo + (hi - lo) * frac))
            seen += n
        return self.max

    def to_dict(self) -> dict:
        return {
            "unit": self.unit,
            "base": self.base,
            "nbuckets": self.nbuckets,
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            # sparse: only occupied buckets, keyed by bucket index
            "buckets": {str(i): n for i, n in enumerate(self.counts) if n},
        }


class MetricsRegistry:
    """Get-or-create registry of named counters and histograms."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str, unit: str = "") -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, unit)
        return c

    def gauge(self, name: str, unit: str = "") -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, unit)
        return g

    def histogram(self, name: str, **kw) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, **kw)
        return h

    def to_dict(self) -> dict:
        return {
            "version": OBS_SCHEMA_VERSION,
            "counters": {k: c.to_dict()
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.to_dict()
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.to_dict()
                           for k, h in sorted(self._histograms.items())},
        }

    def to_prometheus(self, prefix: str = "repro_serve") -> str:
        """The registry in Prometheus/OpenMetrics text exposition format.

        Counters get the conventional ``_total`` suffix; histograms render
        their log2 buckets as the cumulative ``le``-labelled series (upper
        bound = ``bucket_hi``), truncated after the last occupied bucket —
        the mandatory ``+Inf`` bucket carries the total count either way.
        ``#`` HELP lines carry the unit (scrapers ignore them)."""
        lines: list[str] = []
        for key, c in sorted(self._counters.items()):
            n = _prom_name(prefix, key) + "_total"
            if c.unit:
                lines.append(f"# HELP {n} ({c.unit})")
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n} {_prom_num(c.value)}")
        for key, g in sorted(self._gauges.items()):
            n = _prom_name(prefix, key)
            if g.unit:
                lines.append(f"# HELP {n} ({g.unit})")
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {_prom_num(g.value)}")
        for key, h in sorted(self._histograms.items()):
            n = _prom_name(prefix, key)
            if h.unit:
                lines.append(f"# HELP {n} ({h.unit})")
            lines.append(f"# TYPE {n} histogram")
            last = max((i for i, c in enumerate(h.counts) if c), default=-1)
            cum = 0
            for i in range(last + 1):
                cum += h.counts[i]
                lines.append(f'{n}_bucket{{le="{_prom_num(h.bucket_hi(i))}"}}'
                             f" {cum}")
            lines.append(f'{n}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{n}_sum {_prom_num(h.sum)}")
            lines.append(f"{n}_count {h.count}")
        return "\n".join(lines) + "\n"
