"""Serving metrics registry: counters + fixed-bucket log2 histograms.

The registry replaces ad-hoc windowed sample lists in ``EngineStats``.  Each
histogram keeps a preallocated array of log2 buckets (bucket ``i`` covers
``[base * 2**(i-1), base * 2**i)``; bucket 0 is everything below ``base``)
next to exact streaming aggregates (count / sum / min / max), so recording a
sample is O(1) with no growth, percentiles stay available forever on a
long-lived engine, and serialization is a fixed-size dict however much
traffic flowed through.  Quantiles interpolate inside the landing bucket and
are clamped to the exact [min, max] envelope — within one bucket width
(a factor of 2 at ``base=1e-6``-grained latencies) of the true value.

``MetricsRegistry.to_dict()`` is the versioned ``obs`` section of
``EngineStats.summary()``; bump ``OBS_SCHEMA_VERSION`` on any shape change.
"""
from __future__ import annotations

import math

#: version of the serialized ``obs`` stats section (see docs/observability.md)
OBS_SCHEMA_VERSION = 1


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "unit", "value")

    def __init__(self, name: str, unit: str = ""):
        self.name = name
        self.unit = unit
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def to_dict(self) -> dict:
        return {"unit": self.unit, "value": self.value}


class Histogram:
    """Fixed-size log2 histogram with exact streaming aggregates.

    ``base`` is the resolution floor: bucket 0 counts samples below it,
    bucket ``i >= 1`` counts ``[base * 2**(i-1), base * 2**i)``, and the last
    bucket absorbs everything above the range.  64 buckets at ``base=1e-6``
    span microseconds to ~290 years of latency.
    """

    __slots__ = ("name", "unit", "base", "nbuckets", "counts",
                 "count", "sum", "min", "max")

    def __init__(self, name: str, *, base: float = 1e-6, nbuckets: int = 64,
                 unit: str = "s"):
        if base <= 0 or nbuckets < 2:
            raise ValueError(f"need base > 0 and >= 2 buckets, got "
                             f"{base} x {nbuckets}")
        self.name = name
        self.unit = unit
        self.base = base
        self.nbuckets = nbuckets
        self.counts = [0] * nbuckets
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def bucket_of(self, v: float) -> int:
        if v < self.base:
            return 0
        # frexp: v/base = m * 2**e with m in [0.5, 1) -> floor(log2) == e - 1,
        # so values in [base * 2**(i-1), base * 2**i) land in bucket i
        e = math.frexp(v / self.base)[1]
        return min(self.nbuckets - 1, max(0, e))

    def record(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self.counts[self.bucket_of(v)] += 1

    def bucket_lo(self, i: int) -> float:
        return 0.0 if i == 0 else self.base * 2.0 ** (i - 1)

    def bucket_hi(self, i: int) -> float:
        return self.base * 2.0 ** i

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Approximate quantile: linear interpolation inside the landing
        bucket, clamped to the exact [min, max] envelope."""
        if not self.count:
            return 0.0
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        rank = q * self.count
        seen = 0.0
        for i, n in enumerate(self.counts):
            if not n:
                continue
            if seen + n >= rank:
                frac = min(1.0, max(0.0, (rank - seen) / n))
                lo, hi = self.bucket_lo(i), self.bucket_hi(i)
                return min(self.max, max(self.min, lo + (hi - lo) * frac))
            seen += n
        return self.max

    def to_dict(self) -> dict:
        return {
            "unit": self.unit,
            "base": self.base,
            "nbuckets": self.nbuckets,
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            # sparse: only occupied buckets, keyed by bucket index
            "buckets": {str(i): n for i, n in enumerate(self.counts) if n},
        }


class MetricsRegistry:
    """Get-or-create registry of named counters and histograms."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str, unit: str = "") -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, unit)
        return c

    def histogram(self, name: str, **kw) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, **kw)
        return h

    def to_dict(self) -> dict:
        return {
            "version": OBS_SCHEMA_VERSION,
            "counters": {k: c.to_dict()
                         for k, c in sorted(self._counters.items())},
            "histograms": {k: h.to_dict()
                           for k, h in sorted(self._histograms.items())},
        }
