"""Allocation-light event tracer with Chrome trace-event JSON export.

The tracer is ON by default in the serving engine, so the hot path must cost
near nothing: events land in a preallocated ring buffer as plain tuples
``(ph, name, tid, ts, dur, args)`` — no dicts, no growth, no I/O — and the
Chrome-format dicts are only materialized at export time.  When the ring
wraps, the oldest events drop and :attr:`Tracer.dropped` says how many (the
export records it too, so a truncated trace is never mistaken for a quiet
engine).

Event vocabulary (Chrome trace-event ``ph`` codes; see docs/observability.md):

  * ``X`` complete span   — a timed section (prefill call, chunk, decode tick)
  * ``B`` / ``E``         — a request's residency on its slot (admit → finish)
  * ``i`` instant         — submit, stall, copy-on-write, abort
  * ``C`` counter         — per-tick series (queue depth, slot occupancy,
                            KV-pool in-use/cached, per shard)

Tracks are integer ``tid``s named via :meth:`Tracer.set_track` (exported as
``thread_name`` metadata): the engine uses track 0 for queue-level request
events, one track per slot, and one for engine-wide spans.  Timestamps are
``time.perf_counter()`` seconds, exported as microseconds relative to the
tracer's epoch; the export is stably sorted by timestamp so every track is
monotonic and ``B``/``E`` pairs nest.  Load the file at ``ui.perfetto.dev``
or ``chrome://tracing``.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

#: default ring capacity — ~4 MB of tuples, tens of thousands of ticks
DEFAULT_CAPACITY = 65536


class Tracer:
    """Ring-buffered structured-event recorder.

    ``enabled`` may be toggled at runtime (the overhead gate in
    benchmarks/serve_bench.py measures exactly this switch); a disabled
    tracer's emit methods return immediately.  ``clock`` is the shared
    monotonic clock — the engine stamps *all* its times through
    :meth:`now` so spans, stats, and TTFTs live on one timeline.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY, *,
                 enabled: bool = True, clock=time.perf_counter):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.enabled = enabled
        self.clock = clock
        self._buf: list = [None] * capacity
        self._n = 0
        self._epoch = clock()
        self._tracks: dict[int, str] = {}

    # ------------------------------------------------------------------ clock
    def now(self) -> float:
        return self.clock()

    # ----------------------------------------------------------------- tracks
    def set_track(self, tid: int, name: str) -> None:
        self._tracks[tid] = name

    # ------------------------------------------------------------------- emit
    def emit(self, ph: str, name: str, tid: int, ts: float,
             dur: float = 0.0, args: tuple = ()) -> None:
        """Append one raw event; ``args`` is a tuple of (key, value) pairs
        (dicts are built only at export)."""
        if not self.enabled:
            return
        self._buf[self._n % self.capacity] = (ph, name, tid, ts, dur, args)
        self._n += 1

    def span(self, name: str, tid: int, t0: float, t1: float,
             args: tuple = ()) -> None:
        self.emit("X", name, tid, t0, t1 - t0, args)

    def begin(self, name: str, tid: int, ts: float, args: tuple = ()) -> None:
        self.emit("B", name, tid, ts, 0.0, args)

    def end(self, name: str, tid: int, ts: float, args: tuple = ()) -> None:
        self.emit("E", name, tid, ts, 0.0, args)

    def instant(self, name: str, tid: int, ts: float,
                args: tuple = ()) -> None:
        self.emit("i", name, tid, ts, 0.0, args)

    def counter(self, name: str, ts: float, series: tuple) -> None:
        """One multi-series counter sample; ``series`` is (name, value) pairs
        rendered as stacked counter tracks by the viewer."""
        self.emit("C", name, 0, ts, 0.0, series)

    # ------------------------------------------------------------------ state
    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def dropped(self) -> int:
        """Events lost to ring wrap-around since the last :meth:`clear`."""
        return max(0, self._n - self.capacity)

    def clear(self) -> None:
        self._buf = [None] * self.capacity
        self._n = 0
        self._epoch = self.clock()

    def events(self) -> list:
        """Retained raw events, stably sorted by timestamp (emission order
        breaks ties), oldest first."""
        if self._n <= self.capacity:
            raw = self._buf[:self._n]
        else:
            cut = self._n % self.capacity
            raw = self._buf[cut:] + self._buf[:cut]
        return sorted(raw, key=lambda e: e[3])

    # ----------------------------------------------------------------- export
    def _us(self, ts: float) -> float:
        return round((ts - self._epoch) * 1e6, 3)

    def chrome_events(self, pid: int = 0) -> list[dict]:
        """The ``traceEvents`` array: track-name metadata first, then every
        retained event in Chrome trace-event form."""
        out = [{"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
                "args": {"name": "serve_engine"}}]
        for tid in sorted(self._tracks):
            out.append({"ph": "M", "pid": pid, "tid": tid,
                        "name": "thread_name",
                        "args": {"name": self._tracks[tid]}})
            out.append({"ph": "M", "pid": pid, "tid": tid,
                        "name": "thread_sort_index",
                        "args": {"sort_index": tid}})
        for ph, name, tid, ts, dur, args in self.events():
            e = {"ph": ph, "pid": pid, "tid": tid, "name": name,
                 "cat": "serve", "ts": self._us(ts)}
            if ph == "X":
                e["dur"] = round(dur * 1e6, 3)
            if ph == "i":
                e["s"] = "t"                 # thread-scoped instant
            if args:
                e["args"] = dict(args)
            out.append(e)
        return out

    def to_chrome(self, other_data: dict | None = None) -> dict:
        doc = {
            "traceEvents": self.chrome_events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }
        if other_data:
            doc["otherData"].update(other_data)
        return doc

    def dumps(self, other_data: dict | None = None) -> str:
        return json.dumps(self.to_chrome(other_data))

    def save(self, path, other_data: dict | None = None) -> None:
        Path(path).write_text(self.dumps(other_data) + "\n")
