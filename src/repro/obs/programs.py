"""Program-level cost observatory: the warmed inventory, measured live.

The source paper's method is per-layer characterization — FLOP/B intensity,
MAC utilization, memory footprint — against each accelerator's roofline.
:class:`ProgramRegistry` is that table for the *serving* unit of execution,
the compiled program: every jitted program in ``ServeEngine``'s warmed
inventory registers here with its static cost (FLOPs / bytes accessed from
the lowered HLO via :func:`~repro.utils.hlo.normalize_cost_analysis`,
temp/argument/output bytes from the compiled executable via
:func:`~repro.utils.hlo.normalize_memory_analysis`) and accumulates what the
engine actually measured through its device-synchronized ``Timed`` sections
— invocation counts and seconds.  The quotient is live per-program FLOP/s,
bytes/s, and utilization against the ``core/accelerators`` roofline peaks,
surfaced as the versioned ``programs`` section of ``EngineStats.summary()``.

Static costs come from the ahead-of-time lowering path
(``jit_fn.lower(args).cost_analysis()`` — no XLA compile), so registration
is cheap; the optional memory analysis compiles the lowered program once
(``memory=True``), which the AOT cache keeps separate from the dispatch
cache — the engine's zero-recompile invariant is untouched either way.

:meth:`ProgramRegistry.cluster_rollup` maps the measured phase totals back
onto the owning :class:`~repro.serve.placement.PlacementPlan` clusters so
per-cluster measured-vs-predicted rolls into the ``obs.drift`` monitor.
Until per-layer timing exists, a phase's measured seconds are attributed to
clusters by their *predicted* share of that phase — the attribution (which
cluster consumed the wall time, on which Mensa accelerator) is the data; the
per-cluster ratio is uniform within a phase by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..core.accelerators import TPU_V5E, by_name
from ..utils.hlo import normalize_cost_analysis, normalize_memory_analysis

#: version of the ``programs`` section of ``EngineStats.summary()``
#: (see docs/observability.md); bump on any shape change
PROGRAMS_SCHEMA_VERSION = 1

#: phases the cluster rollup attributes (the copy/KV-maintenance programs
#: carry no plan prediction and stay out of the rollup)
ROLLUP_PHASES = ("prefill", "decode")


@dataclass
class ProgramEntry:
    """One compiled program: static cost + accumulated measurements."""
    name: str
    phase: str = ""                    # "prefill" | "decode" | "kv"
    program: str = ""                  # owning jit attribute, e.g. "_prefill"
    flops: float = 0.0                 # per invocation, from the lowered HLO
    bytes_accessed: float = 0.0        # per invocation
    memory: dict = field(default_factory=dict)   # normalize_memory_analysis
    analyzed: bool = False             # static cost extraction succeeded
    invocations: int = 0
    measured_s: float = 0.0            # device-synchronized (Timed.dur) total

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / self.bytes_accessed if self.bytes_accessed else 0.0


class ProgramRegistry:
    """Registry of an engine's compiled programs with live roofline rates.

    ``chip`` is the host-chip roofline the utilization figures divide by
    (default :data:`~repro.core.accelerators.TPU_V5E`, the repo's analytic
    reference); ``plan_summary`` is the owning ``PlacementPlan.summary()``
    dict the cluster rollup attributes against (optional)."""

    def __init__(self, chip=TPU_V5E, plan_summary: dict | None = None):
        self.chip = chip
        self.plan = plan_summary or {}
        self._entries: dict[str, ProgramEntry] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def entry(self, name: str) -> ProgramEntry | None:
        return self._entries.get(name)

    def register(self, name: str, fn, args, *, phase: str,
                 program: str = "", memory: bool = False) -> ProgramEntry:
        """Register one program with the static cost of its lowered HLO.

        ``fn`` is the jitted callable, ``args`` the exact call arguments (or
        ``jax.ShapeDtypeStruct`` trees) — lowering only reads avals, so live
        (even about-to-be-donated) arrays are fine.  ``memory=True``
        additionally AOT-compiles the lowering for its
        ``memory_analysis()`` watermarks.  Cost extraction degrades
        gracefully (entry stays un-``analyzed``) on backends without the
        analyses; registration itself never raises into the serving path."""
        e = self._entries.setdefault(name, ProgramEntry(name))
        e.phase, e.program = phase, program
        try:
            lowered = fn.lower(*args)
            cost = normalize_cost_analysis(lowered.cost_analysis())
            e.flops = float(cost.get("flops", 0.0))
            e.bytes_accessed = float(cost.get("bytes accessed", 0.0))
            e.analyzed = True
            if memory:
                e.memory = normalize_memory_analysis(
                    lowered.compile().memory_analysis())
        except Exception:               # noqa: BLE001 — observability must
            pass                        # never take the serving path down
        return e

    def observe(self, name: str, dur: float, *, phase: str = "",
                program: str = "") -> None:
        """Accumulate one device-synchronized invocation (``Timed.dur``)."""
        e = self._entries.get(name)
        if e is None:
            e = self._entries[name] = ProgramEntry(name, phase=phase,
                                                   program=program)
        e.invocations += 1
        e.measured_s += dur

    def reset_observed(self) -> None:
        """Zero the dynamic accumulators; static registration survives
        (mirrors ``ServeEngine.reset_stats``)."""
        for e in self._entries.values():
            e.invocations = 0
            e.measured_s = 0.0

    def temp_bytes_peak(self) -> int:
        """High-water compiled temp memory across the inventory (0 until a
        program was registered with ``memory=True``)."""
        return max((int(e.memory.get("temp_size_in_bytes", 0))
                    for e in self._entries.values()), default=0)

    def phase_totals(self) -> dict:
        """Per-phase sums over the inventory: measured seconds and total
        executed FLOPs/bytes (static cost x invocations)."""
        out: dict = {}
        for e in self._entries.values():
            t = out.setdefault(e.phase or "?", {"measured_s": 0.0,
                                                "flops": 0.0, "bytes": 0.0,
                                                "invocations": 0})
            t["measured_s"] += e.measured_s
            t["flops"] += e.flops * e.invocations
            t["bytes"] += e.bytes_accessed * e.invocations
            t["invocations"] += e.invocations
        return out

    def cluster_rollup(self) -> dict:
        """Measured phase time attributed to the plan's clusters.

        Each cluster's policy predicted its share of a phase
        (``predicted_prefill_s`` / ``predicted_decode_s``); the measured
        phase total splits by those shares, and the cluster's attributed
        FLOP/s divides by its designated Mensa accelerator's peak — the
        paper's per-cluster characterization, live.  Empty without a plan
        (fixed engines) or before anything ran."""
        policies = self.plan.get("policies") or []
        if not policies:
            return {}
        totals = self.phase_totals()
        pred_key = {"prefill": "predicted_prefill_s",
                    "decode": "predicted_decode_s"}
        out: dict = {}
        for ph in ROLLUP_PHASES:
            meas = totals.get(ph)
            total_pred = sum(p.get(pred_key[ph]) or 0.0 for p in policies)
            if not meas or not meas["measured_s"] or total_pred <= 0:
                continue
            for pol in policies:
                pred = pol.get(pred_key[ph]) or 0.0
                if pred <= 0:
                    continue
                share = pred / total_pred
                measured = share * meas["measured_s"]
                flops = share * meas["flops"]
                try:
                    peak = by_name(pol["accelerator"]).peak_flops
                except (KeyError, TypeError):
                    peak = 0.0
                c = out.setdefault(str(pol["cluster"]), {
                    "accelerator": pol.get("accelerator"),
                    "kinds": list(pol.get("kinds") or ()),
                })
                c[ph] = {
                    "share": share,
                    "predicted_s": pred,
                    "measured_s": measured,
                    "ratio": measured / pred,
                    "flops": flops,
                    "flops_per_s": flops / measured if measured else 0.0,
                    "utilization": (flops / measured / peak)
                    if measured and peak else 0.0,
                }
        return out

    def summary(self) -> dict:
        """The versioned ``programs`` section of ``EngineStats.summary()``."""
        programs = {}
        for name in sorted(self._entries):
            e = self._entries[name]
            total_flops = e.flops * e.invocations
            total_bytes = e.bytes_accessed * e.invocations
            fps = total_flops / e.measured_s if e.measured_s else 0.0
            bps = total_bytes / e.measured_s if e.measured_s else 0.0
            rec = {
                "phase": e.phase,
                "program": e.program,
                "analyzed": e.analyzed,
                "flops": e.flops,
                "bytes_accessed": e.bytes_accessed,
                "arithmetic_intensity": e.arithmetic_intensity,
                "invocations": e.invocations,
                "measured_s": e.measured_s,
                "flops_per_s": fps,
                "bytes_per_s": bps,
                "utilization": fps / self.chip.peak_flops,
                "bandwidth_utilization": bps / self.chip.hbm_bw,
            }
            if e.memory:
                rec["memory"] = dict(e.memory)
            programs[name] = rec
        out = {
            "version": PROGRAMS_SCHEMA_VERSION,
            "chip": {"name": self.chip.name,
                     "peak_flops": self.chip.peak_flops,
                     "hbm_bw": self.chip.hbm_bw},
            "programs": programs,
        }
        peak_tmp = self.temp_bytes_peak()
        if peak_tmp:
            out["temp_bytes_peak"] = peak_tmp
        clusters = self.cluster_rollup()
        if clusters:
            out["clusters"] = clusters
        return out
