"""Persistent perf ledger: append-only run history + rolling-median trend.

``results/`` is single-point snapshots — a committed baseline and the last
CI run — so the bench trajectory between refreshes is invisible.  The ledger
keeps it: ``serve_bench --ledger`` appends one schema-versioned JSON line
per run (tokens/s, TTFT p50, prefix hit rate, trace overhead, per-program
utilization, git sha), and :func:`trend_check` gates the newest record
against the rolling median of its predecessors — a history-aware band
instead of a single committed point.

Stdlib-only on purpose (no jax, no numpy): the trend check must be runnable
as a standalone blocking CI step (``python -m repro.obs.ledger``) and from
``benchmarks/report.py ledger`` without pulling the serving stack in.
"""
from __future__ import annotations

import json
import statistics
import subprocess
import time
from pathlib import Path

#: version of one ledger record; bump on any shape change
LEDGER_SCHEMA_VERSION = 1

#: trended metrics: (record key, "higher" | "lower" is better).  Gate-style
#: absolutes (recompiles, overhead budget) stay with the bench's own
#: assertions — the ledger trends the two throughput/latency numbers a
#: slow regression could walk past a fixed baseline.
TREND_METRICS = (("tokens_per_s", "higher"), ("ttft_p50_ms", "lower"))

#: default regression band (fraction of the rolling median) and window —
#: generous on purpose: CI-runner variance must not flag, a real regression
#: (2x latency, half throughput) must
DEFAULT_BAND = 0.5
DEFAULT_WINDOW = 8
#: records required before the trend binds (the first runs always pass)
MIN_HISTORY = 2


def git_sha(root: Path | str | None = None) -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "unknown"


def make_record(*, arch: str, tokens_per_s: float, ttft_p50_ms: float,
                prefix_hit_rate: float | None = None,
                trace_overhead_frac: float | None = None,
                recompiles_after_warmup: int | None = None,
                program_utilization: dict | None = None,
                sha: str | None = None, extra: dict | None = None) -> dict:
    """One ledger line.  ``time.time()`` is the run's wall-clock identity —
    host-side file bookkeeping, nothing here touches a device."""
    rec = {
        "version": LEDGER_SCHEMA_VERSION,
        "ts": time.time(),
        "git_sha": sha if sha is not None else git_sha(),
        "arch": arch,
        "tokens_per_s": float(tokens_per_s),
        "ttft_p50_ms": float(ttft_p50_ms),
    }
    if prefix_hit_rate is not None:
        rec["prefix_hit_rate"] = float(prefix_hit_rate)
    if trace_overhead_frac is not None:
        rec["trace_overhead_frac"] = float(trace_overhead_frac)
    if recompiles_after_warmup is not None:
        rec["recompiles_after_warmup"] = int(recompiles_after_warmup)
    if program_utilization:
        rec["program_utilization"] = dict(program_utilization)
    if extra:
        rec.update(extra)
    return rec


def record_from_report(report: dict, *, sha: str | None = None,
                       extra: dict | None = None) -> dict:
    """A ledger record from a ``serve_bench`` report dict.  ``extra`` merges
    additional fields into the record (e.g. the disaggregated run's per-role
    tokens/s) without widening the schema for runs that lack them."""
    m = report["measure"]
    kv = report.get("paged_prefix", {}).get("kv") or {}
    overhead = report.get("trace_overhead") or {}
    progs = (m.get("programs") or {}).get("programs") or {}
    return make_record(
        arch=report.get("arch", "?"),
        tokens_per_s=m["tokens_per_s"],
        ttft_p50_ms=m["ttft_ms"]["p50"],
        prefix_hit_rate=kv.get("prefix_hit_rate"),
        trace_overhead_frac=overhead.get("overhead_frac"),
        recompiles_after_warmup=report.get("recompiles_after_warmup"),
        program_utilization={name: p["utilization"]
                             for name, p in sorted(progs.items())},
        sha=sha, extra=extra)


def append_record(path: Path | str, record: dict) -> None:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    with p.open("a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")


def read_ledger(path: Path | str) -> list[dict]:
    """All records, oldest first.  Blank lines are skipped; a malformed line
    raises — an append-only file that stopped parsing is corruption worth
    failing on, not skipping past."""
    p = Path(path)
    if not p.exists():
        return []
    out = []
    for i, line in enumerate(p.read_text().splitlines(), start=1):
        if not line.strip():
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError as e:
            raise ValueError(f"{p}:{i}: malformed ledger line: {e}") from e
    return out


def trend_check(records: list[dict], *, band: float = DEFAULT_BAND,
                window: int = DEFAULT_WINDOW,
                min_history: int = MIN_HISTORY,
                metrics=TREND_METRICS) -> dict:
    """Gate the newest record against the rolling median of its history.

    For each ``(key, direction)`` in ``metrics``, takes the last ``window``
    prior records carrying the key; with fewer than ``min_history`` the
    check passes vacuously (the band has to have a history to be relative
    to).  "higher"-is-better fails when the latest value falls below
    ``(1 - band) * median``; "lower"-is-better when it rises above
    ``(1 + band) * median``."""
    if not 0.0 < band:
        raise ValueError(f"band must be positive, got {band}")
    if not records:
        return {"ok": True, "band": band, "runs": 0, "checks": []}
    latest = records[-1]
    checks = []
    for key, direction in metrics:
        history = [r[key] for r in records[:-1] if key in r][-window:]
        cur = latest.get(key)
        c = {"metric": key, "direction": direction, "current": cur,
             "history": len(history)}
        if cur is None or len(history) < min_history:
            c.update(ok=True, median=None, bound=None)
        else:
            med = statistics.median(history)
            if direction == "higher":
                bound = (1.0 - band) * med
                ok = cur >= bound
            else:
                bound = (1.0 + band) * med
                ok = cur <= bound
            c.update(ok=ok, median=med, bound=bound)
        checks.append(c)
    return {"ok": all(c["ok"] for c in checks), "band": band,
            "runs": len(records), "checks": checks}


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="perf-ledger trend check (blocking CI step)")
    ap.add_argument("path", help="perf_ledger.jsonl")
    ap.add_argument("--band", type=float, default=DEFAULT_BAND,
                    help="allowed fraction off the rolling median")
    ap.add_argument("--window", type=int, default=DEFAULT_WINDOW)
    args = ap.parse_args(argv)
    records = read_ledger(args.path)
    check = trend_check(records, band=args.band, window=args.window)
    print(json.dumps(check, indent=1))
    return 0 if check["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
