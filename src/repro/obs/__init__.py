"""Serving observability: tracing, synchronized timing, metrics, drift.

The pieces (each its own module, importable without the rest):

* :mod:`repro.obs.trace`   — ring-buffered event tracer, Chrome trace export
* :mod:`repro.obs.timing`  — ``Timed`` device-synchronized sections,
  ``profile_trace`` (``jax.profiler``) hook
* :mod:`repro.obs.metrics` — counters + log2-histogram registry (the
  versioned ``obs`` section of ``EngineStats.summary()``)
* :mod:`repro.obs.drift`   — measured-vs-predicted placement residuals,
  shared with ``benchmarks/calibrate.py``

See docs/observability.md for the event vocabulary and schema.
"""
from .metrics import OBS_SCHEMA_VERSION, Counter, Histogram, MetricsRegistry
from .timing import Timed, profile_trace
from .trace import Tracer

__all__ = [
    "OBS_SCHEMA_VERSION", "Counter", "Histogram", "MetricsRegistry",
    "Timed", "profile_trace", "Tracer",
]
