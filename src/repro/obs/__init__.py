"""Serving observability: tracing, synchronized timing, metrics, drift.

The pieces (each its own module, importable without the rest):

* :mod:`repro.obs.trace`   — ring-buffered event tracer, Chrome trace export
* :mod:`repro.obs.timing`  — ``Timed`` device-synchronized sections,
  ``profile_trace`` (``jax.profiler``) hook
* :mod:`repro.obs.metrics` — counters, gauges + log2-histogram registry (the
  versioned ``obs`` section of ``EngineStats.summary()``; Prometheus text
  exposition via ``to_prometheus``)
* :mod:`repro.obs.drift`   — measured-vs-predicted placement residuals,
  shared with ``benchmarks/calibrate.py``
* :mod:`repro.obs.programs` — per-program cost registry: static FLOPs/bytes
  of the warmed inventory + live roofline utilization and cluster rollup
* :mod:`repro.obs.ledger`  — append-only perf ledger (``perf_ledger.jsonl``)
  with the rolling-median trend check

See docs/observability.md for the event vocabulary and schema.
"""
from .ledger import LEDGER_SCHEMA_VERSION, append_record, read_ledger, \
    trend_check
from .metrics import OBS_SCHEMA_VERSION, Counter, Gauge, Histogram, \
    MetricsRegistry
from .programs import PROGRAMS_SCHEMA_VERSION, ProgramRegistry
from .timing import Timed, profile_trace
from .trace import Tracer

__all__ = [
    "LEDGER_SCHEMA_VERSION", "OBS_SCHEMA_VERSION", "PROGRAMS_SCHEMA_VERSION",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "ProgramRegistry",
    "Timed", "Tracer", "append_record", "profile_trace", "read_ledger",
    "trend_check",
]
