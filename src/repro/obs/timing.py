"""Device-synchronized timing sections.

JAX dispatches asynchronously: ``t1 - t0`` around a jitted call measures how
long *enqueueing* took, not the computation — on an accelerator the gap is
orders of magnitude (the dispatch returns in microseconds while the program
runs for milliseconds).  Every duration the serving stack reports must
therefore block on the program's outputs before the closing stamp.  ``Timed``
packages that discipline:

    with Timed("decode") as tm:
        out, states = program(...)
        out = tm.sync(out)          # block_until_ready BEFORE the stamp
    stats.decode_time_s += tm.dur

jitlint rule JL008 (timing-discipline) statically rejects raw
``time.perf_counter()`` pairs around device work; routing through ``Timed``
(whose ``sync`` is the one sanctioned blocking point) is the fix it suggests.

``profile=True`` additionally wraps the section in a
``jax.profiler.TraceAnnotation`` so engine spans line up with XLA's own
timeline when serving runs under ``--profile-dir``
(:func:`profile_trace`).
"""
from __future__ import annotations

import time
from contextlib import contextmanager

import jax


class Timed:
    """Context manager timing one device-synchronized section.

    Attributes after the block: ``t0`` / ``t1`` (clock stamps), ``dur``
    (seconds), ``synced`` (whether :meth:`sync` ran — callers timing device
    work must call it on the program outputs, or the duration only covers
    dispatch).
    """

    __slots__ = ("name", "profile", "t0", "t1", "dur", "synced", "_clock",
                 "_ann")

    def __init__(self, name: str = "", *, profile: bool = False,
                 clock=time.perf_counter):
        self.name = name
        self.profile = profile
        self._clock = clock
        self.t0 = self.t1 = self.dur = 0.0
        self.synced = False
        self._ann = None

    def __enter__(self) -> "Timed":
        if self.profile:
            self._ann = jax.profiler.TraceAnnotation(self.name or "timed")
            self._ann.__enter__()
        self.t0 = self._clock()
        return self

    def sync(self, out):
        """Block until ``out`` (any pytree of arrays) is computed; returns it.
        Call on the program outputs before the block closes."""
        out = jax.block_until_ready(out)
        self.synced = True
        return out

    def __exit__(self, *exc) -> bool:
        if self._ann is not None:
            self._ann.__exit__(*exc)
            self._ann = None
        self.t1 = self._clock()
        self.dur = self.t1 - self.t0
        return False


@contextmanager
def profile_trace(profile_dir):
    """Run the body under ``jax.profiler`` trace collection when
    ``profile_dir`` is truthy (no-op otherwise): the XLA-level companion to
    the engine's own Chrome trace, viewable in TensorBoard/Perfetto."""
    if not profile_dir:
        yield
        return
    jax.profiler.start_trace(str(profile_dir))
    try:
        yield
    finally:
        jax.profiler.stop_trace()
