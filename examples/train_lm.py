"""End-to-end training driver example: train a language model for a few
hundred steps with checkpointing, fault injection, and auto-resume.

Default runs a ~7M-param smollm-family model (CPU-friendly).  Pass --full to
train the real smollm-135m config (the assignment's ~100M-class model) — same
code path, more compute.

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --full --steps 300   # ~135M
"""
import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config
from repro.ft.watchdog import FailureInjector, run_with_restarts
from repro.launch.train import train_once


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="train the real 135M-param smollm config")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (tests auto-resume)")
    args = ap.parse_args()

    if args.full:
        cfg = get_config("smollm-135m").replace(remat=True)
    else:
        # smollm topology at ~7M params: 6 layers, d_model 256
        cfg = get_config("smollm-135m").replace(
            num_layers=6, d_model=256, num_heads=8, num_kv_heads=4,
            head_dim=32, d_ff=768, vocab_size=8192, remat=False,
            scan_chunk=64, attn_block_kv=128)

    ckpt_dir = tempfile.mkdtemp(prefix="mensa_train_")
    print(f"arch: smollm-family, ~{cfg.param_count() / 1e6:.1f}M params; "
          f"checkpoints -> {ckpt_dir}")
    injector = FailureInjector(fail_at_step=args.fail_at)
    out = {}

    def once():
        out["result"] = train_once(
            cfg, steps=args.steps, global_batch=args.global_batch,
            seq_len=args.seq_len, ckpt_dir=ckpt_dir,
            ckpt_every=max(args.steps // 5, 10), injector=injector,
            log_every=max(args.steps // 20, 1))

    restarts = run_with_restarts(once, max_restarts=2, on_restart=lambda n, e:
                                 print(f"[example] restart {n}: {e!r}"))
    r = out["result"]
    first = min(r["losses"])
    print(f"\nloss {r['losses'][first]:.3f} -> {r['final_loss']:.3f} over "
          f"{args.steps} steps ({restarts} restarts)")
    assert r["final_loss"] < r["losses"][first], "loss did not improve"
    print("train_lm OK")


if __name__ == "__main__":
    main()
