"""Serving example: batched requests through the continuous-batching engine,
with the Mensa view of the workload (prefill = compute-centric Pascal phase,
decode = memory-centric Jacquard/Pavlov phase).

  PYTHONPATH=src python examples/serve_edge.py --arch qwen3-0.6b --requests 6
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.strategy import plan
from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    # the pod-scale serving plan for this arch (decode_32k shape)
    p = plan(get_config(args.arch), tokens=128, batch=128, train=False,
             shape_name="decode_32k")
    print(p.summary())

    cfg = reduced_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=args.slots, max_len=128)

    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, 4 + i % 5).tolist(),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    n_tokens = sum(len(r.generated) for r in done)
    for r in done[:3]:
        print(f"req {r.rid}: prompt {r.prompt} -> {r.generated}")
    print(f"\nserved {len(done)} requests / {n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens / dt:.1f} tok/s on CPU with {args.slots} slots)")
    assert all(r.done for r in done)
    print("serve_edge OK")


if __name__ == "__main__":
    main()
