"""Serving example: batched requests through the continuous-batching engine,
with the Mensa view of the workload (prefill = compute-centric Pascal phase,
decode = memory-centric Jacquard/Pavlov phase) — each phase lowers as its own
jitted program with its own execution profile, and prompts are padded to
power-of-two buckets so every prefill shape compiles exactly once.

  PYTHONPATH=src python examples/serve_edge.py --arch qwen3-0.6b --requests 6
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.executor import phase_profiles
from repro.launch.serve import build_engine
from repro.serve.engine import Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    # the pod-scale per-phase serving plans for this arch
    prefill_prof, decode_prof = phase_profiles(get_config(args.arch))
    print(prefill_prof.plan.summary())
    print(f"prefill overrides={prefill_prof.cfg_overrides} | "
          f"decode overrides={decode_prof.cfg_overrides}")

    cfg = reduced_config(args.arch)
    engine = build_engine(cfg, slots=args.slots, max_len=128,
                          profiles=(prefill_prof, decode_prof))

    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, 4 + i % 5).tolist(),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    done = engine.run(reqs)
    for r in done[:3]:
        print(f"req {r.rid}: prompt {r.prompt} -> {r.generated}")
    s = engine.stats.summary()
    print(f"\nserved {s['requests_completed']} requests / "
          f"{s['tokens_generated']} tokens "
          f"({s['tokens_per_s']:.1f} tok/s on CPU with {args.slots} slots, "
          f"ttft p50 {s['ttft_ms']['p50']:.0f}ms, "
          f"{s['prefill_compiles']} prefill compiles)")
    assert all(r.done for r in done)
    print("serve_edge OK")


if __name__ == "__main__":
    main()
