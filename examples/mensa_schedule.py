"""The full paper pipeline over all 24 Google edge models: characterize ->
cluster -> schedule -> evaluate vs Baseline / Base+HB / Eyeriss v2, printing
the §7 comparison table.

  PYTHONPATH=src python examples/mensa_schedule.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from collections import Counter

from repro.core import (MensaScheduler, characterize_zoo, evaluate_zoo,
                        rule_cluster, strict_fraction, summarize)
from repro.edge import edge_zoo


def main() -> None:
    zoo = edge_zoo()
    chars = characterize_zoo(zoo)
    clusters = Counter(rule_cluster(c).cluster for c in chars)
    print(f"24 models, {len(chars)} layers; cluster populations: "
          f"{dict(sorted(clusters.items()))}")
    print(f"layers inside published cluster boxes: "
          f"{strict_fraction(chars, 2.5):.1%} (paper: 97%)\n")

    sched = MensaScheduler()
    print(f"{'model':24s} {'family':10s} {'lat_x':>6s} {'E_x':>6s} "
          f"{'pascal':>7s} {'pavlov':>7s} {'jacq':>6s}")
    results = evaluate_zoo(zoo)
    for g, r in zip(zoo, results):
        s = sched.schedule(g)
        names = s.accelerator_names()
        print(f"{g.name:24s} {g.family:10s} "
              f"{r.baseline.latency_s / r.mensa.latency_s:6.2f} "
              f"{r.baseline.energy.total / r.mensa.energy.total:6.2f} "
              f"{names.count('pascal'):7d} {names.count('pavlov'):7d} "
              f"{names.count('jacquard'):6d}")

    s = summarize(results)
    print(f"\nMensa vs baseline: throughput {s.throughput_x_vs_baseline:.2f}x "
          f"(paper 3.1x), energy eff {s.energy_eff_x_vs_baseline:.2f}x "
          f"(paper 3.0x), energy -{s.energy_reduction_vs_baseline:.1%} "
          f"(paper -66%)")
    print("mensa_schedule OK")


if __name__ == "__main__":
    main()
