"""Quickstart: the Mensa pipeline end to end in under a minute on CPU.

1. Characterize + cluster the layers of a Google edge model (paper §3/§5.1).
2. Schedule it across Pascal/Pavlov/Jacquard with the two-phase scheduler
   (§4.2) and compare against the Edge TPU baseline (§7).
3. Run the SAME framework at pod scale: plan execution strategies for an
   assigned architecture and run a few training steps of its reduced config.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.core import (MensaScheduler, characterize_model,
                        evaluate_model, rule_cluster)
from repro.core.strategy import plan
from repro.configs import get_config, reduced_config
from repro.edge import get_model
from repro.models import build_model
from repro.train import optim
from repro.train.trainer import make_train_step
from repro.data.pipeline import DataConfig, SyntheticTokens


def level_a() -> None:
    print("=" * 72)
    print("LEVEL A — the paper: heterogeneous edge acceleration")
    print("=" * 72)
    g = get_model("TR1_rnnt_mobile")          # mobile RNN-T transducer
    chars = characterize_model(g)
    print(f"{g.name}: {len(g.layers)} layers, "
          f"{g.total_params / 1e6:.1f}M params")
    for c in chars[:4]:
        cl = rule_cluster(c).cluster
        print(f"  {c.name:12s} kind={c.kind.value:10s} cluster={cl} "
              f"footprint={c.param_bytes / 2**20:7.1f}MB "
              f"FLOP/B={c.param_flop_per_byte:8.1f}")
    sched = MensaScheduler()
    s = sched.schedule(g)
    print(f"schedule: {dict((a, s.accelerator_names().count(a)) for a in set(s.accelerator_names()))}"
          f"  (phase-2 remapped {s.n_remapped} layers)")
    r = evaluate_model(g)
    print(f"baseline EdgeTPU : {r.baseline.latency_s * 1e3:8.1f} ms   "
          f"{r.baseline.energy.total * 1e3:7.1f} mJ")
    print(f"Mensa            : {r.mensa.latency_s * 1e3:8.1f} ms   "
          f"{r.mensa.energy.total * 1e3:7.1f} mJ   "
          f"({r.baseline.latency_s / r.mensa.latency_s:.1f}x faster, "
          f"{r.baseline.energy.total / r.mensa.energy.total:.1f}x less energy)")


def level_b() -> None:
    print()
    print("=" * 72)
    print("LEVEL B — the same idea at pod scale (execution strategies)")
    print("=" * 72)
    p = plan(get_config("recurrentgemma-2b"), tokens=256 * 4096, batch=256,
             train=True, shape_name="train_4k")
    print(p.summary())

    print("\ntraining the reduced config for 10 steps on CPU:")
    cfg = reduced_config("recurrentgemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = optim.adamw_init(params)
    step_fn = jax.jit(make_train_step(model))
    data = SyntheticTokens(DataConfig(cfg.vocab_size, 64, 8))
    for step in range(10):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 3 == 0 or step == 9:
            print(f"  step {step}: loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    level_a()
    level_b()
    print("\nquickstart OK")
