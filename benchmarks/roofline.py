import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# Roofline analysis (assignment §ROOFLINE): per (arch x shape) on the
# single-pod mesh, derive the three terms from the compiled dry-run artifact:
#
#   compute term    = HLO_FLOPs / (chips x 197e12)          [bf16 peak]
#   memory term     = HLO_bytes / (chips x 819e9)           [HBM]
#   collective term = collective_wire_bytes / (chips x 50e9) [ICI]
#
# XLA's HloCostAnalysis counts while-loop bodies ONCE, so the roofline pass
# recompiles each cell with every scan unrolled (cfg.unroll_scans) and
# grad-accum=1 — loop-free HLO whose cost analysis is exact.  The standard
# (scan-based) dry-run remains the source of the memory-fit numbers.
#
#   PYTHONPATH=src python -m benchmarks.roofline --cell smollm-135m:train_4k
#   PYTHONPATH=src python -m benchmarks.roofline --all

import argparse
import json
import time
from pathlib import Path

import jax

from repro.configs import ARCHS, SHAPES, applicable, get_config
from repro.core.accelerators import TPU_V5E
from repro.utils.hlo import normalize_cost_analysis, parse_collectives

RESULTS = Path(__file__).resolve().parent.parent / "results" / "roofline"

PEAK_FLOPS = TPU_V5E.peak_flops   # bf16 / chip
HBM_BW = TPU_V5E.hbm_bw           # bytes/s / chip
ICI_BW = TPU_V5E.ici_bw           # bytes/s / link

# per-cell overrides for the unrolled compile (keep HLO size manageable)
UNROLL_BLOCK_KV = {"prefill_32k": 2048, "train_4k": 1024}
UNROLL_CHUNK = {"train_4k": 1024, "prefill_32k": 2048}


def run_cell(arch: str, shape_name: str, out_dir: Path = RESULTS,
             variant: str = "baseline", cfg_override=None,
             accum: int = 1, strategy: str = "tp") -> dict:
    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "variant": variant}
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    out_path = out_dir / f"{arch}__{shape_name}{suffix}.json"
    if not ok:
        rec.update(status="skip", reason=why)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    cfg = cfg.replace(
        unroll_scans=True,
        attn_block_kv=UNROLL_BLOCK_KV.get(shape_name, cfg.attn_block_kv),
        scan_chunk=UNROLL_CHUNK.get(shape_name, cfg.scan_chunk))
    if cfg_override:
        cfg = cfg_override(cfg)
    mesh = make_production_mesh(multi_pod=False)
    saved_accum = dict(dr.ACCUM)
    dr.ACCUM.clear()
    dr.ACCUM.update({"default": accum})
    t0 = time.time()
    try:
        fn, args, _, meta = dr.build_lowerable(cfg, shape, mesh, strategy)
        with mesh:
            lowered = jax.jit(fn).lower(*args)
            compiled = lowered.compile()
            cost = normalize_cost_analysis(compiled.cost_analysis())
            hlo = compiled.as_text()
        coll = parse_collectives(hlo, default_group=256)
        flops_dev = float(cost.get("flops", 0.0))
        bytes_dev = float(cost.get("bytes accessed", 0.0))
        wire_dev = coll.total_wire_bytes

        t_compute = flops_dev / PEAK_FLOPS
        t_memory = bytes_dev / HBM_BW
        t_collective = wire_dev / ICI_BW
        terms = {"compute_s": t_compute, "memory_s": t_memory,
                 "collective_s": t_collective}
        dominant = max(terms, key=terms.get)

        # MODEL_FLOPS: 6*N*D for training, 2*N_active*D for inference fwd
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        n_active = cfg.param_count(active_only=True)
        model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
        model_flops_dev = model_flops / 256

        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            flops_per_dev=flops_dev, bytes_per_dev=bytes_dev,
            coll_wire_per_dev=wire_dev,
            collectives=coll.to_dict(),
            terms=terms, dominant=dominant,
            bound_s=max(terms.values()),
            model_flops_per_dev=model_flops_dev,
            useful_ratio=model_flops_dev / max(flops_dev, 1.0),
            roofline_fraction=(model_flops_dev / PEAK_FLOPS)
            / max(max(terms.values()), 1e-30),
        )
        print(f"[roofline] {arch} x {shape_name} ({variant}): "
              f"C={t_compute*1e3:.2f}ms M={t_memory*1e3:.2f}ms "
              f"X={t_collective*1e3:.2f}ms dom={dominant[:-2]} "
              f"useful={rec['useful_ratio']:.2f} "
              f"roofline_frac={rec['roofline_fraction']:.3f}")
    except Exception as e:  # noqa: BLE001
        import traceback
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-3000:])
        print(f"[roofline] {arch} x {shape_name}: ERROR {e}")
    finally:
        dr.ACCUM.clear()
        dr.ACCUM.update(saved_accum)
    out_path.write_text(json.dumps(rec, indent=1, default=float))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                p = RESULTS / f"{arch}__{shape}.json"
                if args.skip_done and p.exists() and \
                        json.loads(p.read_text()).get("status") in ("ok", "skip"):
                    continue
                run_cell(arch, shape)
    else:
        arch, shape = args.cell.split(":")
        run_cell(arch, shape)


if __name__ == "__main__":
    main()
