"""Placement-oracle calibration: fit and gate predicted vs measured phase times.

Closes the Mensa loop. The ExecutionOracle predicts per-phase latency from
``core/costmodel.layer_cost`` against the paper's edge-accelerator configs;
the serving engine measures the same phases on whatever backend CI runs on.
The two live on different hardware, so a single fitted scale per phase
(geometric mean of measured/predicted across the served archs) absorbs the
platform gap — what the gate checks is the *relative* story: after the fit,
no arch's measured phase time may sit more than ``--bound``x away from its
prediction.  A cost model that mis-ranks the archs (predicts the recurrent
stack cheaper than it measures, say) fails here even though every absolute
number is off by the same platform constant.

Also records, informationally, the ``results/roofline/`` HLO analyses next
to the oracle's phase story (decode is memory-bound: the roofline files'
dominant term should agree).

  PYTHONPATH=src python benchmarks/calibrate.py \\
      --json results/placement_calibration.json
  PYTHONPATH=src python benchmarks/calibrate.py \\
      --check results/placement_calibration.json   # CI: re-measure + gate

Writes ``results/placement_calibration.json``; CI re-runs the measurement,
gates the post-fit residual, and uploads the fresh JSON as an artifact.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

ARCHS = ("qwen3-0.6b", "recurrentgemma-2b", "falcon-mamba-7b")

# post-fit residual bound, as a multiplicative factor.  Generous on purpose:
# the measured side is a tiny reduced model on a shared CI host where per-call
# dispatch overhead dominates; the gate exists to catch the cost model
# mis-ranking phases/archs by an order of magnitude, not to certify absolute
# latency.
DEFAULT_BOUND = 25.0


def measure_arch(arch: str, *, slots: int = 2, max_len: int = 64,
                 max_bucket: int = 32, max_new: int = 8,
                 requests: int = 6) -> dict:
    """Serve a small trace through an oracle-resolved engine and return the
    plan's predicted per-phase times next to the measured ones."""
    import jax
    from repro.configs import reduced_config
    from repro.launch.serve import build_engine
    from repro.models import build_model
    from repro.serve.engine import Request

    cfg = reduced_config(arch)
    cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    engine = build_engine(cfg, params, slots=slots, max_len=max_len,
                          max_bucket=max_bucket, policy="auto")
    plan = engine.policy
    engine.warmup()
    engine.reset_stats()
    rng = np.random.RandomState(3)
    engine.run([Request(rid=i,
                        prompt=rng.randint(1, cfg.vocab_size,
                                           5 + 9 * i % 40).tolist(),
                        max_new_tokens=max_new)
                for i in range(requests)])
    # the engine's own drift monitor (obs.drift over the plan summary +
    # Timed-synchronized phase times) IS the measurement: calibration fits
    # exactly the numbers the running engine reports in its stats and traces
    drift = engine.stats.summary()["placement"]["drift"]
    assert set(drift["phases"]) == {"prefill_token_s", "decode_step_s"}, \
        (arch, drift)
    return {
        "arch": arch,
        "clusters": list(plan.layer_clusters),
        "prefill_chunk": plan.prefill_chunk,
        "predicted": {ph: rec["predicted"]
                      for ph, rec in drift["phases"].items()},
        "measured": {ph: rec["measured"]
                     for ph, rec in drift["phases"].items()},
        "residual_factors": {ph: rec["residual_factor"]
                             for ph, rec in drift["phases"].items()},
    }


def fit(per_arch: list[dict]) -> dict:
    """Per-phase log-space scale fit + residuals, through the same
    ``repro.obs.drift`` arithmetic the engine's live drift monitor uses.

    scale = geomean(measured / predicted); residual_factor per arch =
    exp(|log measured - log (scale * predicted)|) >= 1."""
    from repro.obs.drift import PHASES, geomean, residual_factor
    out = {"phases": {}, "max_residual_factor": 1.0}
    for phase in PHASES:
        ratios = []
        for rec in per_arch:
            pred, meas = rec["predicted"][phase], rec["measured"][phase]
            assert pred > 0 and meas > 0, (rec["arch"], phase, pred, meas)
            ratios.append(meas / pred)
        scale = geomean(ratios)
        residuals = {}
        for rec, r in zip(per_arch, ratios):
            factor = residual_factor(r, scale)
            residuals[rec["arch"]] = factor
            out["max_residual_factor"] = max(out["max_residual_factor"],
                                             factor)
        out["phases"][phase] = {"scale": scale, "residual_factors": residuals}
    return out


def roofline_consistency(roofline_dir: Path) -> list[dict]:
    """Informational: the HLO roofline analyses should tell the same phase
    story the cost model does (decode shapes are memory-bound)."""
    out = []
    for p in sorted(roofline_dir.glob("*.json")):
        try:
            rec = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError):
            continue
        if rec.get("status") != "ok":
            continue
        is_decode = "decode" in rec.get("shape", "")
        out.append({
            "file": p.name,
            "shape": rec.get("shape"),
            "dominant": rec.get("dominant"),
            "terms": rec.get("terms"),
            # the cost model predicts decode memory-bound; agreement here is
            # recorded, not gated (the roofline corpus grows independently)
            "agrees_with_cost_model":
                rec.get("dominant") == "memory_s" if is_decode else None,
        })
    return out


def calibrate(bound: float) -> dict:
    per_arch = [measure_arch(a) for a in ARCHS]
    fitted = fit(per_arch)
    report = {
        "archs": per_arch,
        "fit": fitted,
        "bound": bound,
        "ok": fitted["max_residual_factor"] <= bound,
        "roofline": roofline_consistency(
            Path(__file__).resolve().parent.parent / "results" / "roofline"),
        "wall_s": None,         # stamped by main()
    }
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/placement_calibration.json",
                    help="write the calibration report here")
    ap.add_argument("--bound", type=float, default=DEFAULT_BOUND,
                    help="max post-fit residual factor (predicted vs "
                         "measured, after the per-phase platform scale)")
    ap.add_argument("--check", default="",
                    help="also compare against a committed calibration "
                         "JSON: per-phase scales must agree within the "
                         "bound (platform drift is fine, rank flips are "
                         "not)")
    args = ap.parse_args()

    t0 = time.perf_counter()
    report = calibrate(args.bound)
    report["wall_s"] = round(time.perf_counter() - t0, 2)

    out = json.dumps(report, indent=1)
    print(out)
    p = Path(args.json)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(out + "\n")

    assert report["ok"], (
        f"placement calibration failed: max post-fit residual factor "
        f"{report['fit']['max_residual_factor']:.2f} exceeds bound "
        f"{args.bound} — the cost model mis-ranks a served phase; see "
        f"{args.json}")

    if args.check:
        committed = json.loads(Path(args.check).read_text())
        for phase, cur in report["fit"]["phases"].items():
            ref = committed["fit"]["phases"][phase]["scale"]
            drift = math.exp(abs(math.log(cur["scale"] / ref)))
            print(f"[calibrate] {phase}: scale {cur['scale']:.3g} vs "
                  f"committed {ref:.3g} (drift factor {drift:.2f})")


if __name__ == "__main__":
    main()
