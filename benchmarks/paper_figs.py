"""One benchmark per paper figure/table.  Each function prints CSV rows
(`name,us_per_call,derived`) and returns a dict of derived metrics.

Fig.1  — throughput + energy rooflines of the Edge TPU across all 24 models.
Fig.2  — energy breakdown during inference (per family, per component).
Fig.3  — layer parameter footprint vs FLOP/B (per family scatter stats).
Fig.4/5— MAC count and footprint variation across layers of four CNNs.
Fig.6  — layer clustering (footprint vs FLOP/B vs MACs, cluster populations).
Fig.10 — inference energy for Baseline / Base+HB / EyerissV2 / Mensa + Mensa
         per-accelerator energy breakdown.
Fig.11 — utilization and throughput, normalized to Baseline.
Fig.12 — inference latency, normalized to Baseline.
"""
from __future__ import annotations

import time
from collections import Counter, defaultdict

from repro.core import (EDGE_TPU, DEFAULT_ENERGY, characterize_model,
                        characterize_zoo, cluster_all, evaluate_zoo,
                        monolithic_cost, strict_fraction,
                        summarize)
from repro.edge import edge_zoo

MB = 1024 * 1024


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def fig1_rooflines(emit=print) -> dict:
    """Edge TPU throughput roofline (2 TFLOP/s knee at AI=peak/bw) and energy
    roofline, with each model's operating point."""
    zoo = edge_zoo()
    ep = DEFAULT_ENERGY
    peak = EDGE_TPU.peak_flops
    bw = EDGE_TPU.dram_bw
    knee = peak / bw                      # FLOP/B where compute == memory
    rows = []
    for g in zoo:
        sc = monolithic_cost(g, EDGE_TPU)
        traffic = sum(c.prof.offchip_bytes for c in sc.per_layer)
        ai = sc.flops / max(traffic, 1.0)
        roof = min(peak, ai * bw)
        attained = sc.throughput_flops
        # energy roofline (Choi et al. [8]): eff(AI) = 1/(e_flop + e_dram/AI)
        eff_max = 1.0 / (ep.e_flop + ep.e_dram_lpddr4 / ai)
        eff = sc.efficiency_flops_per_j
        rows.append((g.name, ai, attained / roof, attained / peak,
                     eff / eff_max))
    util = sum(r[3] for r in rows) / len(rows)
    e_frac = sum(r[4] for r in rows) / len(rows)
    (out, us) = _timed(lambda: rows)
    emit(f"fig1_rooflines,{us:.1f},knee_flopb={knee:.1f};mean_util={util:.3f};"
         f"mean_energy_roofline_frac={e_frac:.3f}")
    for name, ai, roof_frac, peak_frac, ef in rows:
        emit(f"fig1.{name},0.0,AI={ai:.1f};roof_frac={roof_frac:.3f};"
             f"peak_frac={peak_frac:.4f};energy_frac={ef:.3f}")
    return {"mean_util": util, "mean_energy_frac": e_frac, "rows": rows}


def fig2_energy_breakdown(emit=print) -> dict:
    zoo = edge_zoo()
    fam_tot = defaultdict(lambda: defaultdict(float))
    for g in zoo:
        sc = monolithic_cost(g, EDGE_TPU)
        e = sc.energy
        t = fam_tot[g.family]
        t["pe"] += e.pe
        t["buf_param"] += e.buf_param_dynamic
        t["buf_act"] += e.buf_act_dynamic
        t["noc"] += e.noc
        t["dram"] += e.dram
        t["static"] += e.static
    out = {}
    for fam, t in fam_tot.items():
        tot = sum(t.values())
        shares = {k: v / tot for k, v in t.items()}
        out[fam] = shares
        emit(f"fig2.{fam},0.0," + ";".join(f"{k}={v:.3f}"
                                           for k, v in shares.items()))
    # headline claims
    all_t = defaultdict(float)
    for t in fam_tot.values():
        for k, v in t.items():
            all_t[k] += v
    tot = sum(all_t.values())
    offchip = all_t["dram"] / tot
    onchip = (all_t["buf_param"] + all_t["noc"]) / tot
    emit(f"fig2.overall,0.0,offchip_param_share={offchip:.3f}(paper~0.503);"
         f"onchip_param_share={onchip:.3f}(paper~0.309)")
    out["overall"] = {"offchip": offchip, "onchip": onchip}
    return out


def fig3_footprint_vs_flopb(emit=print) -> dict:
    chars = characterize_zoo(edge_zoo())
    by_fam = defaultdict(list)
    for c in chars:
        if c.param_bytes > 256:
            by_fam[c.model.split("_")[0][:3]].append(c)
    lstm_tr = [c for c in chars
               if c.recurrent and c.param_bytes > 256]
    avg_foot = sum(c.param_bytes for c in lstm_tr) / len(lstm_tr) / MB
    emit(f"fig3,0.0,lstm_tr_avg_layer_footprint_mb={avg_foot:.1f}(paper 33.4);"
         f"n_layers={len(chars)}")
    return {"avg_footprint_mb": avg_foot}


def fig4_5_layer_variation(emit=print) -> dict:
    zoo = [g for g in edge_zoo() if g.family == "cnn"][:4]
    out = {}
    for g in zoo:
        chars = [c for c in characterize_model(g) if c.macs > 0
                 and c.param_bytes > 1]
        macs = [c.macs for c in chars]
        foot = [c.param_bytes for c in chars]
        mac_x = max(macs) / max(min(macs), 1)
        foot_x = max(foot) / max(min(foot), 1)
        out[g.name] = (mac_x, foot_x)
        emit(f"fig4_5.{g.name},0.0,mac_variation_x={mac_x:.0f}(paper~200);"
             f"footprint_variation_x={foot_x:.0f}(paper~20)")
    return out


def fig6_clusters(emit=print) -> dict:
    chars = characterize_zoo(edge_zoo())
    assignments = cluster_all(chars)
    pops = Counter(a.cluster for a in assignments)
    s1 = strict_fraction(chars, pad=1.0)
    s25 = strict_fraction(chars, pad=2.5)
    emit(f"fig6,0.0,populations={dict(sorted(pops.items()))};"
         f"in_box_frac_pad1={s1:.3f};in_box_frac_pad2.5={s25:.3f}(paper 0.97)")
    return {"populations": dict(pops), "strict": s1, "padded": s25}


def fig10_11_12_mensa_vs_baselines(emit=print) -> dict:
    zoo = edge_zoo()
    results = evaluate_zoo(zoo)
    s = summarize(results)
    paper = dict(energy_reduction_vs_baseline=0.660, energy_eff_x_vs_baseline=3.0,
                 energy_eff_x_vs_eyeriss=2.4, throughput_x_vs_baseline=3.1,
                 throughput_x_vs_base_hb=1.3, throughput_x_vs_eyeriss=4.3,
                 latency_x_vs_baseline=1.96, latency_x_vs_base_hb=1.17,
                 base_hb_energy_reduction=0.075, base_hb_throughput_x=2.5,
                 baseline_mean_utilization=0.273,
                 lstm_transducer_throughput_x=5.7,
                 lstm_transducer_baseline_util=0.01)
    for k, v in s.__dict__.items():
        emit(f"fig10_11_12.{k},0.0,ours={v:.3f};paper={paper.get(k, float('nan')):.3f}")
    # per-model energy + latency normalized to baseline (Fig 10/12 bars)
    for r in results:
        emit(f"fig10.{r.model},0.0,"
             f"base_hb={r.base_hb.energy.total / r.baseline.energy.total:.3f};"
             f"eyeriss={r.eyeriss.energy.total / r.baseline.energy.total:.3f};"
             f"mensa={r.mensa.energy.total / r.baseline.energy.total:.3f}")
        emit(f"fig12.{r.model},0.0,"
             f"latency_mensa_x={r.baseline.latency_s / r.mensa.latency_s:.2f}")
    # Mensa per-accelerator energy breakdown (Fig 10 right)
    accel_e = defaultdict(float)
    for r in results:
        for lc in r.mensa.per_layer:
            accel_e[lc.accelerator] += lc.energy.total
    tot = sum(accel_e.values())
    emit("fig10.accel_breakdown,0.0," + ";".join(
        f"{k}={v / tot:.3f}" for k, v in sorted(accel_e.items())))
    return {"summary": s.__dict__, "accel_breakdown": dict(accel_e)}


ALL_FIGS = [fig1_rooflines, fig2_energy_breakdown, fig3_footprint_vs_flopb,
            fig4_5_layer_variation, fig6_clusters, fig10_11_12_mensa_vs_baselines]


def run_all(emit=print) -> dict:
    out = {}
    for fn in ALL_FIGS:
        t0 = time.perf_counter()
        out[fn.__name__] = fn(emit)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"{fn.__name__},{us:.1f},done")
    return out


if __name__ == "__main__":
    run_all()
