"""Generate the data-driven sections of EXPERIMENTS.md from results/."""
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _results_dir(name: str) -> Path:
    """results/<name>, created on demand — a fresh checkout has no results/
    tree, and both the globbing readers here and anything redirected into the
    directory must not depend on a previous run having made it."""
    d = ROOT / "results" / name
    d.mkdir(parents=True, exist_ok=True)
    return d


def dryrun_table() -> str:
    d = _results_dir("dryrun")
    rows = []
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        arch, shape, mesh = r["arch"], r["shape"], r["mesh"]
        if r["status"] == "skip":
            rows.append((arch, shape, mesh, "SKIP", "-", "-", "-", "-", "-", "-"))
        elif r["status"] == "ok":
            coll = r["collectives"]["total_wire_bytes"]
            mem = r.get("memory", {})
            args = mem.get("argument_size_in_bytes", 0)
            peak = mem.get("peak_memory_in_bytes", 0)
            fits = "yes" if (args + peak) < 16 * 2**30 else "NO"
            rows.append((arch, shape, mesh, "OK",
                         f"{r['flops']:.3g}", f"{coll:.3g}",
                         f"{args / 2**30:.2f}", f"{peak / 2**30:.2f}", fits,
                         f"{r['compile_s']:.0f}s"))
        else:
            rows.append((arch, shape, mesh, "ERROR", "-", "-", "-", "-", "-", "-"))
    out = ["| arch | shape | mesh | status | HLO FLOPs/dev | coll wire B/dev | args GiB/dev | peak GiB/dev | fits 16G | compile |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def roofline_table() -> str:
    d = _results_dir("roofline")
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | dominant | MODEL/HLO flops | roofline frac | lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    levers = {
        ("memory", True): "drop fp32 intermediates / rely on TPU fusion; reduce remat",
        ("compute", True): "remove dispatch/replication waste (see §Perf)",
        ("collective", True): "cheaper layouts (block-diag gates, fewer psums)",
    }
    for f in sorted(d.glob("*.json")):
        if "__v" in f.stem:
            continue            # variants appear in §Perf
        r = json.loads(f.read_text())
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | SKIP | - | - | {r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            continue
        t = r["terms"]
        dom = r["dominant"].replace("_s", "")
        lever = levers.get((dom, True), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.1f} | "
            f"{t['memory_s']*1e3:.1f} | {t['collective_s']*1e3:.1f} | {dom} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} | {lever} |")
    return "\n".join(out)


def perf_variants() -> str:
    d = _results_dir("roofline")
    out = ["| cell | variant | compute (ms) | memory (ms) | collective (ms) | roofline frac |",
           "|---|---|---|---|---|---|"]
    for f in sorted(d.glob("*__v*.json")):
        r = json.loads(f.read_text())
        if r["status"] != "ok":
            continue
        t = r["terms"]
        out.append(
            f"| {r['arch']} x {r['shape']} | {r['variant']} | "
            f"{t['compute_s']*1e3:.1f} | {t['memory_s']*1e3:.1f} | "
            f"{t['collective_s']*1e3:.1f} | {r['roofline_fraction']:.4f} |")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("dryrun", "all"):
        print("### Dry-run table\n")
        print(dryrun_table())
    if which in ("roofline", "all"):
        print("\n### Roofline table\n")
        print(roofline_table())
    if which in ("perf", "all"):
        print("\n### Perf variants\n")
        print(perf_variants())
