"""Generate the data-driven sections of EXPERIMENTS.md from results/."""
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _results_dir(name: str) -> Path:
    """results/<name>, created on demand — a fresh checkout has no results/
    tree, and both the globbing readers here and anything redirected into the
    directory must not depend on a previous run having made it."""
    d = ROOT / "results" / name
    d.mkdir(parents=True, exist_ok=True)
    return d


def dryrun_table() -> str:
    d = _results_dir("dryrun")
    rows = []
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        arch, shape, mesh = r["arch"], r["shape"], r["mesh"]
        if r["status"] == "skip":
            rows.append((arch, shape, mesh, "SKIP", "-", "-", "-", "-", "-", "-"))
        elif r["status"] == "ok":
            coll = r["collectives"]["total_wire_bytes"]
            mem = r.get("memory", {})
            args = mem.get("argument_size_in_bytes", 0)
            peak = mem.get("peak_memory_in_bytes", 0)
            fits = "yes" if (args + peak) < 16 * 2**30 else "NO"
            rows.append((arch, shape, mesh, "OK",
                         f"{r['flops']:.3g}", f"{coll:.3g}",
                         f"{args / 2**30:.2f}", f"{peak / 2**30:.2f}", fits,
                         f"{r['compile_s']:.0f}s"))
        else:
            rows.append((arch, shape, mesh, "ERROR", "-", "-", "-", "-", "-", "-"))
    out = ["| arch | shape | mesh | status | HLO FLOPs/dev | coll wire B/dev | args GiB/dev | peak GiB/dev | fits 16G | compile |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def roofline_table() -> str:
    d = _results_dir("roofline")
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | dominant | MODEL/HLO flops | roofline frac | lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    levers = {
        ("memory", True): "drop fp32 intermediates / rely on TPU fusion; reduce remat",
        ("compute", True): "remove dispatch/replication waste (see §Perf)",
        ("collective", True): "cheaper layouts (block-diag gates, fewer psums)",
    }
    for f in sorted(d.glob("*.json")):
        if "__v" in f.stem:
            continue            # variants appear in §Perf
        r = json.loads(f.read_text())
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | SKIP | - | - | {r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            continue
        t = r["terms"]
        dom = r["dominant"].replace("_s", "")
        lever = levers.get((dom, True), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.1f} | "
            f"{t['memory_s']*1e3:.1f} | {t['collective_s']*1e3:.1f} | {dom} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} | {lever} |")
    return "\n".join(out)


def perf_variants() -> str:
    d = _results_dir("roofline")
    out = ["| cell | variant | compute (ms) | memory (ms) | collective (ms) | roofline frac |",
           "|---|---|---|---|---|---|"]
    for f in sorted(d.glob("*__v*.json")):
        r = json.loads(f.read_text())
        if r["status"] != "ok":
            continue
        t = r["terms"]
        out.append(
            f"| {r['arch']} x {r['shape']} | {r['variant']} | "
            f"{t['compute_s']*1e3:.1f} | {t['memory_s']*1e3:.1f} | "
            f"{t['collective_s']*1e3:.1f} | {r['roofline_fraction']:.4f} |")
    return "\n".join(out)


def trace_table(path: Path) -> str:
    """Per-request latency breakdown rendered from a serve trace
    (``serve_bench.py --trace`` / ``repro.launch.serve --trace``): for each
    request span, where its wall time went — queueing, prefill (and how many
    chunks), decode-resident time — plus stall hits.  The same numbers
    Perfetto shows on the slot tracks, in review-pasteable form."""
    doc = json.loads(Path(path).read_text())
    events = doc["traceEvents"]
    tracks = {e["tid"]: e["args"]["name"] for e in events
              if e["ph"] == "M" and e["name"] == "thread_name"}
    reqs: dict = {}

    def rec(args):
        return reqs.setdefault(args["rid"], {
            "slot": "-", "prompt": "-", "prefix_hit": 0, "queue_ms": 0.0,
            "prefill_ms": 0.0, "chunks": 0, "span_ms": "-", "tokens": "-",
            "stalls": 0, "_b": None, "_e": None})

    for e in events:
        args = e.get("args") or {}
        if "rid" not in args:
            continue
        r = rec(args)
        if e["ph"] == "B":
            r.update(slot=tracks.get(e["tid"], e["tid"]),
                     prompt=args["prompt_tokens"],
                     prefix_hit=args.get("prefix_hit_tokens", 0),
                     queue_ms=1e3 * args.get("queue_wait_s", 0.0), _b=e["ts"])
        elif e["ph"] == "E":
            r.update(tokens=args.get("tokens", "-"), _e=e["ts"])
        elif e["ph"] == "X" and e["name"] in ("prefill", "prefill_chunk"):
            r["prefill_ms"] += e["dur"] / 1e3
            if e["name"] == "prefill_chunk":
                r["chunks"] += 1
        elif e["ph"] == "i" and e["name"] == "stall":
            r["stalls"] += 1
    out = ["| rid | slot | prompt | prefix hit | queue ms | prefill ms "
           "| chunks | span ms | tokens | stalls |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for rid in sorted(reqs):
        r = reqs[rid]
        span = f"{(r['_e'] - r['_b']) / 1e3:.1f}" \
            if r["_b"] is not None and r["_e"] is not None else "-"
        out.append(
            f"| {rid} | {r['slot']} | {r['prompt']} | {r['prefix_hit']} | "
            f"{r['queue_ms']:.1f} | {r['prefill_ms']:.1f} | {r['chunks']} | "
            f"{span} | {r['tokens']} | {r['stalls']} |")
    dropped = doc.get("otherData", {}).get("dropped_events", 0)
    if dropped:
        out.append(f"\n({dropped} events dropped by the ring buffer — "
                   f"raise Tracer capacity for full spans)")
    return "\n".join(out)


def ledger_table(path: Path) -> str:
    """The perf ledger's trajectory (``serve_bench --ledger``), one row per
    run oldest-first, with the rolling-median trend verdict for the newest
    record — the history the single committed baseline point cannot show."""
    from repro.obs.ledger import read_ledger, trend_check
    records = read_ledger(path)
    if not records:
        return f"(no ledger at {path})"
    out = ["| run | git sha | arch | tokens/s | TTFT p50 ms | prefix hit "
           "| trace ovh | recompiles |",
           "|---|---|---|---|---|---|---|---|"]

    def fmt(v, spec=".3g"):
        return format(v, spec) if isinstance(v, (int, float)) else "-"

    for i, r in enumerate(records, start=1):
        out.append(
            f"| {i} | {str(r.get('git_sha', '-'))[:9]} | {r.get('arch', '-')}"
            f" | {fmt(r.get('tokens_per_s'), '.1f')}"
            f" | {fmt(r.get('ttft_p50_ms'), '.1f')}"
            f" | {fmt(r.get('prefix_hit_rate'), '.2f')}"
            f" | {fmt(r.get('trace_overhead_frac'), '.3f')}"
            f" | {fmt(r.get('recompiles_after_warmup'), 'd')} |")
    trend = trend_check(records)
    verdict = "ok" if trend["ok"] else "REGRESSED"
    checks = ", ".join(
        f"{c['metric']} {fmt(c['current'], '.1f')} vs median "
        f"{fmt(c['median'], '.1f')}" for c in trend["checks"])
    out.append(f"\ntrend ({trend['runs']} runs, band "
               f"{trend['band']:.0%}): {verdict} — {checks}")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which == "trace":
        path = sys.argv[2] if len(sys.argv) > 2 \
            else ROOT / "results" / "serve_trace.json"
        print("### Serve trace: per-request breakdown\n")
        print(trace_table(Path(path)))
        sys.exit(0)
    if which == "ledger":
        sys.path.insert(0, str(ROOT / "src"))
        path = sys.argv[2] if len(sys.argv) > 2 \
            else ROOT / "results" / "perf_ledger.jsonl"
        print("### Perf ledger: run trajectory\n")
        print(ledger_table(Path(path)))
        sys.exit(0)
    if which in ("dryrun", "all"):
        print("### Dry-run table\n")
        print(dryrun_table())
    if which in ("roofline", "all"):
        print("\n### Roofline table\n")
        print(roofline_table())
    if which in ("perf", "all"):
        print("\n### Perf variants\n")
        print(perf_variants())
