"""Serving-engine benchmark: drive the bucketed continuous-batching engine
with a synthetic mixed-length request trace — including prompts LONGER than
the largest prefill bucket, which take the chunked path — and report engine
metrics as JSON.

Gates (all assertions, the acceptance criteria for the serving path):
  * zero prefill/decode recompiles after ``engine.warmup()`` — the program
    inventory (every (batch-bucket, bucket) prefill shape, the chunk
    continuation, the decode step) is closed;
  * batched admission: fewer compiled prefill calls than requests prefilled;
  * chunked prefill interleaves with decode (ticks < chunks + decode steps)
    and decode-step latency stays within a generous factor of a decode-only
    baseline while long prompts prefill;
  * chunked output is identical (token-for-token) to the unchunked reference
    across the attention, RG-LRU, and Mamba state families;
  * placement policy: an engine resolved through the ExecutionOracle
    (``--policy auto``, the default) generates tokens bitwise-identical to
    the fixed-knob engine with zero recompiles after warmup, and the report
    carries the plan's predicted per-phase latency next to the measured
    times (the calibration loop's raw material);
  * paged KV + prefix cache (the shared-prefix workload): nonzero
    prefix-cache hit rate and fewer prefill tokens computed than the same
    trace with the cache off, zero recompiles after warmup with paging on,
    and peak blocks-in-use on a ragged trace strictly under the dense
    ``slots x max_len`` equivalent — while generating the exact same tokens;
  * multi-device (``--sharded``, needs >= 8 devices — force them on CPU with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``): engines sharded
    over 1-, 2-, and 8-device data-parallel meshes generate tokens identical
    to the unsharded engine, with zero recompiles after warmup and the paged
    pool's per-shard accounting summing exactly to the unsharded totals;
  * disaggregated identity (``disagg_identity_gate``, in the default run):
    a role-split prefill/decode ``DisaggEngine`` (KV-suitcase handoff
    between the role pools) generates tokens bitwise-identical to the
    interleaved engine across all three state families, with zero
    recompiles after warmup on either role and exactly one handoff per
    request;
  * disaggregated serving (``--disagg``, needs >= 8 devices): on a
    prefill-heavy trace, prefill pinned to 4 devices + decode to the other
    4 matches the tokens of an interleaved dp=8 engine at equal device
    count, compiles nothing after warmup on either submesh, and holds a
    strictly better decode p99 time-between-tokens — the interference
    number disaggregation exists to buy;
  * tracing overhead (``trace_overhead_gate``): with the ring tracer ON the
    warmed engine must hold >= 95% of its tracing-OFF tokens/s on the same
    trace, generate bitwise-identical tokens, and compile nothing new — the
    observability layer is paid for in preallocated tuples, not throughput;
  * program accounting (``program_accounting_gate``): the cost observatory
    covers the warmed inventory exactly — every compiled program carries
    analyzed static FLOPs/bytes (plus memory watermarks, the bench engine
    runs ``program_memory=True``), the exercised programs accumulated
    invocations and device-synchronized seconds, and the oracle-resolved
    plan's per-cluster rollup lands in the drift section;
  * regression (``--compare results/serve_bench_baseline.json``): tokens/s
    must stay within 20% of the committed baseline, tracing overhead within
    the 5% budget, and no gate metric (recompiles, prefix hit rate, peak
    blocks, decode stalls) may regress; the diff is written next to
    ``--json`` for the CI artifact;
  * trend (``--ledger results/perf_ledger.jsonl``): after every gate above
    passes, the run appends one record to the append-only perf ledger and
    the newest record must stay inside the rolling-median band
    (``repro.obs.ledger.trend_check``) — history-aware regression tracking
    on top of the single committed baseline point.

  PYTHONPATH=src python benchmarks/serve_bench.py
  PYTHONPATH=src python benchmarks/serve_bench.py --arch recurrentgemma-2b \\
      --requests 24 --slots 4 --json results/serve_bench.json
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python benchmarks/serve_bench.py --sharded
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

VERIFY_ARCHS = ("qwen3-0.6b", "recurrentgemma-2b", "falcon-mamba-7b")

# tracing-on tokens/s may sit at most this fraction below tracing-off
TRACE_OVERHEAD_BOUND = 0.05


def make_trace(n: int, vocab: int, lengths: list[int], max_new: int,
               seed: int):
    from repro.serve.engine import Request
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        ln = lengths[i % len(lengths)]
        ln = max(1, ln + int(rng.randint(-2, 3)))       # jitter within bucket
        reqs.append(Request(rid=i,
                            prompt=rng.randint(1, vocab, ln).tolist(),
                            max_new_tokens=max_new))
    return reqs


def verify_chunked_identity(max_new: int = 6) -> dict:
    """Chunked vs unchunked engines must generate identical token ids for a
    long prompt, per state family (KV cache / RG-LRU / Mamba SSM)."""
    import jax
    from repro.configs import reduced_config
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine

    out = {}
    for arch in VERIFY_ARCHS:
        cfg = reduced_config(arch)
        cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompt = np.random.RandomState(7).randint(
            1, cfg.vocab_size, 45).tolist()

        chunked = ServeEngine(model, params, slots=2, max_len=128,
                              buckets=(16,), prefill_chunk=16)
        (rc,) = chunked.run([Request(rid=0, prompt=prompt,
                                     max_new_tokens=max_new)])
        unchunked = ServeEngine(model, params, slots=2, max_len=128)
        (ru,) = unchunked.run([Request(rid=0, prompt=prompt,
                                       max_new_tokens=max_new)])
        assert chunked.stats.prefill_chunks >= 3, (
            f"{arch}: expected a multi-chunk prefill, got "
            f"{chunked.stats.prefill_chunks}")
        assert rc.generated == ru.generated, (
            f"{arch}: chunked prefill diverged from unchunked reference:\n"
            f"  chunked:   {rc.generated}\n  unchunked: {ru.generated}")
        out[arch] = {"tokens": rc.generated,
                     "chunks": chunked.stats.prefill_chunks}
    return out


def policy_identity_gate(max_new: int = 6) -> dict:
    """Oracle-resolved engines must be a pure re-derivation of the fixed
    configuration: same tokens, same closed program inventory.

    For each state family, builds the same reduced model twice — once with
    ``policy="fixed"`` (constructor-global knobs) and once with
    ``policy="auto"`` (ExecutionOracle characterize -> cluster -> cost) —
    and asserts (a) bitwise-identical generated tokens, (b) zero recompiles
    after warmup on the auto engine, (c) the auto engine's stats carry the
    placement section with the plan's per-cluster policies and predictions.
    """
    import jax
    from repro.configs import reduced_config
    from repro.launch.serve import build_engine
    from repro.models import build_model
    from repro.serve.engine import Request

    out = {}
    for arch in VERIFY_ARCHS:
        cfg = reduced_config(arch)
        cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
        params = build_model(cfg).init(jax.random.PRNGKey(0))

        def trace():
            rng = np.random.RandomState(11)
            return [Request(rid=i,
                            prompt=rng.randint(1, cfg.vocab_size,
                                               5 + 7 * i).tolist(),
                            max_new_tokens=max_new) for i in range(4)]

        def run(policy):
            eng = build_engine(cfg, params, slots=2, max_len=64,
                               max_bucket=32, policy=policy)
            eng.warmup()
            w = eng.stats.summary()
            eng.reset_stats()
            done = eng.run(trace())
            s = eng.stats.summary()
            rec = (s["prefill_compiles"] - w["prefill_compiles"]) \
                + (s["decode_compiles"] - w["decode_compiles"])
            return [r.generated for r in done], s, rec

        fixed_toks, fixed_s, _ = run("fixed")
        auto_toks, auto_s, auto_rec = run("auto")
        assert auto_toks == fixed_toks, (
            f"{arch}: --policy auto changed generated tokens:\n"
            f"  auto:  {auto_toks}\n  fixed: {fixed_toks}")
        assert auto_rec == 0, (
            f"{arch}: {auto_rec} recompiles after warmup with the "
            f"placement policy active")
        placement = auto_s.get("placement")
        assert placement and placement["source"] == "auto", placement
        assert placement["policies"], (
            f"{arch}: auto plan resolved no per-cluster policies")
        assert fixed_s["placement"]["source"] == "fixed", fixed_s.get(
            "placement")
        out[arch] = {
            "tokens_identical": auto_toks == fixed_toks,
            "recompiles_after_warmup": auto_rec,
            "clusters": placement["layer_clusters"],
            "decode_overrides": placement["decode_overrides"],
            "predicted": placement["predicted"],
            "measured": placement["measured"],
        }
    return out


def paged_shared_prefix_gate(max_new: int = 6) -> dict:
    """The paged-KV + prefix-cache acceptance workload (qwen3: the pure
    full-attention stack, the one whose every layer is block-sharable).

    Asserts (a) a nonzero prefix-cache hit rate and fewer prefill tokens
    computed than the identical trace with the cache off, (b) zero decode/
    prefill recompiles after warmup with paging on, (c) peak KV blocks in
    use on a ragged-length trace strictly under the dense ``slots x max_len``
    equivalent — with generated tokens identical to the cache-off engine.
    """
    import jax
    from repro.configs import reduced_config
    from repro.launch.serve import build_engine
    from repro.models import build_model
    from repro.serve.engine import Request

    arch = "qwen3-0.6b"
    cfg = reduced_config(arch)
    cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots, max_len, bs = 4, 128, 16
    # fewer physical blocks than the dense equivalent: paging must actually
    # cap memory, not just re-index it
    kv_blocks = slots * (max_len // bs) * 3 // 4

    def engine(prefix_cache):
        return build_engine(cfg, params, slots=slots, max_len=max_len,
                            max_bucket=64, max_prefill_per_step=4,
                            kv_block_size=bs, kv_blocks=kv_blocks,
                            prefix_cache=prefix_cache)

    ragged = [5, 11, 23, 34, 47, 60]

    def trace():
        rng = np.random.RandomState(13)
        shared = rng.randint(1, cfg.vocab_size, 40).tolist()   # 2.5 blocks
        out = [Request(rid=i, prompt=shared + rng.randint(
                   1, cfg.vocab_size, 3 + i).tolist(),
                   max_new_tokens=max_new) for i in range(8)]
        out += [Request(rid=100 + i, prompt=rng.randint(
                    1, cfg.vocab_size, n).tolist(), max_new_tokens=max_new)
                for i, n in enumerate(ragged)]
        return out

    cold = engine(prefix_cache=False)
    cold.warmup()
    cold.run(trace())
    cold_s = cold.stats.summary()

    warm = engine(prefix_cache=True)
    warm.warmup()
    w0 = warm.stats.summary()
    assert w0["prefill_compiles"] > 0, "compile counters unavailable"
    warm.reset_stats()
    done = warm.run(trace())
    warm_s = warm.stats.summary()

    # identical outputs with the cache on
    ref = engine(prefix_cache=False)
    ref_done = ref.run(trace())
    assert [r.generated for r in done] == [r.generated for r in ref_done], \
        "prefix cache changed generated tokens"

    kv = warm_s["kv"]
    # (a) the cache hit, and skipped real prefill work
    assert kv["prefix_hit_rate"] > 0, kv
    assert warm_s["prefill_tokens_computed"] \
        < cold_s["prefill_tokens_computed"], (warm_s, cold_s)
    # (b) paging + prefix shortcuts stay inside the warmed program inventory
    recompiles = (warm_s["prefill_compiles"] - w0["prefill_compiles"]) \
        + (warm_s["decode_compiles"] - w0["decode_compiles"])
    assert recompiles == 0, \
        f"{recompiles} recompiles after warmup with paging on"
    # (c) ragged lengths keep peak blocks under the dense equivalent — gated
    # against a bound derived from the trace's ACTUAL sequence lengths (the
    # `slots` largest per-request block demands), not the pool size we
    # configured, so a paging regression that pins whole-max_len worth of
    # blocks per slot fails even inside a generously sized pool
    from repro.serve.kvpool import blocks_for
    dense_equiv = slots * (max_len // bs)
    need = sorted(blocks_for(len(r.prompt) + max_new, bs) for r in trace())
    concurrent_bound = sum(need[-slots:])
    assert concurrent_bound < dense_equiv, (concurrent_bound, dense_equiv)
    assert kv["blocks_peak"] <= concurrent_bound, (kv, concurrent_bound)
    assert kv["decode_stalls"] == 0, kv     # the constrained pool sufficed
    assert kv["pool_blocks"] < dense_equiv
    return {"cold_prefill_tokens_computed":
            cold_s["prefill_tokens_computed"],
            "warm_prefill_tokens_computed":
            warm_s["prefill_tokens_computed"],
            "kv": kv, "dense_equivalent_blocks": dense_equiv,
            "concurrent_demand_bound_blocks": concurrent_bound,
            "recompiles_after_warmup": recompiles}


def sharded_serve_gate(max_new: int = 6) -> dict:
    """Multi-device serving acceptance gate.

    Runs the shared-prefix + ragged paged workload on engines sharded over
    1-, 2-, and 8-device data-parallel meshes (and a 4x2 tensor-parallel
    mesh) and asserts, per mesh: (a) generated tokens identical to the
    unsharded reference engine (hard-gated on the pure-dp meshes, where
    identity is a structural invariant; recorded informationally on the TP
    mesh, where collectives reorder reductions), (b) zero prefill/decode
    recompiles after warmup — the NamedSharding-pinned program inventory is
    closed, (c) the paged pool's per-shard accounting sums exactly to the
    unsharded totals (in-use per tick, and the per-shard distribution at
    the peak summing to the unsharded peak).
    """
    import jax
    from repro.configs import reduced_config
    from repro.launch.mesh import make_serve_mesh
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine

    ndev = len(jax.devices())
    assert ndev >= 8, (
        f"the sharded gate needs >= 8 devices, found {ndev} — on CPU run "
        f"under XLA_FLAGS=--xla_force_host_platform_device_count=8")
    cfg = reduced_config("qwen3-0.6b")
    cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    slots, max_len, bs, kv_blocks = 8, 128, 16, 48

    def trace():
        rng = np.random.RandomState(13)
        shared = rng.randint(1, cfg.vocab_size, 40).tolist()
        out = [Request(rid=i, prompt=shared + rng.randint(
                   1, cfg.vocab_size, 3 + i).tolist(),
                   max_new_tokens=max_new) for i in range(8)]
        out += [Request(rid=100 + i, prompt=rng.randint(
                    1, cfg.vocab_size, n).tolist(), max_new_tokens=max_new)
                for i, n in enumerate([5, 23, 47, 78, 90])]  # 78/90: chunked
        return out

    def run(mesh):
        eng = ServeEngine(build_model(cfg), params, slots=slots,
                          max_len=max_len, buckets=(16, 32, 64),
                          max_prefill_per_step=4, kv_block_size=bs,
                          kv_blocks=kv_blocks, mesh=mesh)
        eng.warmup()
        w = eng.stats.summary()
        assert w["prefill_compiles"] > 0, "compile counters unavailable"
        eng.reset_stats()
        done = eng.run(trace())
        s = eng.stats.summary()
        rec = (s["prefill_compiles"] - w["prefill_compiles"]) \
            + (s["decode_compiles"] - w["decode_compiles"])
        return [r.generated for r in done], s, rec

    ref_tokens, ref_s, ref_rec = run(None)
    assert ref_rec == 0, f"{ref_rec} recompiles on the unsharded reference"
    out = {"devices": ndev, "unsharded_kv": ref_s["kv"], "meshes": {}}
    for dp, mp in ((1, 1), (2, 1), (8, 1), (4, 2)):
        tag = f"{dp}x{mp}"
        toks, s, rec = run(make_serve_mesh(dp, mp))
        kv = s["kv"]
        if mp == 1:
            # bitwise identity is a *pure-dp* invariant (no per-slot
            # reduction crosses a shard) — hard-gated.  On TP meshes
            # model-axis collectives reorder reductions, so identity holds
            # empirically but is recorded, not asserted: a ulp-level argmax
            # tie after a JAX upgrade is not a serving regression.
            assert toks == ref_tokens, (
                f"mesh {tag}: sharded engine diverged from the "
                f"single-device reference")
        assert rec == 0, f"mesh {tag}: {rec} recompiles after warmup"
        shards = kv.get("shards", 1)
        if shards > 1:
            assert shards == dp, (tag, kv)
            # per-shard accounting must mirror the device layout and sum to
            # the single-device totals: the allocator is mesh-independent
            assert sum(kv["in_use_per_shard"]) == kv["blocks_in_use"], kv
            assert sum(kv["peak_per_shard"]) == kv["blocks_peak"], kv
        assert kv["blocks_peak"] == ref_s["kv"]["blocks_peak"], (kv, ref_s)
        assert kv["prefix_hit_rate"] == ref_s["kv"]["prefix_hit_rate"]
        out["meshes"][tag] = {
            "recompiles_after_warmup": rec,
            "tokens_identical": toks == ref_tokens,
            "kv": {k: kv[k] for k in
                   ("blocks_peak", "prefix_hit_rate", "decode_stalls",
                    "shards", "in_use_per_shard", "peak_per_shard")
                   if k in kv},
            "tokens_per_s": s["tokens_per_s"],
        }
    return out


def disagg_identity_gate(max_new: int = 6) -> dict:
    """Prefill/decode disaggregation must be a pure re-plumbing of the
    interleaved engine (single-device functional split, all three state
    families).

    For each family, serves the same mixed trace — short prompts plus one
    long enough to chunk — through an interleaved ``ServeEngine`` and a
    ``DisaggEngine`` (role-split prefill/decode pair with KV-suitcase
    handoff) and asserts (a) bitwise-identical generated tokens, (b) zero
    recompiles after warmup on either role (the handoff export/import
    programs are part of the closed warmed inventory), (c) exactly one
    handoff per request with none left pending.  qwen3 additionally runs
    the paged pool with the prefix cache on, so the suitcase block copy and
    a COW'd shared prefix both cross the handoff.
    """
    import jax
    from repro.configs import reduced_config
    from repro.models import build_model
    from repro.serve.disagg import DisaggEngine
    from repro.serve.engine import Request, ServeEngine

    out = {}
    for arch in VERIFY_ARCHS:
        cfg = reduced_config(arch)
        cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        kw = dict(max_len=128, buckets=(16, 32), prefill_chunk=32)
        if arch == "qwen3-0.6b":
            kw.update(kv_block_size=16, kv_blocks=56)

        def trace():
            rng = np.random.RandomState(23)
            shared = rng.randint(1, cfg.vocab_size, 20).tolist()
            reqs = [Request(rid=i, prompt=rng.randint(
                        1, cfg.vocab_size, n).tolist(), max_new_tokens=max_new)
                    for i, n in enumerate([4, 11, 30, 70])]   # 70 -> chunked
            # shared-prefix pair: on the paged engine the second admission
            # COW-hits the first's blocks, and both then cross the handoff
            reqs += [Request(rid=10 + i, prompt=shared + rng.randint(
                         1, cfg.vocab_size, 3 + i).tolist(),
                         max_new_tokens=max_new) for i in range(2)]
            return reqs

        ref = ServeEngine(model, params, slots=4, **kw)
        ref_done = ref.run(trace())

        dis = DisaggEngine(model, params, prefill_slots=2, decode_slots=4,
                           **kw)
        dis.warmup()
        warm = dis.summary()
        dis.reset_stats()
        done = dis.run(trace())
        s = dis.summary()
        rec = dis.recompiles_since(warm)
        assert [r.generated for r in done] \
            == [r.generated for r in ref_done], (
            f"{arch}: disaggregated serving diverged from the interleaved "
            f"reference:\n  disagg:      {[r.generated for r in done]}\n"
            f"  interleaved: {[r.generated for r in ref_done]}")
        assert rec == 0, (
            f"{arch}: {rec} recompiles after warmup across the role pair")
        assert s["handoffs"] == len(trace()), s
        assert s["handoffs_pending"] == 0, s
        pre_kv = s["roles"]["prefill"].get("kv")
        if pre_kv:
            ref_kv = ref.stats.summary()["kv"]
            assert pre_kv["prefix_hit_rate"] == ref_kv["prefix_hit_rate"], (
                pre_kv, ref_kv)
        out[arch] = {
            "tokens_identical": True,
            "recompiles_after_warmup": rec,
            "handoffs": s["handoffs"],
            "handoff_stalls": s["handoff_stalls"],
            "per_role_tokens_per_s": s["per_role_tokens_per_s"],
            "decode_tbt_ms": s["decode_tbt_ms"],
        }
    return out


def disagg_serve_gate(max_new: int = 16) -> dict:
    """Disaggregated-serving acceptance gate (needs >= 8 devices — force
    them on CPU with XLA_FLAGS=--xla_force_host_platform_device_count=8).

    Serves a prefill-heavy trace — long chunked prompts keep arriving while
    short requests decode — through (a) an interleaved engine data-parallel
    over all 8 devices and (b) a ``DisaggEngine`` with prefill pinned to 4
    devices and decode to the other 4 (equal device count), and asserts:
    bitwise-identical tokens, zero recompiles after warmup on either
    submesh, every request handed off exactly once with none stranded, and
    the disaggregated decode p99 time-between-tokens strictly better than
    interleaved — on the interleaved engine every chunk-prefill tick
    inflates the tick wall for all decoding slots; the dedicated decode
    submesh never sees a prefill.
    """
    import jax
    from repro.configs import reduced_config
    from repro.launch.mesh import RoleConfig, make_role_meshes, \
        make_serve_mesh
    from repro.models import build_model
    from repro.serve.disagg import DisaggEngine
    from repro.serve.engine import Request, ServeEngine

    ndev = len(jax.devices())
    assert ndev >= 8, (
        f"the disagg gate needs >= 8 devices, found {ndev} — on CPU run "
        f"under XLA_FLAGS=--xla_force_host_platform_device_count=8")
    cfg = reduced_config("qwen3-0.6b")
    cfg = cfg.replace(num_layers=max(2, len(cfg.block_pattern)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots, max_len, bs, kv_blocks = 8, 256, 16, 120
    buckets = (16, 32, 64)

    def trace():
        rng = np.random.RandomState(31)
        # 6 short decode-bound requests (16 new tokens each) ...
        reqs = [Request(rid=i, prompt=rng.randint(
                    1, cfg.vocab_size, 5 + 3 * i).tolist(),
                    max_new_tokens=max_new) for i in range(6)]
        # ... + 8 long prompts that chunk at 64 wide, arriving throughout:
        # on the interleaved engine their chunks share ticks with decode
        reqs += [Request(rid=100 + i, prompt=rng.randint(
                     1, cfg.vocab_size, 150 + 10 * i).tolist(),
                     max_new_tokens=4) for i in range(8)]
        return reqs

    def common(mesh_kw):
        return dict(max_len=max_len, buckets=buckets,
                    kv_block_size=bs, kv_blocks=kv_blocks,
                    max_prefill_per_step=2, **mesh_kw)

    inter = ServeEngine(model, params, slots=slots,
                        max_prefill_batch=4,
                        **common({"mesh": make_serve_mesh(8, 1)}))
    inter.warmup()
    iw = inter.stats.summary()
    assert iw["prefill_compiles"] > 0, "compile counters unavailable"
    inter.reset_stats()
    inter_done = inter.run(trace())
    is_ = inter.stats.summary()
    inter_rec = (is_["prefill_compiles"] - iw["prefill_compiles"]) \
        + (is_["decode_compiles"] - iw["decode_compiles"])
    assert inter_rec == 0, \
        f"{inter_rec} recompiles on the interleaved reference"
    inter_tbt = inter.stats.metrics.histogram("decode_tbt_s")

    pm, dm = make_role_meshes(RoleConfig(prefill=4, decode=4))
    dis = DisaggEngine(model, params, prefill_mesh=pm, decode_mesh=dm,
                       prefill_slots=4, decode_slots=slots,
                       max_prefill_batch=4,
                       **common({}))
    dis.warmup()
    warm = dis.summary()
    dis.reset_stats()
    done = dis.run(trace())
    s = dis.summary()
    rec = dis.recompiles_since(warm)

    assert [r.generated for r in done] \
        == [r.generated for r in inter_done], (
        "disaggregated serving diverged from the interleaved engine at "
        "equal device count")
    assert rec == 0, f"{rec} recompiles after warmup across the submeshes"
    assert s["handoffs"] == len(trace()), s
    assert s["handoffs_pending"] == 0, s

    inter_p99 = inter_tbt.quantile(0.99)
    dis_p99 = s["decode_tbt_ms"]["p99"] / 1e3
    assert dis_p99 < inter_p99, (
        f"disaggregation did not improve decode p99 time-between-tokens: "
        f"{1e3 * dis_p99:.2f}ms disagg vs {1e3 * inter_p99:.2f}ms "
        f"interleaved — chunk-prefill interference should dominate the "
        f"interleaved tail")
    return {
        "devices": ndev,
        "tokens_identical": True,
        "recompiles_after_warmup": rec,
        "handoffs": s["handoffs"],
        "handoff_stalls": s["handoff_stalls"],
        "handoff_time_s": s["handoff_time_s"],
        "per_role_tokens_per_s": s["per_role_tokens_per_s"],
        "decode_tbt_p99_ms": {"interleaved": 1e3 * inter_p99,
                              "disagg": 1e3 * dis_p99,
                              "improvement_frac":
                                  1.0 - dis_p99 / inter_p99},
        "decode_tbt_p50_ms": {"interleaved":
                                  1e3 * inter_tbt.quantile(0.5),
                              "disagg": s["decode_tbt_ms"]["p50"]},
        "interleaved_tokens_per_s": is_["tokens_per_s"],
        "disagg_tokens_per_s": s["tokens_per_s"],
    }


def trace_overhead_gate(engine, trace_fn, reps: int = 2) -> dict:
    """Tracing must cost ring-buffer tuples, not throughput.

    On the already-warmed bench engine, runs the same trace with the tracer
    OFF and ON (``reps`` times each, best tokens/s per mode absorbs CI
    scheduler noise) and asserts (a) tracing-on throughput stays >= 95% of
    tracing-off, (b) generated tokens are bitwise identical — the tracer
    observes the tick loop, it must not perturb it, and (c) zero prefill/
    decode recompiles across every run: emitting events compiles nothing.
    """
    tracer = engine.tracer
    was_enabled = tracer.enabled
    before = engine.stats.summary()
    best_tps = {False: 0.0, True: 0.0}
    tokens = {}
    for enabled in (False, True) * reps:
        tracer.enabled = enabled
        engine.reset_stats()
        done = engine.run(trace_fn())
        s = engine.stats.summary()
        best_tps[enabled] = max(best_tps[enabled], s["tokens_per_s"])
        tokens[enabled] = [r.generated for r in done]
        tracer.clear()
    tracer.enabled = was_enabled
    after = engine.stats.summary()
    recompiles = (after["prefill_compiles"] - before["prefill_compiles"]) \
        + (after["decode_compiles"] - before["decode_compiles"])

    assert tokens[True] == tokens[False], \
        "enabling the tracer changed generated tokens"
    assert recompiles == 0, \
        f"{recompiles} recompiles while toggling the tracer"
    overhead = max(0.0, 1.0 - best_tps[True] / best_tps[False])
    assert overhead <= TRACE_OVERHEAD_BOUND, (
        f"tracing overhead {overhead:.1%} exceeds the "
        f"{TRACE_OVERHEAD_BOUND:.0%} budget: {best_tps[True]:.1f} tokens/s "
        f"on vs {best_tps[False]:.1f} off")
    return {"tokens_per_s_off": best_tps[False],
            "tokens_per_s_on": best_tps[True],
            "overhead_frac": overhead,
            "tokens_identical": True,
            "recompiles_after_warmup": recompiles}


def program_accounting_gate(engine, measured: dict) -> dict:
    """The cost observatory must cover the warmed inventory exactly.

    Asserts (a) the measured summary's ``programs`` section holds precisely
    the programs ``warmup()`` compiled — every (batch-bucket, bucket)
    prefill shape, the chunk continuation and block-clone programs when
    reachable, and the decode step; (b) every entry was statically analyzed
    (lowered-HLO FLOPs and bytes, and — the bench engine runs with
    ``program_memory=True`` — compiled memory watermarks); (c) the programs
    the trace exercised accumulated invocations and device-synchronized
    seconds, so the roofline rates are live, not vacuous; (d) the
    oracle-resolved plan's per-cluster rollup reached the drift section.
    """
    progs = (measured.get("programs") or {}).get("programs")
    assert progs, "stats summary carries no programs section"
    expected = {f"prefill[{nb}x{b}]" for b in engine.buckets
                for nb in engine.batch_buckets}
    if engine.max_len - 1 > engine.buckets[-1] \
            or (engine.kv is not None and engine.kv.prefix_enabled):
        expected.add("chunk")
    if engine._copy is not None:
        expected.add("copy")
    expected.add("decode")
    assert set(progs) == expected, (
        f"programs section does not match the warmed inventory:\n"
        f"  missing: {sorted(expected - set(progs))}\n"
        f"  extra:   {sorted(set(progs) - expected)}")
    bad = [n for n, p in progs.items()
           if not (p["analyzed"] and p["flops"] > 0
                   and p["bytes_accessed"] > 0 and "memory" in p)]
    assert not bad, f"programs without full static cost: {sorted(bad)}"
    live = [n for n, p in progs.items() if p["invocations"] > 0]
    assert progs["decode"]["invocations"] > 0, progs["decode"]
    assert any(n.startswith("prefill[") for n in live), sorted(live)
    for n in live:
        p = progs[n]
        assert p["measured_s"] > 0 and p["flops_per_s"] > 0 \
            and 0 < p["utilization"] <= 1.0, (n, p)
    placement = measured.get("placement") or {}
    if placement.get("policies") and placement.get("drift"):
        assert "clusters" in placement["drift"], (
            "oracle-planned engine produced no per-cluster rollup in drift")
    return {"programs": len(progs), "invoked": sorted(live),
            "temp_bytes_peak": measured["programs"].get("temp_bytes_peak"),
            "utilization": {n: progs[n]["utilization"] for n in sorted(live)}}


# ------------------------------------------------------------ regression gate
def _report_metrics(report: dict) -> dict:
    """Flatten the gate metrics a baseline records / a compare run checks."""
    m = report["measure"]
    out = {
        "tokens_per_s": m["tokens_per_s"],
        "recompiles_after_warmup": report["recompiles_after_warmup"],
    }
    kv = report.get("paged_prefix", {}).get("kv")
    if kv:
        out.update({"prefix_hit_rate": kv["prefix_hit_rate"],
                    "blocks_peak": kv["blocks_peak"],
                    "decode_stalls": kv["decode_stalls"]})
    overhead = report.get("trace_overhead")
    if overhead:
        out["trace_overhead_frac"] = overhead["overhead_frac"]
    di = report.get("disagg_identity")
    if di:
        out["disagg_handoffs"] = sum(v["handoffs"] for v in di.values())
        out["disagg_recompiles_after_warmup"] = sum(
            v["recompiles_after_warmup"] for v in di.values())
    return out


def compare_to_baseline(report: dict, baseline: dict,
                        tps_drop: float = 0.20) -> dict:
    """Gate the current run against a committed baseline: tokens/s may not
    drop more than ``tps_drop`` (20%), tracing overhead must stay inside its
    absolute 5% budget, and no gate metric may regress — recompiles/stalls/
    peak-blocks above baseline or hit rate below it."""
    cur = _report_metrics(report)
    checks = []

    def check(name, ok):
        checks.append({"metric": name, "ok": bool(ok),
                       "current": cur.get(name),
                       "baseline": baseline.get(name)})

    check("tokens_per_s",
          cur["tokens_per_s"] >= (1.0 - tps_drop) * baseline["tokens_per_s"])
    check("recompiles_after_warmup",
          cur["recompiles_after_warmup"] <= baseline["recompiles_after_warmup"])
    if "disagg_handoffs" in baseline:
        # handoff count is deterministic (exactly one per request): any
        # drift — skipped or doubled handoffs — is a lifecycle regression
        check("disagg_handoffs",
              cur.get("disagg_handoffs") == baseline["disagg_handoffs"])
    for name, worse_is_higher in (("prefix_hit_rate", False),
                                  ("blocks_peak", True),
                                  ("decode_stalls", True),
                                  ("disagg_recompiles_after_warmup", True)):
        if name not in baseline:
            continue
        if name not in cur:
            check(name, False)          # metric vanished: that's a regression
            continue
        check(name, cur[name] <= baseline[name] if worse_is_higher
              else cur[name] >= baseline[name])
    if "trace_overhead_frac" in baseline:
        # absolute budget, not baseline-relative: a lucky 0.1%-overhead
        # baseline run must not turn ordinary scheduler noise into failures
        check("trace_overhead_frac",
              "trace_overhead_frac" in cur
              and cur["trace_overhead_frac"] <= TRACE_OVERHEAD_BOUND)
    return {"ok": all(c["ok"] for c in checks), "tps_drop_allowed": tps_drop,
            "checks": checks}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=18)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-bucket", type=int, default=64)
    ap.add_argument("--max-prefill-per-step", type=int, default=4)
    ap.add_argument("--max-prefill-batch", type=int, default=4)
    ap.add_argument("--policy", default="auto", choices=("auto", "fixed"),
                    help="resolve engine knobs through the placement oracle "
                         "('auto', default) or keep constructor-global "
                         "knobs ('fixed')")
    ap.add_argument("--skip-verify", action="store_true",
                    help="skip the 3-family chunked-identity and "
                         "policy-identity checks")
    ap.add_argument("--skip-paged", action="store_true",
                    help="skip the paged-KV shared-prefix workload")
    ap.add_argument("--sharded", action="store_true",
                    help="run ONLY the multi-device sharded gate (needs >= 8 "
                         "devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--disagg", action="store_true",
                    help="run ONLY the disaggregated prefill/decode gate "
                         "(needs >= 8 devices): role submeshes vs an "
                         "interleaved engine at equal device count — token "
                         "identity, zero recompiles, and strictly better "
                         "decode p99 time-between-tokens")
    ap.add_argument("--trace", default="",
                    help="write the measured phase's Chrome trace-event JSON "
                         "here (open in Perfetto / chrome://tracing)")
    ap.add_argument("--no-trace", action="store_true",
                    help="disable the ring tracer for the whole run (the "
                         "overhead gate still toggles it to measure cost)")
    ap.add_argument("--compare", default="",
                    help="baseline JSON (results/serve_bench_baseline.json): "
                         "fail on >20%% tokens/s drop or any gate-metric "
                         "regression; the diff lands next to --json")
    ap.add_argument("--write-baseline", default="",
                    help="write this run's gate metrics as a new baseline")
    ap.add_argument("--json", default="", help="also write the report here")
    ap.add_argument("--ledger", default="",
                    help="append this run to the perf ledger "
                         "(results/perf_ledger.jsonl) after all gates pass, "
                         "then fail if it falls outside the rolling-median "
                         "trend band")
    ap.add_argument("--ledger-band", type=float, default=None,
                    help="trend band as a fraction of the rolling median "
                         "(default: repro.obs.ledger.DEFAULT_BAND)")
    args = ap.parse_args()

    if (args.sharded or args.disagg) and (args.compare
                                          or args.write_baseline):
        ap.error("--sharded/--disagg are standalone gates (token identity, "
                 "not throughput); run --compare/--write-baseline on the "
                 "standard bench")
    if args.sharded and args.disagg:
        ap.error("--sharded and --disagg are separate standalone gates; "
                 "run them as two invocations")
    if args.trace and args.no_trace:
        ap.error("--trace needs the tracer on; drop --no-trace")
    if args.sharded or args.disagg:
        report = {"sharded": sharded_serve_gate()} if args.sharded \
            else {"disagg": disagg_serve_gate()}
        out = json.dumps(report, indent=1)
        print(out)
        if args.json:
            p = Path(args.json)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(out)
        return

    from repro.configs import get_config, reduced_config
    from repro.launch.serve import build_engine

    cfg = reduced_config(args.arch)
    engine = build_engine(cfg, slots=args.slots, max_len=args.max_len,
                          max_bucket=args.max_bucket,
                          max_prefill_per_step=args.max_prefill_per_step,
                          max_prefill_batch=args.max_prefill_batch,
                          plan_cfg=get_config(args.arch),
                          policy=args.policy,
                          program_memory=True)
    if args.no_trace:
        engine.tracer.enabled = False
    # short lengths spanning >= 3 buckets, plus prompts long enough to need
    # ~4 chunk-continuation calls each
    assert len(engine.buckets) >= 3, (
        f"buckets {engine.buckets} span < 3 sizes; raise --max-bucket/"
        f"--max-len")
    long_len = min(4 * engine.prefill_chunk - 3, args.max_len - 3)
    assert long_len > engine.buckets[-1], (
        "--max-len leaves no room for prompts beyond the largest bucket")
    short_lengths = [5, 14, 20, 30, 40, 60]
    mixed_lengths = short_lengths + [long_len, long_len]

    # warmup compiles the full program inventory up front
    engine.warmup()
    warm = engine.stats.summary()
    # guard against a vacuous gate: if jit compile counters are unavailable
    # (private _cache_size dropped by a JAX upgrade) they read 0 everywhere
    # and 0 - 0 == 0 would "pass" even while every prefill recompiles
    assert warm["prefill_compiles"] > 0, (
        "compile counters unavailable — cannot certify the zero-recompile "
        "gate on this JAX version")

    # decode-only baseline: short prompts, no chunking in flight
    engine.reset_stats()
    engine.run(make_trace(max(6, args.slots), cfg.vocab_size, short_lengths,
                          args.max_new, seed=0))
    baseline = engine.stats.summary()

    # measured phase: mixed trace with long (chunked) prompts.  The ring is
    # cleared first so --trace captures exactly this phase (warmup/baseline
    # events would collide with the measured trace's request ids)
    engine.reset_stats()
    engine.tracer.clear()
    engine.run(make_trace(args.requests, cfg.vocab_size, mixed_lengths,
                          args.max_new, seed=1))
    s = engine.stats.summary()
    ticks = engine.stats.ticks

    recompiles = (s["prefill_compiles"] - warm["prefill_compiles"]) \
        + (s["decode_compiles"] - warm["decode_compiles"])
    report = {
        "arch": args.arch,
        "slots": args.slots,
        "policy": args.policy,
        "placement": s.get("placement", {}),
        "buckets": list(engine.buckets),
        "prefill_chunk": engine.prefill_chunk,
        "batch_buckets": list(engine.batch_buckets),
        "warmup": {
            "prefill_compiles": warm["prefill_compiles"],
            "decode_compiles": warm["decode_compiles"],
        },
        "baseline_decode_step_ms": baseline["decode_step_ms"],
        "measure": s,
        "ticks": ticks,
        "recompiles_after_warmup": recompiles,
    }
    # snapshot the measured phase's trace BEFORE the overhead gate below
    # clears the ring buffer
    if args.trace:
        engine.save_trace(args.trace)
    report["trace"] = {"enabled": engine.tracer.enabled,
                       "events": len(engine.tracer),
                       "dropped_events": engine.tracer.dropped,
                       "path": args.trace or None}
    report["program_accounting"] = program_accounting_gate(engine, s)
    report["trace_overhead"] = trace_overhead_gate(
        engine, lambda: make_trace(args.requests, cfg.vocab_size,
                                   mixed_lengths, args.max_new, seed=1))
    if not args.skip_verify:
        report["chunked_identity"] = verify_chunked_identity()
        report["policy_identity"] = policy_identity_gate()
        report["disagg_identity"] = disagg_identity_gate()
    if not args.skip_paged:
        report["paged_prefix"] = paged_shared_prefix_gate()
    compare = None
    if args.compare:
        committed = json.loads(Path(args.compare).read_text())
        compare = compare_to_baseline(report, committed)
        report["compare"] = compare
    out = json.dumps(report, indent=1)
    print(out)
    if args.json:
        p = Path(args.json)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(out)
        if compare is not None:
            # the diff is its own artifact so a failed gate is one click away
            (p.parent / "serve_bench_compare.json").write_text(
                json.dumps(compare, indent=1))
    if args.write_baseline:
        p = Path(args.write_baseline)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(_report_metrics(report), indent=1) + "\n")
    if compare is not None:
        assert compare["ok"], (
            "serve_bench regressed against the committed baseline:\n"
            + json.dumps([c for c in compare["checks"] if not c["ok"]],
                         indent=1))

    assert recompiles == 0, \
        f"{recompiles} recompiles after warmup — bucketing is broken"
    # compare against BUCKETED prefills only — chunked long prompts inflate
    # `prefills` without adding `prefill_calls`, which would make the gate
    # vacuous on a fully-regressed (one call per request) batching path
    bucketed = s["prefills"] - s["prefills_chunked"]
    assert s["prefill_calls"] < bucketed, (
        f"batched admission had no effect: {s['prefill_calls']} compiled "
        f"prefill calls for {bucketed} bucketed prefills")
    assert s["prefill_chunks"] >= 4, (
        f"long prompts did not exercise chunked prefill "
        f"({s['prefill_chunks']} chunks)")
    # interleaving: if chunks ran on ticks with no decode work the tick count
    # would be >= chunks + decode steps; sharing ticks keeps it strictly below
    assert ticks < s["prefill_chunks"] + s["decode_steps"], (
        f"chunked prefill did not interleave with decode: {ticks} ticks for "
        f"{s['prefill_chunks']} chunks + {s['decode_steps']} decode steps")
    # decode-step latency while long prompts prefill stays within a generous
    # (CI-noise-tolerant) factor of the decode-only baseline
    assert s["decode_step_ms"] < 10 * baseline["decode_step_ms"], (
        f"decode-step latency regressed during chunked prefill: "
        f"{s['decode_step_ms']:.2f}ms vs baseline "
        f"{baseline['decode_step_ms']:.2f}ms")

    # only gate-passing runs enter the history: the ledger trends healthy
    # runs, the asserts above catch broken ones
    if args.ledger:
        from repro.obs.ledger import (DEFAULT_BAND, append_record,
                                      read_ledger, record_from_report,
                                      trend_check)
        lp = Path(args.ledger)
        di = report.get("disagg_identity") or {}
        roles = next((v["per_role_tokens_per_s"] for v in di.values()
                      if v.get("per_role_tokens_per_s")), None)
        append_record(lp, record_from_report(
            report,
            extra={"per_role_tokens_per_s": roles} if roles else None))
        band = args.ledger_band if args.ledger_band is not None \
            else DEFAULT_BAND
        trend = trend_check(read_ledger(lp), band=band)
        print(f"[ledger] {lp}: run {trend['runs']} appended")
        print(json.dumps(trend, indent=1))
        assert trend["ok"], (
            "perf ledger trend check failed — this run fell outside the "
            "rolling-median band:\n"
            + json.dumps([c for c in trend["checks"] if not c["ok"]],
                         indent=1))


if __name__ == "__main__":
    main()
