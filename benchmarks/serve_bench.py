"""Serving-engine benchmark: drive the bucketed continuous-batching engine
with a synthetic mixed-length request trace and report engine metrics as JSON.

Phase 1 (warmup) compiles one prefill program per bucket plus the decode
program; phase 2 (measure) replays a fresh trace over the same buckets and
must trigger **zero** recompiles — the acceptance gate for the bucketed
prefill path — while reporting TTFT, decode-step latency, tokens/s, slot
occupancy, and per-bucket padding overhead.

  PYTHONPATH=src python benchmarks/serve_bench.py
  PYTHONPATH=src python benchmarks/serve_bench.py --arch recurrentgemma-2b \\
      --requests 24 --slots 4 --json results/serve_bench.json
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np


def make_trace(n: int, vocab: int, lengths: list[int], max_new: int,
               seed: int):
    from repro.serve.engine import Request
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        ln = lengths[i % len(lengths)]
        ln = max(1, ln + int(rng.randint(-2, 3)))       # jitter within bucket
        reqs.append(Request(rid=i,
                            prompt=rng.randint(1, vocab, ln).tolist(),
                            max_new_tokens=max_new))
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=18)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--json", default="", help="also write the report here")
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.launch.serve import build_engine

    cfg = reduced_config(args.arch)
    engine = build_engine(cfg, slots=args.slots, max_len=args.max_len,
                          plan_cfg=get_config(args.arch))
    # lengths spanning >= 3 buckets (16 / 32 / 64 at the default min_bucket)
    lengths = [5, 14, 20, 30, 40, 60]
    usable = [b for b in (16, 32, 64) if b <= args.max_len]
    assert len(usable) >= 3, (
        f"--max-len {args.max_len} spans only prefill buckets {usable}; "
        f"the trace needs >= 3 (use --max-len >= 64)")

    warm = make_trace(max(6, args.slots), cfg.vocab_size, lengths,
                      args.max_new, seed=0)
    engine.run(warm)
    warm_summary = engine.stats.summary()
    # guard against a vacuous gate: if jit compile counters are unavailable
    # (private _cache_size dropped by a JAX upgrade) they read 0 everywhere
    # and 0 - 0 == 0 would "pass" even while every prefill recompiles
    assert warm_summary["prefill_compiles"] > 0, (
        "compile counters unavailable — cannot certify the zero-recompile "
        "gate on this JAX version")

    engine.reset_stats()
    engine.run(make_trace(args.requests, cfg.vocab_size, lengths,
                          args.max_new, seed=1))
    s = engine.stats.summary()

    recompiles = (s["prefill_compiles"] - warm_summary["prefill_compiles"]) \
        + (s["decode_compiles"] - warm_summary["decode_compiles"])
    report = {
        "arch": args.arch,
        "slots": args.slots,
        "buckets": list(engine.buckets),
        "warmup": {
            "prefill_compiles": warm_summary["prefill_compiles"],
            "decode_compiles": warm_summary["decode_compiles"],
            "bucket_counts": warm_summary["bucket_counts"],
        },
        "measure": s,
        "recompiles_after_warmup": recompiles,
    }
    out = json.dumps(report, indent=1)
    print(out)
    if args.json:
        p = Path(args.json)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(out)
    assert recompiles == 0, \
        f"{recompiles} recompiles after warmup — bucketing is broken"


if __name__ == "__main__":
    main()
