"""Benchmark harness entry point: one benchmark per paper table/figure plus
the Level-B dry-run/roofline summaries.  Prints `name,us_per_call,derived`
CSV rows (assignment format).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --fast     # skip kernel micro-sweeps
"""
import argparse
import json
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def bench_paper_figures(emit=print) -> None:
    from benchmarks import paper_figs
    paper_figs.run_all(emit)


def bench_dryrun_summary(emit=print) -> None:
    """Summarize the multi-pod dry-run artifacts (results/dryrun)."""
    d = ROOT / "results" / "dryrun"
    if not d.exists():
        emit("dryrun_summary,0.0,missing(run repro.launch.dryrun --all)")
        return
    n_ok = n_skip = n_err = 0
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        st = rec.get("status")
        n_ok += st == "ok"
        n_skip += st == "skip"
        n_err += st == "error"
        if st == "ok":
            emit(f"dryrun.{f.stem},0.0,"
                 f"flops_dev={rec.get('flops', 0):.3g};"
                 f"coll_wire={rec['collectives']['total_wire_bytes']:.3g};"
                 f"compile_s={rec.get('compile_s')}")
    emit(f"dryrun_summary,0.0,ok={n_ok};skip={n_skip};error={n_err}")
    assert n_err == 0, "dry-run cells failed"


def bench_roofline_summary(emit=print) -> None:
    d = ROOT / "results" / "roofline"
    if not d.exists():
        emit("roofline_summary,0.0,missing(run benchmarks.roofline --all)")
        return
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            continue
        t = rec["terms"]
        emit(f"roofline.{f.stem},0.0,"
             f"compute_ms={t['compute_s'] * 1e3:.2f};"
             f"memory_ms={t['memory_s'] * 1e3:.2f};"
             f"collective_ms={t['collective_s'] * 1e3:.2f};"
             f"dominant={rec['dominant']};"
             f"useful_ratio={rec['useful_ratio']:.3f};"
             f"roofline_frac={rec['roofline_fraction']:.4f}")


def bench_kernels(emit=print) -> None:
    """Kernel wall-time microbench (CPU interpret mode: correctness-path
    timing only; TPU timings come from the roofline terms)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.pascal_matmul import pascal_matmul, pascal_matmul_ref
    from repro.kernels.pavlov_rglru import pavlov_rglru, pavlov_rglru_ref
    from repro.kernels.flash_attention import flash_attention, flash_attention_ref

    key = jax.random.PRNGKey(0)
    cases = []
    x = jax.random.normal(key, (256, 512), jnp.float32)
    w = jax.random.normal(key, (512, 256), jnp.float32)
    cases.append(("pascal_matmul_256x512x256",
                  lambda: pascal_matmul(x, w, block_m=128, block_n=128,
                                        block_k=256),
                  lambda: pascal_matmul_ref(x, w)))
    a = jax.nn.sigmoid(jax.random.normal(key, (2, 128, 256)))
    b = jax.random.normal(key, (2, 128, 256)) * 0.5
    cases.append(("pavlov_rglru_2x128x256",
                  lambda: pavlov_rglru(a, b, block_t=64, block_e=128),
                  lambda: pavlov_rglru_ref(a, b)))
    q = jax.random.normal(key, (1, 128, 4, 32), jnp.float32)
    cases.append(("flash_attention_128x4x32",
                  lambda: flash_attention(q, q, q, block_q=64, block_kv=64),
                  lambda: flash_attention_ref(q, q, q)))
    for name, fn, ref in cases:
        fn()  # compile
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) / 3 * 1e6
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(ref())
        us_ref = (time.perf_counter() - t0) / 3 * 1e6
        emit(f"kernel.{name},{us:.0f},interpret_vs_ref_us={us_ref:.0f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    t0 = time.time()
    bench_paper_figures()
    bench_dryrun_summary()
    bench_roofline_summary()
    if not args.fast:
        bench_kernels()
    print(f"benchmarks_total,{(time.time() - t0) * 1e6:.0f},done")


if __name__ == "__main__":
    main()
